"""Render EXPERIMENTS.md roofline tables from dryrun_results.json."""

from __future__ import annotations

import json
import sys


def render(path="dryrun_results.json", mesh="16x16"):
    rows = json.load(open(path))
    out = []
    out.append(
        "| arch | shape | kind | compute s | memory s | collective s | bottleneck "
        "| model GFLOP | useful ratio | peak GiB/dev |")
    out.append("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | SKIP ({r['skipped'][:40]}…) | — | — | — |")
            continue
        if "error" in r:
            out.append(f"| {r['arch']} | {r['shape']} | — | ERROR | | | | | | |")
            continue
        rl = r["roofline"]
        pd = r["per_device_bytes"]
        peak = max(pd.get("peak", 0), pd.get("argument", 0) + pd.get("temp", 0)) / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} | {rl['compute_s']:.4f} "
            f"| {rl['memory_s']:.4f} | {rl['collective_s']:.4f} "
            f"| {rl['bottleneck'].replace('_s','')} | {r['model_gflops_global']:.0f} "
            f"| {r['useful_flops_ratio']:.2f} | {peak:.2f} |")
    return "\n".join(out)


if __name__ == "__main__":
    mesh = sys.argv[2] if len(sys.argv) > 2 else "16x16"
    print(render(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json", mesh))
