"""Dispatch-overhead benchmark: solves/sec through the three front ends.

The regime is small-model serving (the paper Sec. 4's per-step overhead
argument pushed to its limit): a tiny batch (b=16, f=4) of linear ODEs under
``dopri5``, where the integration itself is microseconds and *dispatch* --
Python call overhead, tracing, compilation-cache lookup -- decides the
throughput.  Three paths over identical numerics:

  eager        ``AutoDiffAdjoint.solve`` called directly: every call re-traces
               the full ``lax.while_loop`` program (what a naive caller gets).
  cached_jit   the solve wrapped in ``jax.jit`` once: traced on the first
               call, later calls pay jit's Python dispatch + cache lookup.
  compiled     ``CompiledSolver``: AOT ``lower().compile()`` executable behind
               an LRU config/shape cache -- zero retraces, minimal dispatch.

Reports solves/sec per path and the speedup of ``compiled`` over ``eager``
(the acceptance bar: >= 5x on CPU).

Usage: python -m benchmarks.dispatch_bench [--json [PATH]] [--calls N]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AutoDiffAdjoint, CompiledSolver, Stepper

BATCH, FEAT = 16, 4
T_EVAL_POINTS = 8


def _decay(t, y, args):
    return -y * args


def _fresh_inputs(n: int):
    """One distinct y0 per timed call: serving-shaped traffic, and donation
    in the compiled path may consume its input buffer."""
    base = np.linspace(0.5, 1.5, BATCH * FEAT, dtype=np.float32).reshape(BATCH, FEAT)
    return [jnp.asarray(base + 0.01 * i) for i in range(n)]


def _throughput(fn, inputs) -> float:
    """Solves/sec over the given per-call inputs (first call excluded: every
    path is allowed its one-time trace/compile)."""
    jax.block_until_ready(fn(inputs[0]))
    t0 = time.perf_counter()
    for y in inputs[1:]:
        jax.block_until_ready(fn(y))
    dt = time.perf_counter() - t0
    return (len(inputs) - 1) / dt


def rows(calls: int = 30):
    t_eval = jnp.linspace(0.0, 1.0, T_EVAL_POINTS)
    args = jnp.asarray(2.0)
    driver = AutoDiffAdjoint(Stepper("dopri5"))

    def eager(y):
        return AutoDiffAdjoint(Stepper("dopri5")).solve(_decay, y, t_eval, args=args)

    jitted = jax.jit(lambda y: driver.solve(_decay, y, t_eval, args=args))

    compiled = CompiledSolver(driver)

    def aot(y):
        return compiled.solve(_decay, y, t_eval, args=args)

    # Eager retracing is slow enough that a handful of calls suffices.
    eager_calls = max(4, calls // 5)
    r_eager = _throughput(eager, _fresh_inputs(eager_calls))
    r_jit = _throughput(jitted, _fresh_inputs(calls))
    r_aot = _throughput(aot, _fresh_inputs(calls))
    info = compiled.cache_info()

    speedup = r_aot / r_eager
    out = [
        ("eager/solves_per_sec", r_eager, f"b={BATCH} f={FEAT} dopri5"),
        ("cached_jit/solves_per_sec", r_jit, f"b={BATCH} f={FEAT} dopri5"),
        ("compiled/solves_per_sec", r_aot,
         f"b={BATCH} f={FEAT} dopri5 retraces={info.misses - 1} "
         f"speedup_vs_eager={speedup:.1f}x"),
    ]

    # The final-state serving path (t_eval=None): donation active, the
    # regime the CNF/serving workloads actually run.
    compiled_fs = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5")))

    def aot_final(y):
        return compiled_fs.solve(_decay, y, None, t_start=0.0, t_end=1.0, args=args)

    r_fs = _throughput(aot_final, _fresh_inputs(calls))
    out.append(("compiled_final_state/solves_per_sec", r_fs,
                f"b={BATCH} f={FEAT} dopri5 donate=auto"))
    return out


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_dispatch.json", default=None,
                        metavar="PATH", help="also write rows to a JSON file")
    parser.add_argument("--calls", type=int, default=30,
                        help="timed calls per path (first call excluded)")
    opts = parser.parse_args()

    records = []
    print("name,value,derived")
    t0 = time.time()
    for name, v, extra in rows(opts.calls):
        print(f"dispatch/{name},{v:.2f},{extra}", flush=True)
        records.append({"suite": "dispatch", "name": name, "value": v, "derived": extra})
    records.append({"suite": "dispatch", "name": "_suite_wall_s",
                    "value": time.time() - t0, "derived": ""})

    if opts.json:
        payload = {"bench": "dispatch", "unit": "solves/sec", "rows": records}
        with open(opts.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(records)} rows to {opts.json}", flush=True)


if __name__ == "__main__":
    main()
