"""Table 4: FEN-style benchmark (discretize-then-optimize).

Finite Element Networks learn dynamics of physical systems on a graph; the
benchmark-relevant structure is: an ODE whose dynamics are a graph message-
passing network, trained by backprop THROUGH the solver, with few (10) eval
points and small batch.  We reproduce that setup on a synthetic advection
field over a random geometric graph and measure loop time, model time / step,
steps and MAE -- the paper's Table 4 quantities.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solve_ivp, solve_ivp_scan

from .common import solve_joint, timed


def make_graph(n=64, k=6, seed=0):
    rng = np.random.default_rng(seed)
    pos = rng.uniform(size=(n, 2)).astype(np.float32)
    d2 = ((pos[:, None] - pos[None]) ** 2).sum(-1)
    nbr = np.argsort(d2, axis=1)[:, 1 : k + 1]  # (n, k)
    return jnp.asarray(pos), jnp.asarray(nbr)


def init_fen(key, feat=4, hidden=64):
    k1, k2, k3 = jax.random.split(key, 3)
    s = lambda k, sh: jax.random.normal(k, sh) / np.sqrt(sh[0])
    return {
        "w1": s(k1, (2 * feat, hidden)),
        "w2": s(k2, (hidden, hidden)),
        "w3": s(k3, (hidden, feat)),
    }


def fen_dynamics(nbr):
    def f(t, y, params):
        # y: (batch, n*feat) flattened graph state
        b = y.shape[0]
        n, k = nbr.shape
        feat = y.shape[1] // n
        yg = y.reshape(b, n, feat)
        msg = jnp.mean(yg[:, nbr, :], axis=2)  # (b, n, feat)
        h = jnp.concatenate([yg, msg], axis=-1)
        h = jnp.tanh(h @ params["w1"])
        h = jnp.tanh(h @ params["w2"])
        return (h @ params["w3"]).reshape(b, n * feat)

    return f


def run(batch=8, n=64, feat=4, n_eval=10, tol=1e-4, train_iters=15):
    pos, nbr = make_graph(n)
    key = jax.random.PRNGKey(0)
    params = init_fen(key, feat)
    f = fen_dynamics(nbr)

    # synthetic ground truth: smooth rotation of features over time
    y0 = jax.random.normal(key, (batch, n * feat)) * 0.5
    t_eval = jnp.linspace(0.0, 1.0, n_eval)
    theta = 0.8

    def true_traj(y0):
        ang = theta * t_eval
        c, s = jnp.cos(ang), jnp.sin(ang)
        yg = y0.reshape(batch, n, feat)
        out = jnp.stack([
            jnp.concatenate([
                yg[..., :2] * c[i] + yg[..., 2:] * s[i],
                yg[..., 2:] * c[i] - yg[..., :2] * s[i],
            ], -1).reshape(batch, n * feat)
            for i in range(n_eval)
        ], 1)
        return out

    target = true_traj(y0)

    def loss_fn(params):
        sol = solve_ivp_scan(f, y0, t_eval, args=params, atol=tol, rtol=tol,
                             max_steps=48)
        return jnp.mean(jnp.abs(sol.ys - target)), sol.stats

    grad_fn = jax.jit(jax.value_and_grad(loss_fn, has_aux=True))
    lr = 3e-2
    for _ in range(train_iters):
        (mae, stats), g = grad_fn(params)
        params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)

    # ---- measurement (forward pass, as in the paper's Table 4) ----
    fwd = jax.jit(lambda p: solve_ivp(f, y0, t_eval, args=p, atol=tol, rtol=tol,
                                      max_steps=256))
    sol = fwd(params)
    steps = float(np.mean(np.asarray(sol.stats["n_steps"])))
    n_f = float(np.asarray(sol.stats["n_f_evals"])[0])
    total, _ = timed(fwd, params)

    # model time: n_f chained dynamics evaluations in ONE jit program (timing
    # n_f separate dispatches would charge per-call overhead n_f times and
    # overestimate past the total solver time)
    n_f_int = int(n_f)

    def chained(p):
        def body(y, _):
            return f(jnp.zeros((batch,)), y, p), None

        y, _ = jax.lax.scan(body, y0, None, length=n_f_int)
        return y

    model_s, _ = timed(jax.jit(chained), params)

    jnt = jax.jit(lambda p: solve_joint(f, y0, t_eval, args=p, atol=tol, rtol=tol,
                                        max_steps=1024))
    sj = jnt(params)
    steps_j = float(np.asarray(sj.stats["n_steps"])[0])
    total_j, _ = timed(jnt, params)

    return {
        "mae": float(mae),
        "steps": steps,
        "loop_ms": 1e3 * max(total - model_s, 0.0) / steps,
        "total_per_step_ms": 1e3 * total / steps,
        "model_per_step_ms": 1e3 * model_s / steps,
        "joint_steps": steps_j,
        "joint_loop_ms": 1e3 * max(total_j - model_s, 0.0) / steps_j,
    }


def rows():
    r = run()
    # In the FEN setup the model dominates (paper: 10.1 of 11.9 ms/step); on
    # CPU the solver overhead can fall below model-timing noise, in which case
    # loop_time reports 0 and total/model per-step are the meaningful rows.
    note = "model-dominated; solver overhead < timing noise" if r["loop_ms"] == 0 else ""
    return [
        ("fen/parallel/loop_time", r["loop_ms"] * 1e3,
         f"steps={r['steps']:.1f} {note}".strip()),
        ("fen/parallel/total_per_step", r["total_per_step_ms"] * 1e3, ""),
        ("fen/parallel/model_per_step", r["model_per_step_ms"] * 1e3, ""),
        ("fen/joint/loop_time", r["joint_loop_ms"] * 1e3, f"steps={r['joint_steps']:.1f}"),
        ("fen/mae", r["mae"], "trained 15 iters"),
    ]


if __name__ == "__main__":
    for name, v, extra in rows():
        print(f"{name},{v},{extra}")
