"""Section 4.1 / Figure 1: within-batch interaction.

Stacked VdP oscillators with randomized phases: a joint solver's common step
size is ~the minimum over instances, inflating total steps up to 4x.  Our
parallel solver keeps per-instance steps constant as batch size grows.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solve_ivp

from .common import solve_joint
from .vdp_bench import vdp


def run(mu=25.0, tol=1e-5):
    t_end = 2.0 * mu  # roughly one cycle at high mu
    out = {}
    key = jax.random.PRNGKey(1)
    for batch in (1, 4, 16, 64):
        y0 = jnp.array([2.0, 0.0]) + 0.5 * jax.random.normal(key, (batch, 2))
        sp = solve_ivp(vdp, y0, None, t_start=0.0, t_end=t_end, args=mu,
                       atol=tol, rtol=tol, max_steps=30000)
        sj = solve_joint(vdp, y0, None, t_start=0.0, t_end=t_end, args=mu,
                         atol=tol, rtol=tol, max_steps=60000)
        par_steps = float(np.mean(np.asarray(sp.stats["n_steps"])))
        joint_steps = float(np.asarray(sj.stats["n_steps"])[0])
        out[batch] = dict(parallel=par_steps, joint=joint_steps,
                          ratio=joint_steps / par_steps)
    return out


def rows():
    r = run()
    out = []
    for batch, d in r.items():
        out.append((f"interaction/b{batch}/steps_parallel", d["parallel"], ""))
        out.append((f"interaction/b{batch}/steps_joint", d["joint"],
                    f"ratio={d['ratio']:.2f}"))
    return out


if __name__ == "__main__":
    for name, v, extra in rows():
        print(f"{name},{v:.1f},{extra}")
