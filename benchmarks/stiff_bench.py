"""Stiff suite: explicit vs implicit steppers on classic stiff problems.

Two problems where every instance of the batch is stiff -- the regime the
explicit-only solver could not touch (any stiff instance grinds at its
stability limit, the exact within-batch pathology the paper measures):

  robertson   the 3-species chemical kinetics IVP (rates spanning 9 orders
              of magnitude), t in [0, 100]
  vdp1000     Van der Pol with mu = 1000, t in [0, 20] (relaxation phase)

For each problem we run ``kvaerno5`` (SDIRK + batched masked Newton) and
``dopri5`` at the same tolerance and report wall time, accepted steps, Newton
iterations and Jacobian evaluations.  The explicit method gets a generous but
bounded step budget; when it hits the cap the step ratio reported is a lower
bound.

A third section benchmarks the FUSED diagonally-implicit step (factor-once
chord Newton, one launch per iteration) against the unfused op-per-op path on
the ``interpret`` kernel backend -- the launch-count proxy tier, same as
``step_bench`` -- on a stiff Allen-Cahn method-of-lines problem where the
per-iteration O(n^3) elimination the fused path removes actually dominates.
``--bars`` enforces the committed speedup floors.

``REPRO_STIFF_SMOKE=1`` shrinks batch/horizons/budgets for CI smoke runs.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solve_ivp
from repro.kernels import ops

from .common import timed, vdp


def robertson(t, y, args):
    y1, y2, y3 = y[..., 0], y[..., 1], y[..., 2]
    r1 = -0.04 * y1 + 1e4 * y2 * y3
    r3 = 3e7 * y2 * y2
    return jnp.stack((r1, -r1 - r3, r3), axis=-1)


def allen_cahn(t, y, args):
    """Stiff 1D Allen-Cahn semidiscretization (Dirichlet): lam*Lap(y) + y - y^3."""
    lam = args
    up = jnp.concatenate([y[..., 1:], jnp.zeros_like(y[..., :1])], axis=-1)
    dn = jnp.concatenate([jnp.zeros_like(y[..., :1]), y[..., :-1]], axis=-1)
    return lam * (up - 2.0 * y + dn) + y - y**3


# (method, batch, n_feat, t_end, speedup bar) for the fused-DIRK section.
# One point keeps the interpret-tier suite affordable; n_feat is large enough
# that the factored system, not launch bookkeeping, is the per-iteration cost.
FUSED_POINTS = (("kvaerno5", 4, 32, 1.0, 2.0),)


def _fused_dirk_rows(repeats=2):
    """Fused vs unfused DIRK steps/sec on the interpret (launch-proxy) backend.

    The suite normally runs under REPRO_KERNEL_BACKEND=ref in CI; this section
    pins the interpret backend itself (and restores the previous one) so the
    comparison always measures kernel launches, not the jnp oracle.
    """
    smoke = os.environ.get("REPRO_STIFF_SMOKE", "0") == "1"
    prev = ops.backend()
    out = []
    try:
        ops.set_backend("interpret")
        for method, batch, n_feat, t_end, bar in FUSED_POINTS:
            if not smoke:
                t_end *= 5.0
            lam = float((n_feat + 1) ** 2)
            x = jnp.linspace(0.0, 1.0, n_feat + 2)[1:-1]
            amps = 1.0 + 0.2 * jnp.arange(batch, dtype=jnp.float32)
            y0 = amps[:, None] * jnp.sin(jnp.pi * x)[None, :]

            per_sec = {}
            for fused in (False, True):
                fn = jax.jit(
                    lambda y, fused=fused: solve_ivp(
                        allen_cahn, y, None, t_start=0.0, t_end=t_end,
                        method=method, atol=1e-7, rtol=1e-4, args=lam,
                        max_steps=4000, fused=fused)
                )
                sol = fn(y0)
                assert bool(np.all(np.asarray(sol.status) == 0)), (
                    f"fused-DIRK bench solve failed: {np.asarray(sol.status)}")
                if fused:
                    assert "n_fused_steps" in sol.stats, (
                        "fused implicit path did not engage")
                total, _ = timed(fn, y0, repeats=repeats, reduce="min")
                n_loop = int(np.max(np.asarray(sol.stats["n_steps"])))
                label = "fused" if fused else "unfused"
                per_sec[label] = n_loop / total
                out.append((f"fused_dirk/{method}/{label}_steps_per_sec",
                            per_sec[label], f"{n_loop} loop steps, b={batch} f={n_feat}"))
            out.append((f"fused_dirk/{method}/fused_speedup",
                        per_sec["fused"] / per_sec["unfused"],
                        f"steps/sec ratio, fused over unfused (bar {bar}x)"))
    finally:
        ops.set_backend(prev)
    return out


def _solve(f, y0, t_end, method, max_steps, args=None, atol=1e-8, rtol=1e-5):
    fn = jax.jit(
        lambda y: solve_ivp(f, y, None, t_start=0.0, t_end=t_end, method=method,
                            atol=atol, rtol=rtol, args=args, max_steps=max_steps)
    )
    sol = fn(y0)
    total, _ = timed(fn, y0, repeats=2)
    stats = {k: np.asarray(v) for k, v in sol.stats.items()}
    return sol, stats, total


def _problem_rows(tag, f, y0, t_end, args, imp_steps, exp_steps):
    out = []
    isol, istats, itime = _solve(f, y0, t_end, "kvaerno5", imp_steps, args)
    esol, estats, etime = _solve(f, y0, t_end, "dopri5", exp_steps, args)
    i_acc = float(istats["n_accepted"].mean())
    e_acc = float(estats["n_accepted"].mean())
    i_done = bool(np.all(np.asarray(isol.status) == 0))
    e_done = bool(np.all(np.asarray(esol.status) == 0))
    out.append((f"{tag}/kvaerno5/total_time", itime * 1e6,
                f"acc={i_acc:.0f} newton={istats['n_newton_iters'].mean():.0f} "
                f"jac={istats['n_jac_evals'].mean():.0f} finished={i_done}"))
    out.append((f"{tag}/dopri5/total_time", etime * 1e6,
                f"acc={e_acc:.0f} finished={e_done}"))
    if not i_done:
        # A truncated implicit solve would make the headline ratio bogus:
        # report the failure itself instead of a flattering number.
        out.append((f"{tag}/IMPLICIT_SOLVE_FAILED", 1.0,
                    f"statuses={np.asarray(isol.status).tolist()}"))
        return out
    ratio = e_acc / max(i_acc, 1.0)
    out.append((f"{tag}/explicit_vs_implicit_step_ratio", ratio,
                "x more accepted steps when explicit"
                + ("" if e_done else " (lower bound: capped)")))
    return out


def rows():
    smoke = os.environ.get("REPRO_STIFF_SMOKE", "0") == "1"
    batch = 4 if smoke else 32
    key = jax.random.PRNGKey(0)

    out = []
    # Van der Pol mu=1000: relaxation-oscillation stiffness.
    y0 = jnp.array([2.0, 0.0]) + 0.05 * jax.random.normal(key, (batch, 2))
    t_end = 2.0 if smoke else 20.0
    exp_cap = 4000 if smoke else 200_000
    out += _problem_rows("vdp1000", vdp, y0, t_end, 1000.0,
                         imp_steps=20_000, exp_steps=exp_cap)

    # Robertson kinetics: rate constants spanning 9 orders of magnitude.
    ry0 = jnp.tile(jnp.array([[1.0, 0.0, 0.0]]), (batch, 1))
    rt_end = 1.0 if smoke else 100.0
    rexp_cap = 4000 if smoke else 50_000
    out += _problem_rows("robertson", robertson, ry0, rt_end, None,
                         imp_steps=20_000, exp_steps=rexp_cap)

    # Fused-DIRK launch-proxy comparison (pins its own backend).
    out += _fused_dirk_rows()
    return out


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_stiff.json", default=None,
                        metavar="PATH")
    parser.add_argument("--bars", action="store_true",
                        help="fail if any fused_speedup row misses its floor "
                             "(use when refreshing the committed baseline)")
    opts = parser.parse_args()

    bars = {f"fused_dirk/{p[0]}/fused_speedup": p[4] for p in FUSED_POINTS}
    records = []
    missed = []
    print("name,value,derived")
    for name, v, extra in rows():
        print(f"stiff/{name},{v},{extra}", flush=True)
        records.append({"suite": "stiff", "name": name, "value": v, "derived": extra})
        if opts.bars and name in bars and v < bars[name]:
            missed.append(f"{name}: {v:.3f}x < bar {bars[name]}x")

    if opts.json:
        from .common import calibration_us

        payload = {"bench": "stiff", "unit": "us for *_time rows",
                   "calibration_us": calibration_us(), "rows": records}
        with open(opts.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(records)} rows to {opts.json}")

    if missed:
        raise SystemExit("speedup below bar:\n  " + "\n  ".join(missed))


if __name__ == "__main__":
    main()
