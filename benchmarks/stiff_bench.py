"""Stiff suite: explicit vs implicit steppers on classic stiff problems.

Two problems where every instance of the batch is stiff -- the regime the
explicit-only solver could not touch (any stiff instance grinds at its
stability limit, the exact within-batch pathology the paper measures):

  robertson   the 3-species chemical kinetics IVP (rates spanning 9 orders
              of magnitude), t in [0, 100]
  vdp1000     Van der Pol with mu = 1000, t in [0, 20] (relaxation phase)

For each problem we run ``kvaerno5`` (SDIRK + batched masked Newton) and
``dopri5`` at the same tolerance and report wall time, accepted steps, Newton
iterations and Jacobian evaluations.  The explicit method gets a generous but
bounded step budget; when it hits the cap the step ratio reported is a lower
bound.

``REPRO_STIFF_SMOKE=1`` shrinks batch/horizons/budgets for CI smoke runs.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solve_ivp

from .common import timed, vdp


def robertson(t, y, args):
    y1, y2, y3 = y[..., 0], y[..., 1], y[..., 2]
    r1 = -0.04 * y1 + 1e4 * y2 * y3
    r3 = 3e7 * y2 * y2
    return jnp.stack((r1, -r1 - r3, r3), axis=-1)


def _solve(f, y0, t_end, method, max_steps, args=None, atol=1e-8, rtol=1e-5):
    fn = jax.jit(
        lambda y: solve_ivp(f, y, None, t_start=0.0, t_end=t_end, method=method,
                            atol=atol, rtol=rtol, args=args, max_steps=max_steps)
    )
    sol = fn(y0)
    total, _ = timed(fn, y0, repeats=2)
    stats = {k: np.asarray(v) for k, v in sol.stats.items()}
    return sol, stats, total


def _problem_rows(tag, f, y0, t_end, args, imp_steps, exp_steps):
    out = []
    isol, istats, itime = _solve(f, y0, t_end, "kvaerno5", imp_steps, args)
    esol, estats, etime = _solve(f, y0, t_end, "dopri5", exp_steps, args)
    i_acc = float(istats["n_accepted"].mean())
    e_acc = float(estats["n_accepted"].mean())
    i_done = bool(np.all(np.asarray(isol.status) == 0))
    e_done = bool(np.all(np.asarray(esol.status) == 0))
    out.append((f"{tag}/kvaerno5/total_time", itime * 1e6,
                f"acc={i_acc:.0f} newton={istats['n_newton_iters'].mean():.0f} "
                f"jac={istats['n_jac_evals'].mean():.0f} finished={i_done}"))
    out.append((f"{tag}/dopri5/total_time", etime * 1e6,
                f"acc={e_acc:.0f} finished={e_done}"))
    if not i_done:
        # A truncated implicit solve would make the headline ratio bogus:
        # report the failure itself instead of a flattering number.
        out.append((f"{tag}/IMPLICIT_SOLVE_FAILED", 1.0,
                    f"statuses={np.asarray(isol.status).tolist()}"))
        return out
    ratio = e_acc / max(i_acc, 1.0)
    out.append((f"{tag}/explicit_vs_implicit_step_ratio", ratio,
                "x more accepted steps when explicit"
                + ("" if e_done else " (lower bound: capped)")))
    return out


def rows():
    smoke = os.environ.get("REPRO_STIFF_SMOKE", "0") == "1"
    batch = 4 if smoke else 32
    key = jax.random.PRNGKey(0)

    out = []
    # Van der Pol mu=1000: relaxation-oscillation stiffness.
    y0 = jnp.array([2.0, 0.0]) + 0.05 * jax.random.normal(key, (batch, 2))
    t_end = 2.0 if smoke else 20.0
    exp_cap = 4000 if smoke else 200_000
    out += _problem_rows("vdp1000", vdp, y0, t_end, 1000.0,
                         imp_steps=20_000, exp_steps=exp_cap)

    # Robertson kinetics: rate constants spanning 9 orders of magnitude.
    ry0 = jnp.tile(jnp.array([[1.0, 0.0, 0.0]]), (batch, 1))
    rt_end = 1.0 if smoke else 100.0
    rexp_cap = 4000 if smoke else 50_000
    out += _problem_rows("robertson", robertson, ry0, rt_end, None,
                         imp_steps=20_000, exp_steps=rexp_cap)
    return out


if __name__ == "__main__":
    for name, v, extra in rows():
        print(f"{name},{v:.1f},{extra}")
