"""Table 3: VdP loop-time benchmark.

Paper setup: batch of 256 VdP problems, one cycle, mu=2, atol=rtol=1e-5,
200 evenly spaced evaluation points, dopri5.  We compare:

  parallel        our batch-parallel solver (per-instance state)
  parallel-nodense same but final-state-only (no eval tracking)
  joint           torchdiffeq-style single joint instance (shared step size)

Loop time = solver wall time / mean steps.  (CPU-host numbers; relative
ordering is the reproducible claim, see EXPERIMENTS.md.)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solve_ivp

from .common import solve_joint, timed, vdp


def run(batch=256, mu=2.0, n_eval=200, tol=1e-5):
    key = jax.random.PRNGKey(0)
    y0 = jnp.array([2.0, 0.0]) + 0.1 * jax.random.normal(key, (batch, 2))
    t_cycle = (3.0 - 2.0 * np.log(2.0)) * mu + 2 * np.pi / mu**(1 / 3)  # ~ one cycle
    t_eval = jnp.linspace(0.0, float(t_cycle), n_eval)

    results = {}

    par = jax.jit(lambda y: solve_ivp(vdp, y, t_eval, method="dopri5",
                                      atol=tol, rtol=tol, args=mu, max_steps=2000))
    sol = par(y0)
    steps = float(np.mean(np.asarray(sol.stats["n_steps"])))
    total, std = timed(par, y0)
    results["parallel"] = dict(total_s=total, steps=steps, loop_ms=1e3 * total / steps)

    par_w = jax.jit(lambda y: solve_ivp(vdp, y, t_eval, method="dopri5",
                                        atol=tol, rtol=tol, args=mu, max_steps=2000,
                                        dense_window=8))
    solw = par_w(y0)
    steps_w = float(np.mean(np.asarray(solw.stats["n_steps"])))
    total_w, _ = timed(par_w, y0)
    results["parallel-windowed"] = dict(total_s=total_w, steps=steps_w,
                                        loop_ms=1e3 * total_w / steps_w)

    par_nd = jax.jit(lambda y: solve_ivp(vdp, y, None, t_start=0.0, t_end=float(t_cycle),
                                         method="dopri5", atol=tol, rtol=tol,
                                         args=mu, max_steps=2000))
    soln = par_nd(y0)
    steps_nd = float(np.mean(np.asarray(soln.stats["n_steps"])))
    total_nd, _ = timed(par_nd, y0)
    results["parallel-nodense"] = dict(total_s=total_nd, steps=steps_nd,
                                       loop_ms=1e3 * total_nd / steps_nd)

    joint = jax.jit(lambda y: solve_joint(vdp, y, t_eval, method="dopri5",
                                          atol=tol, rtol=tol, args=mu, max_steps=4000))
    solj = joint(y0)
    steps_j = float(np.asarray(solj.stats["n_steps"])[0])
    total_j, _ = timed(joint, y0)
    results["joint"] = dict(total_s=total_j, steps=steps_j, loop_ms=1e3 * total_j / steps_j)

    return results


def rows():
    r = run()
    out = []
    for name, d in r.items():
        out.append((f"vdp/{name}/loop_time", d["loop_ms"] * 1e3,
                    f"steps={d['steps']:.0f}"))
    out.append(("vdp/joint_vs_parallel_step_ratio",
                r["joint"]["steps"] / r["parallel"]["steps"], "x more steps when joint"))
    return out


if __name__ == "__main__":
    for name, us, extra in rows():
        print(f"{name},{us:.1f},{extra}")
