"""Table 5: CNF benchmark (optimize-then-discretize / adjoint).

FFJORD-style continuous normalizing flow with exact trace (2-D data), trained
via the adjoint equation.  Reproduces the paper's comparison:

  - forward loop time (parallel solver)
  - backward loop time, PER-INSTANCE adjoint (torchode default: b(2f+p) vars,
    slow -- the paper's 58 ms pathology)
  - backward loop time, JOINT adjoint (torchode-joint: 2bf+p vars, fast)
  - NLL (the bits/dim analogue for 2-D synthetic data)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solve_ivp
from repro.core.adjoint import adjoint_backsolve_problem, make_adjoint_solve

from .common import timed


def init_mlp(key, dim=2, hidden=64):
    ks = jax.random.split(key, 3)
    s = lambda k, sh: jax.random.normal(k, sh) / np.sqrt(sh[0])
    return {"w1": s(ks[0], (dim + 1, hidden)), "w2": s(ks[1], (hidden, hidden)),
            "w3": s(ks[2], (hidden, dim))}


def vf(t, x, params):
    """Plain velocity field f(t, x): (b, dim) -> (b, dim)."""
    tcol = jnp.broadcast_to(t[:, None], (x.shape[0], 1))
    h = jnp.concatenate([x, tcol], -1)
    h = jnp.tanh(h @ params["w1"])
    h = jnp.tanh(h @ params["w2"])
    return h @ params["w3"]


def aug_dynamics(t, y, params):
    """Augmented CNF state [x (dim), logdet (1)]; exact trace via jacfwd."""
    x = y[:, :-1]
    dim = x.shape[1]

    def fx(xi, ti):
        return vf(ti[None], xi[None], params)[0]

    def one(xi, ti):
        J = jax.jacfwd(fx)(xi, ti)
        return jnp.trace(J)

    dx = vf(t, x, params)
    div = jax.vmap(one)(x, t)
    return jnp.concatenate([dx, -div[:, None]], axis=-1)


def two_moons(key, n):
    k1, k2, k3 = jax.random.split(key, 3)
    th = jax.random.uniform(k1, (n,)) * np.pi
    top = jax.random.bernoulli(k2, 0.5, (n,))
    x = jnp.where(top, jnp.cos(th), 1 - jnp.cos(th))
    y = jnp.where(top, jnp.sin(th) - 0.25, -jnp.sin(th) + 0.25)
    pts = jnp.stack([x, y], -1) + 0.05 * jax.random.normal(k3, (n, 2))
    return pts


def nll_loss(params, x, solve):
    b, dim = x.shape
    y0 = jnp.concatenate([x, jnp.zeros((b, 1))], -1)
    y1 = solve(y0, 0.0, 1.0, params)
    z, logdet = y1[:, :-1], y1[:, -1]
    logp_z = -0.5 * jnp.sum(z**2, -1) - 0.5 * dim * np.log(2 * np.pi)
    return -jnp.mean(logp_z + logdet)


def clip_tree(g, max_norm=1.0):
    gn = jnp.sqrt(sum(jnp.sum(x**2) for x in jax.tree_util.tree_leaves(g)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda x: x * scale, g)


def run(batch=256, train_iters=30, tol=1e-4):
    key = jax.random.PRNGKey(0)
    params = init_mlp(key)
    x = two_moons(key, batch)

    solve_joint_adj = make_adjoint_solve(aug_dynamics, mode="joint", rtol=tol, atol=tol)
    loss_grad = jax.jit(jax.value_and_grad(lambda p: nll_loss(p, x, solve_joint_adj)))
    lr = 1e-2
    m = jax.tree.map(jnp.zeros_like, params)
    for i in range(train_iters):
        nll, g = loss_grad(params)
        g = clip_tree(g)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
        params = jax.tree.map(lambda p, mm: p - lr * mm, params, m)
    nll_final = float(nll)

    # ---- forward loop time ----
    y0 = jnp.concatenate([x, jnp.zeros((batch, 1))], -1)
    fwd = jax.jit(lambda p: solve_ivp(aug_dynamics, y0, None, t_start=0.0, t_end=1.0,
                                      args=p, atol=tol, rtol=tol, max_steps=512))
    sol = fwd(params)
    fw_steps = float(np.mean(np.asarray(sol.stats["n_steps"])))
    t_fw, _ = timed(fwd, params)

    # ---- backward loop time: solve the augmented adjoint IVP directly ----
    y1 = sol.ys
    g = jnp.ones_like(y1)
    results = {"fw_steps": fw_steps, "fw_loop_ms": 1e3 * t_fw / fw_steps,
               "nll": nll_final}
    for mode, tag in (("joint", "bw_joint"), ("per_instance", "bw_per_instance")):
        dyn, aug0, ts, te = adjoint_backsolve_problem(
            aug_dynamics, y1, g, jnp.zeros((batch,)), jnp.ones((batch,)), params,
            mode=mode)
        bwd = jax.jit(lambda a0: solve_ivp(dyn, a0, None, t_start=ts, t_end=te,
                                           atol=tol, rtol=tol, max_steps=512))
        sb = bwd(aug0)
        steps = float(np.mean(np.asarray(sb.stats["n_steps"])))
        t_bw, _ = timed(bwd, aug0)
        results[f"{tag}_steps"] = steps
        results[f"{tag}_loop_ms"] = 1e3 * t_bw / steps
    return results


def rows():
    r = run()
    return [
        ("cnf/fw/loop_time", r["fw_loop_ms"] * 1e3, f"steps={r['fw_steps']:.1f}"),
        ("cnf/bw_joint/loop_time", r["bw_joint_loop_ms"] * 1e3,
         f"steps={r['bw_joint_steps']:.1f}"),
        ("cnf/bw_per_instance/loop_time", r["bw_per_instance_loop_ms"] * 1e3,
         f"steps={r['bw_per_instance_steps']:.1f}"),
        ("cnf/nll", r["nll"], "trained 30 iters, 2D two-moons"),
    ]


if __name__ == "__main__":
    for name, v, extra in rows():
        print(f"{name},{v},{extra}")
