"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [table3|table4|table5|fig1|fig2|all]

Prints ``name,value,derived`` CSV rows (value is microseconds for *_time rows).
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    suites = []
    if which in ("all", "table3"):
        from . import vdp_bench

        suites.append(("table3_vdp", vdp_bench.rows))
    if which in ("all", "fig1"):
        from . import interaction_bench

        suites.append(("fig1_interaction", interaction_bench.rows))
    if which in ("all", "table4"):
        from . import fen_bench

        suites.append(("table4_fen", fen_bench.rows))
    if which in ("all", "table5"):
        from . import cnf_bench

        suites.append(("table5_cnf", cnf_bench.rows))
    if which in ("all", "fig2"):
        from . import pid_bench

        suites.append(("fig2_pid", pid_bench.rows))

    print("name,value,derived")
    for tag, fn in suites:
        t0 = time.time()
        for name, v, extra in fn():
            print(f"{tag}/{name},{v},{extra}", flush=True)
        print(f"# {tag} took {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
