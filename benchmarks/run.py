"""Benchmark harness: one module per paper table/figure.

  python -m benchmarks.run [table3|table4|table5|fig1|fig2|stiff|events|dispatch|serving|training|all] [--json [PATH]]

Prints ``name,value,derived`` CSV rows (value is microseconds for *_time
rows).  ``--json`` additionally writes the rows to a JSON file so CI can
track the perf trajectory across commits; without an explicit PATH each
suite writes its own default (``BENCH_<suite>.json``, e.g. stiff ->
``BENCH_stiff.json``; ``all``/``table3`` keep the historical
``BENCH_solver.json``), so running several suites in one workspace never
silently overwrites another suite's artifact.  ``benchmarks/compare.py``
diffs these files against the committed baselines and gates CI on
regressions.
"""

from __future__ import annotations

import argparse
import json
import time

_SUITE_CHOICES = ["all", "table3", "table4", "table5", "fig1", "fig2",
                  "stiff", "events", "dispatch", "serving", "training", "step"]

# Suite-named --json defaults; "all" and the historical headline suite keep
# the BENCH_solver.json name CI has tracked since PR 1.
_DEFAULT_JSON = {suite: f"BENCH_{suite}.json" for suite in _SUITE_CHOICES}
_DEFAULT_JSON["all"] = "BENCH_solver.json"
_DEFAULT_JSON["table3"] = "BENCH_solver.json"

_JSON_AUTO = "__suite_default__"


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("suite", nargs="?", default="all", choices=_SUITE_CHOICES)
    parser.add_argument("--json", nargs="?", const=_JSON_AUTO, default=None,
                        metavar="PATH",
                        help="also write rows to a JSON file (default: "
                             "BENCH_<suite>.json)")
    opts = parser.parse_args()
    which = opts.suite
    json_path = _DEFAULT_JSON[which] if opts.json == _JSON_AUTO else opts.json

    suites = []
    if which in ("all", "table3"):
        from . import vdp_bench

        suites.append(("table3_vdp", vdp_bench.rows))
    if which in ("all", "fig1"):
        from . import interaction_bench

        suites.append(("fig1_interaction", interaction_bench.rows))
    if which in ("all", "table4"):
        from . import fen_bench

        suites.append(("table4_fen", fen_bench.rows))
    if which in ("all", "table5"):
        from . import cnf_bench

        suites.append(("table5_cnf", cnf_bench.rows))
    if which in ("all", "fig2"):
        from . import pid_bench

        suites.append(("fig2_pid", pid_bench.rows))
    if which in ("all", "events"):
        from . import events_bench

        suites.append(("events", events_bench.rows))
    if which == "dispatch":
        # Not part of "all": the eager-retrace baseline is deliberately slow
        # (it re-traces the whole loop program every call).  CI runs it via
        # ``python -m benchmarks.dispatch_bench --json``.
        from . import dispatch_bench

        suites.append(("dispatch", dispatch_bench.rows))
    if which == "serving":
        # Not part of "all": the per-request eager-jit baseline dispatches
        # hundreds of b=1 solves by design.
        from . import serving_bench

        suites.append(("serving", serving_bench.rows))
    if which == "training":
        # Not part of "all" for the same reason: the per-request jit(grad)
        # baseline dispatches hundreds of b=1 backward solves by design.
        from . import training_bench

        suites.append(("training", training_bench.rows))
    if which == "step":
        # Not part of "all": compares the fused step megakernel against the
        # unfused op-per-op path across backends; the interpret-backend rows
        # are launch-count proxies and take a while.
        from . import step_bench

        suites.append(("step", step_bench.rows))
    if which == "stiff":
        # Not part of "all": the explicit-solver baselines grind at their
        # stability limit by design (200k-step budgets).  Run explicitly, or
        # at reduced size with REPRO_STIFF_SMOKE=1.
        from . import stiff_bench

        suites.append(("stiff", stiff_bench.rows))

    records = []
    print("name,value,derived")
    for tag, fn in suites:
        t0 = time.time()
        for name, v, extra in fn():
            print(f"{tag}/{name},{v},{extra}", flush=True)
            records.append({"suite": tag, "name": name, "value": v, "derived": extra})
        elapsed = time.time() - t0
        print(f"# {tag} took {elapsed:.1f}s", flush=True)
        records.append({"suite": tag, "name": "_suite_wall_s", "value": elapsed,
                        "derived": ""})

    if json_path:
        from .common import calibration_us

        # Machine-speed fingerprint: lets compare.py normalize this payload
        # against a baseline recorded on different hardware (--normalize).
        payload = {"bench": which, "unit": "us for *_time rows",
                   "calibration_us": calibration_us(), "rows": records}
        with open(json_path, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(records)} rows to {json_path}", flush=True)


if __name__ == "__main__":
    main()
