"""Event-handling micro-benchmark: detection overhead + localization cost.

Two measurements on a batch of free-fall ("bouncing ball") instances with
per-instance drop heights:

  overhead    a NON-terminal marker event rides along a Van der Pol solve --
              the trajectory and step sequence are unchanged (asserted via
              n_f_evals), so the delta over the plain solve is the pure cost
              of per-step condition evaluation + (rare) bisection.
  terminal    a terminal ground event stops every instance at its own impact
              time; reports wall time and the worst per-instance deviation
              from the analytic impact time (the localization accuracy the
              acceptance bar holds at 10*rtol).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Event, Status, solve_ivp

from .common import timed, vdp

G = 9.81
BATCH = 256
RTOL, ATOL = 1e-6, 1e-9


def ball(t, y, args):
    return jnp.stack((y[..., 1], jnp.full_like(y[..., 1], -G)), axis=-1)


def rows():
    key = jax.random.PRNGKey(0)
    out = []

    # --- overhead of a marker event on a solve it never terminates ---
    y0 = jnp.array([2.0, 0.0]) + 0.05 * jax.random.normal(key, (BATCH, 2))
    marker = Event(lambda t, y, args: y[0], terminal=False)
    plain_fn = jax.jit(lambda y: solve_ivp(vdp, y, None, t_start=0.0, t_end=5.0,
                                           args=10.0, rtol=RTOL, atol=ATOL))
    ev_fn = jax.jit(lambda y: solve_ivp(vdp, y, None, t_start=0.0, t_end=5.0,
                                        args=10.0, rtol=RTOL, atol=ATOL,
                                        events=marker))
    plain, ev = plain_fn(y0), ev_fn(y0)
    same_steps = bool(
        np.array_equal(np.asarray(plain.stats["n_f_evals"]),
                       np.asarray(ev.stats["n_f_evals"]))
    )
    t_plain, _ = timed(plain_fn, y0)
    t_ev, _ = timed(ev_fn, y0)
    out.append(("vdp_plain/total_time", t_plain * 1e6, f"batch={BATCH}"))
    out.append(("vdp_marker_event/total_time", t_ev * 1e6,
                f"overhead={100.0 * (t_ev / t_plain - 1.0):.1f}% "
                f"zero_extra_vf_evals={same_steps}"))

    # --- terminal localization: batch of balls, per-instance impact times ---
    h0 = np.linspace(1.0, 50.0, BATCH)
    yb = jnp.asarray(np.stack([h0, np.zeros_like(h0)], 1), jnp.float32)
    ground = Event(lambda t, y, args: y[0], terminal=True, direction=-1.0)
    term_fn = jax.jit(lambda y: solve_ivp(ball, y, None, t_start=0.0, t_end=10.0,
                                          events=ground, rtol=RTOL, atol=ATOL))
    sol = term_fn(yb)
    all_fired = bool(np.all(np.asarray(sol.status) == Status.EVENT.value))
    err = float(np.abs(np.asarray(sol.event_t)[:, 0] - np.sqrt(2.0 * h0 / G)).max())
    t_term, _ = timed(term_fn, yb)
    out.append(("ball_terminal/total_time", t_term * 1e6,
                f"batch={BATCH} all_fired={all_fired} max_t_err={err:.2e}"))
    return out


if __name__ == "__main__":
    for name, v, extra in rows():
        print(f"{name},{v:.1f},{extra}")
