"""Serving-throughput benchmark: coalesced buckets vs per-request dispatch.

The workload is the ROADMAP's serving regime: a steady stream of
single-instance solve requests with *mixed shapes* (feature sizes drawn from
a small set, per-request spans and tolerances), where the integration itself
is microseconds and dispatch + batching policy decide the throughput.  Two
ways to serve the identical stream:

  per_request  the naive baseline: each request solved alone, b=1, through a
               per-shape ``jax.jit`` program (warmed before timing -- this
               baseline pays Python dispatch per request, NOT retracing;
               the retrace disaster is ``dispatch_bench``'s subject).
  service      ``SolveService``: requests coalesced into power-of-two padded
               buckets executed through prewarmed ``CompiledSolver``
               programs, sliced back into per-request solutions.

Reports steady-state solves/sec for both, the speedup (acceptance bar:
>= 5x on CPU at max_batch=16), and the service's pad-waste fraction.

Usage: python -m benchmarks.serving_bench [--json [PATH]] [--requests N]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AutoDiffAdjoint,
    SolveRequest,
    SolveService,
    Stepper,
)

FEATURES = (2, 4)
MAX_BATCH = 16
T1 = 1.0


def _decay(t, y, args):
    return -y * args


def _stream(n: int, seed: int = 0) -> list[SolveRequest]:
    """A reproducible mixed-shape request stream (round-robin features, so
    both paths see the identical request sequence)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        feat = FEATURES[i % len(FEATURES)]
        reqs.append(SolveRequest(
            f=_decay,
            y0=jnp.asarray(rng.uniform(0.5, 1.5, (feat,)), jnp.float32),
            t0=0.0,
            t1=float(rng.uniform(0.8, 1.2)),
            args=jnp.asarray(rng.uniform(0.5, 2.0, (feat,)), jnp.float32),
            rtol=float(rng.choice([1e-3, 1e-4])),
        ))
    return reqs


def _per_request(reqs) -> float:
    """Solves/sec serving each request alone at b=1 through jit."""

    @jax.jit
    def jitted(drv, y0, t0, t1, args):
        return drv.solve(_decay, y0, None, t_start=t0, t_end=t1, args=args)

    def run(req):
        # The driver crosses jit as an ordinary argument: its per-request
        # tolerance leaves are dynamic, so the program still compiles once
        # per feature shape, not once per tolerance value.
        drv = AutoDiffAdjoint(Stepper("dopri5"),
                              rtol=jnp.asarray([req.rtol], jnp.float32),
                              atol=jnp.asarray([1e-6], jnp.float32))
        return jitted(drv, req.y0[None],
                      jnp.asarray([req.t0], jnp.float32),
                      jnp.asarray([req.t1], jnp.float32), req.args[None])

    # Warm both feature-shape programs, then time the stream.
    for req in reqs[: 2 * len(FEATURES)]:
        jax.block_until_ready(run(req).ys)
    t0 = time.perf_counter()
    for req in reqs:
        jax.block_until_ready(run(req).ys)
    return len(reqs) / (time.perf_counter() - t0)


def _service(reqs) -> tuple[float, dict]:
    """Solves/sec through the coalescing service (prewarmed, steady state)."""
    svc = SolveService(max_batch=MAX_BATCH, max_delay=None,
                       default_method="dopri5")
    for feat in FEATURES:
        svc.prewarm(SolveRequest(
            f=_decay, y0=jnp.ones((feat,), jnp.float32), t0=0.0, t1=T1,
            args=jnp.ones((feat,), jnp.float32), rtol=1e-3,
        ), batch_classes=[MAX_BATCH])
    # One warm lap outside the timed window (mirrors the baseline's warmup).
    for req in reqs[: 2 * MAX_BATCH]:
        svc.submit(req)
    svc.flush()
    t0 = time.perf_counter()
    futures = [svc.submit(req) for req in reqs]
    svc.flush()
    for fut in futures:
        fut.result(flush=False)
    rate = len(reqs) / (time.perf_counter() - t0)
    return rate, svc.stats()


def rows(requests: int = 512):
    reqs = _stream(requests)
    r_naive = _per_request(reqs)
    r_svc, stats = _service(reqs)
    speedup = r_svc / r_naive
    mix = f"b<=16 f={'/'.join(map(str, FEATURES))} dopri5"
    return [
        ("per_request/solves_per_sec", r_naive, f"{mix} per-request jit b=1"),
        ("service/solves_per_sec", r_svc,
         f"{mix} prewarmed speedup_vs_per_request={speedup:.1f}x"),
        ("service/speedup_vs_per_request", speedup,
         "acceptance bar: >= 5x on CPU"),
        ("service/pad_waste", stats["pad_waste"],
         f"pad rows fraction over {stats['n_batches']} batches"),
        ("service/cache_hit_rate",
         stats["cache_hits"] / max(1, stats["cache_hits"] + stats["cache_misses"]),
         f"hits={stats['cache_hits']} misses={stats['cache_misses']}"),
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_serving.json", default=None,
                        metavar="PATH", help="also write rows to a JSON file")
    parser.add_argument("--requests", type=int, default=512,
                        help="timed requests in the stream")
    opts = parser.parse_args()

    records = []
    print("name,value,derived")
    t0 = time.time()
    for name, v, extra in rows(opts.requests):
        print(f"serving/{name},{v:.4f},{extra}", flush=True)
        records.append({"suite": "serving", "name": name, "value": v,
                        "derived": extra})
    records.append({"suite": "serving", "name": "_suite_wall_s",
                    "value": time.time() - t0, "derived": ""})

    if opts.json:
        payload = {"bench": "serving", "unit": "solves/sec", "rows": records}
        with open(opts.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(records)} rows to {opts.json}", flush=True)


if __name__ == "__main__":
    main()
