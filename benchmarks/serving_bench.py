"""Serving-throughput benchmark: coalesced buckets vs per-request dispatch.

The workload is the ROADMAP's serving regime: a steady stream of
single-instance solve requests with *mixed shapes* (feature sizes drawn from
a small set, per-request spans and tolerances), where the integration itself
is microseconds and dispatch + batching policy decide the throughput.  Two
ways to serve the identical stream:

  per_request  the naive baseline: each request solved alone, b=1, through a
               per-shape ``jax.jit`` program (warmed before timing -- this
               baseline pays Python dispatch per request, NOT retracing;
               the retrace disaster is ``dispatch_bench``'s subject).
  service      ``SolveService``: requests coalesced into power-of-two padded
               buckets executed through prewarmed ``CompiledSolver``
               programs, sliced back into per-request solutions.

Reports steady-state solves/sec for both, the speedup (acceptance bar:
>= 5x on CPU at max_batch=16), and the service's pad-waste fraction.

Two further suites cover the async engine:

  async_overlap  the identical pack-bound stream served blocking
                 (``max_inflight=0``: launch + harvest inline, the pre-async
                 service) vs async (``max_inflight=4``): non-blocking
                 dispatch overlaps host packing of bucket N+1 with device
                 execution of bucket N.  Acceptance bar: >= 1.3x solves/sec
                 at max_batch=16.
  ragged_shard   ``sharded_solve`` on a batch that does not divide the mesh
                 (padded per shard) -- the serve-time uneven-shard path.

Usage: python -m benchmarks.serving_bench [--json [PATH]] [--requests N]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AutoDiffAdjoint,
    SolveRequest,
    SolveService,
    Stepper,
)

FEATURES = (2, 4)
MAX_BATCH = 16
T1 = 1.0


def _decay(t, y, args):
    return -y * args


def _stream(n: int, seed: int = 0) -> list[SolveRequest]:
    """A reproducible mixed-shape request stream (round-robin features, so
    both paths see the identical request sequence)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        feat = FEATURES[i % len(FEATURES)]
        reqs.append(SolveRequest(
            f=_decay,
            y0=jnp.asarray(rng.uniform(0.5, 1.5, (feat,)), jnp.float32),
            t0=0.0,
            t1=float(rng.uniform(0.8, 1.2)),
            args=jnp.asarray(rng.uniform(0.5, 2.0, (feat,)), jnp.float32),
            rtol=float(rng.choice([1e-3, 1e-4])),
        ))
    return reqs


def _per_request(reqs) -> float:
    """Solves/sec serving each request alone at b=1 through jit."""

    @jax.jit
    def jitted(drv, y0, t0, t1, args):
        return drv.solve(_decay, y0, None, t_start=t0, t_end=t1, args=args)

    def run(req):
        # The driver crosses jit as an ordinary argument: its per-request
        # tolerance leaves are dynamic, so the program still compiles once
        # per feature shape, not once per tolerance value.
        drv = AutoDiffAdjoint(Stepper("dopri5"),
                              rtol=jnp.asarray([req.rtol], jnp.float32),
                              atol=jnp.asarray([1e-6], jnp.float32))
        return jitted(drv, req.y0[None],
                      jnp.asarray([req.t0], jnp.float32),
                      jnp.asarray([req.t1], jnp.float32), req.args[None])

    # Warm both feature-shape programs, then time the stream (best of 2
    # laps: the gate compares absolute rates, so per-lap scheduler noise
    # must not leak into the committed baseline).
    for req in reqs[: 2 * len(FEATURES)]:
        jax.block_until_ready(run(req).ys)
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        for req in reqs:
            jax.block_until_ready(run(req).ys)
        best = min(best, time.perf_counter() - t0)
    return len(reqs) / best


def _service(reqs, *, features=FEATURES, max_inflight=4) -> tuple[float, dict]:
    """Solves/sec through the coalescing service (prewarmed, steady state).

    ``max_inflight=0`` is the blocking pre-async service (harvest inline);
    any other value runs the non-blocking pipeline."""
    svc = SolveService(max_batch=MAX_BATCH, max_delay=None,
                       default_method="dopri5", max_inflight=max_inflight)
    for feat in features:
        svc.prewarm(SolveRequest(
            f=_decay, y0=jnp.ones((feat,), jnp.float32), t0=0.0, t1=T1,
            args=jnp.ones((feat,), jnp.float32), rtol=1e-3,
        ), batch_classes=[MAX_BATCH])
    # One warm lap outside the timed window (mirrors the baseline's warmup),
    # then best of 3 timed laps over the same stream.
    for req in reqs[: 2 * MAX_BATCH]:
        svc.submit(req)
    svc.flush()
    svc.drain()
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        futures = [svc.submit(req) for req in reqs]
        svc.flush()
        svc.drain()
        for fut in futures:
            fut.result(flush=False)
        best = min(best, time.perf_counter() - t0)
    return len(reqs) / best, svc.stats()


ASYNC_FEATURES = (48, 64)
ASYNC_RTOL = 1e-6
ASYNC_SPAN = (2.0, 4.0)


def _async_stream(n: int, seed: int = 1) -> list[SolveRequest]:
    """The overlap suite's stream: same mixed-shape round-robin, but sized so
    host packing and device execution are comparable -- the regime where
    overlapping them pays (pure pack-bound or pure device-bound streams have
    nothing to hide)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        feat = ASYNC_FEATURES[i % len(ASYNC_FEATURES)]
        reqs.append(SolveRequest(
            f=_decay,
            y0=jnp.asarray(rng.uniform(0.5, 1.5, (feat,)), jnp.float32),
            t0=0.0,
            t1=float(rng.uniform(*ASYNC_SPAN)),
            args=jnp.asarray(rng.uniform(0.5, 2.0, (feat,)), jnp.float32),
            rtol=ASYNC_RTOL,
        ))
    return reqs


def _overlap_service(reqs, max_inflight):
    svc = SolveService(max_batch=MAX_BATCH, max_delay=None,
                       default_method="dopri5", max_inflight=max_inflight)
    for feat in ASYNC_FEATURES:
        svc.prewarm(SolveRequest(
            f=_decay, y0=jnp.ones((feat,), jnp.float32), t0=0.0, t1=T1,
            args=jnp.ones((feat,), jnp.float32), rtol=1e-3,
        ), batch_classes=[MAX_BATCH])
    for req in reqs[: 2 * MAX_BATCH]:
        svc.submit(req)
    svc.flush()
    svc.drain()

    def lap() -> float:
        t0 = time.perf_counter()
        futures = [svc.submit(req) for req in reqs]
        svc.flush()
        svc.drain()
        for fut in futures:
            fut.result(flush=False)
        return time.perf_counter() - t0

    return svc, lap


def _async_overlap_rows(requests: int):
    """Blocking vs async on the identical stream, laps *interleaved*
    (B A B A ...) so machine-load drift hits both modes equally and the
    speedup ratio stays meaningful on noisy shared hosts; each mode reports
    its best lap."""
    mix = f"b<=16 f={'/'.join(map(str, ASYNC_FEATURES))} dopri5"
    reqs = _async_stream(requests)
    svc_block, lap_block = _overlap_service(reqs, max_inflight=0)
    svc_async, lap_async = _overlap_service(reqs, max_inflight=4)
    t_block, t_async = float("inf"), float("inf")
    for _ in range(3):
        t_block = min(t_block, lap_block())
        t_async = min(t_async, lap_async())
    r_block = len(reqs) / t_block
    r_async = len(reqs) / t_async
    speedup = r_async / r_block
    st = svc_async.stats()
    split = f"pack_s={st['pack_s']:.3f} device_s={st['device_s']:.3f}"
    return [
        ("service_blocking/solves_per_sec", r_block,
         f"{mix} max_inflight=0 (launch+harvest inline)"),
        ("service_async/solves_per_sec", r_async,
         f"{mix} max_inflight=4 speedup_vs_blocking={speedup:.2f}x"),
        ("service_async/speedup_vs_blocking", speedup,
         f"overlap scales with free host cores (bar: >= 1.3x multicore, "
         f"~1x on a 1-core box); {split}"),
    ]


def _ragged_shard_rows():
    from jax.sharding import Mesh

    from repro.core import sharded_solve

    devs = jax.devices()
    mesh = Mesh(np.array(devs), ("data",))
    n_dev = len(devs)
    # One instance more than divides the mesh: every shard pads (the worst
    # ragged case).  On one device nothing pads -- the row then tracks the
    # sharded front end's overhead on the same workload.
    b = 64 * n_dev + (1 if n_dev > 1 else 0)
    rng = np.random.default_rng(2)
    y0 = jnp.asarray(rng.uniform(0.5, 1.5, (b, 32)), jnp.float32)
    args = jnp.asarray(1.0, jnp.float32)

    def run():
        sol = sharded_solve(mesh, _decay, y0, None, t_start=0.0, t_end=T1,
                            rtol=1e-6, atol=1e-6, args=args)
        jax.block_until_ready(sol.ys)
        return sol

    run()  # compile
    best = min(
        (lambda t0: (run(), time.perf_counter() - t0)[1])(time.perf_counter())
        for _ in range(5)
    )
    return [
        ("ragged_shard/instances_per_sec", b / best,
         f"b={b} over {n_dev} device(s), per-shard padding"),
    ]


def rows(requests: int = 512):
    reqs = _stream(requests)
    r_naive = _per_request(reqs)
    r_svc, stats = _service(reqs)
    speedup = r_svc / r_naive
    mix = f"b<=16 f={'/'.join(map(str, FEATURES))} dopri5"
    return [
        ("per_request/solves_per_sec", r_naive, f"{mix} per-request jit b=1"),
        ("service/solves_per_sec", r_svc,
         f"{mix} prewarmed speedup_vs_per_request={speedup:.1f}x"),
        ("service/speedup_vs_per_request", speedup,
         "acceptance bar: >= 5x on CPU"),
        ("service/pad_waste", stats["pad_waste"],
         f"pad rows fraction over {stats['n_batches']} batches"),
        ("service/cache_hit_rate",
         stats["cache_hits"] / max(1, stats["cache_hits"] + stats["cache_misses"]),
         f"hits={stats['cache_hits']} misses={stats['cache_misses']}"),
        *_async_overlap_rows(requests),
        *_ragged_shard_rows(),
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_serving.json", default=None,
                        metavar="PATH", help="also write rows to a JSON file")
    parser.add_argument("--requests", type=int, default=512,
                        help="timed requests in the stream")
    opts = parser.parse_args()

    records = []
    print("name,value,derived")
    t0 = time.time()
    for name, v, extra in rows(opts.requests):
        print(f"serving/{name},{v:.4f},{extra}", flush=True)
        records.append({"suite": "serving", "name": name, "value": v,
                        "derived": extra})
    records.append({"suite": "serving", "name": "_suite_wall_s",
                    "value": time.time() - t0, "derived": ""})

    if opts.json:
        from .common import calibration_us

        payload = {"bench": "serving", "unit": "solves/sec",
                   "calibration_us": calibration_us(), "rows": records}
        with open(opts.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(records)} rows to {opts.json}", flush=True)


if __name__ == "__main__":
    main()
