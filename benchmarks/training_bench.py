"""Training-throughput benchmark: coalesced gradient serving vs per-request
``jax.grad``.

The workload is the gradient-serving regime from the ROADMAP: a training
loop where every sequence in a minibatch is its own solve request (mixed
feature sizes, per-request spans/tolerances/cotangents) and the client needs
``dL/dy0`` and ``dL/dargs`` back for each.  Two ways to produce the identical
gradient stream:

  per_request  the naive baseline: each request differentiated alone at b=1
               through a per-shape ``jax.jit(jax.grad(...))`` over the same
               ``ScanAdjoint`` driver (warmed before timing -- the baseline
               pays Python dispatch + a b=1 backward per request, NOT
               retracing).
  service      ``SolveService`` gradient serving: ``GradRequest``s coalesced
               into power-of-two padded buckets, the whole bucket's VJP
               pulled through one prewarmed compiled program, per-request
               gradient rows sliced back out.

Reports steady-state grad-solves/sec for both and the speedup (acceptance
bar: >= 3x on CPU at max_batch=16).

Usage: python -m benchmarks.training_bench [--json [PATH]] [--requests N]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    GradRequest,
    ScanAdjoint,
    SolveService,
    Stepper,
)

FEATURES = (2, 4)
MAX_BATCH = 16
MAX_STEPS = 64
ATOL = 1e-6


def _decay(t, y, args):
    return -y * args


def _stream(n: int, seed: int = 0) -> list[GradRequest]:
    """A reproducible mixed-shape gradient-request stream (round-robin
    features, so both paths see the identical request sequence)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        feat = FEATURES[i % len(FEATURES)]
        reqs.append(GradRequest(
            f=_decay,
            y0=jnp.asarray(rng.uniform(0.5, 1.5, (feat,)), jnp.float32),
            t0=0.0,
            t1=float(rng.uniform(0.8, 1.2)),
            args=jnp.asarray(rng.uniform(0.5, 2.0, (feat,)), jnp.float32),
            rtol=float(rng.choice([1e-3, 1e-4])),
            cotangent=jnp.asarray(rng.normal(size=(feat,)), jnp.float32),
        ))
    return reqs


def _per_request(reqs) -> float:
    """Grad-solves/sec differentiating each request alone at b=1."""

    @jax.jit
    def jitted(drv, y0, t0, t1, args, ct):
        def scalar(y0_, args_):
            sol = drv.solve(_decay, y0_, None, t_start=t0, t_end=t1,
                            args=args_)
            return jnp.vdot(sol.ys, ct)

        return jax.grad(scalar, argnums=(0, 1))(y0, args)

    def run(req):
        # The driver crosses jit as an ordinary argument: its per-request
        # tolerance leaves are dynamic, so the program still compiles once
        # per feature shape, not once per tolerance value.
        drv = ScanAdjoint(Stepper("dopri5"), max_steps=MAX_STEPS,
                          rtol=jnp.asarray([req.rtol], jnp.float32),
                          atol=jnp.asarray([ATOL], jnp.float32))
        return jitted(drv, req.y0[None],
                      jnp.asarray([req.t0], jnp.float32),
                      jnp.asarray([req.t1], jnp.float32),
                      req.args[None], req.cotangent[None])

    for req in reqs[: 2 * len(FEATURES)]:
        jax.block_until_ready(run(req))
    best = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        for req in reqs:
            jax.block_until_ready(run(req))
        best = min(best, time.perf_counter() - t0)
    return len(reqs) / best


def _service(reqs, *, max_inflight=4) -> tuple[float, dict]:
    """Grad-solves/sec through the coalescing service (prewarmed)."""
    drv = ScanAdjoint(Stepper("dopri5"), max_steps=MAX_STEPS,
                      rtol=1e-3, atol=ATOL)
    svc = SolveService(max_batch=MAX_BATCH, max_delay=None,
                       default_grad_method=drv, max_inflight=max_inflight)
    for feat in FEATURES:
        svc.prewarm(GradRequest(
            f=_decay, y0=jnp.ones((feat,), jnp.float32), t0=0.0, t1=1.0,
            args=jnp.ones((feat,), jnp.float32), rtol=1e-3,
            cotangent=jnp.ones((feat,), jnp.float32),
        ), batch_classes=[MAX_BATCH])
    # One warm lap outside the timed window (mirrors the baseline's warmup),
    # then best of 3 timed laps over the same stream.
    for req in reqs[: 2 * MAX_BATCH]:
        svc.submit(req)
    svc.flush()
    svc.drain()
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        futures = [svc.submit(req) for req in reqs]
        svc.flush()
        svc.drain()
        for fut in futures:
            fut.result(flush=False)
        best = min(best, time.perf_counter() - t0)
    return len(reqs) / best, svc.stats()


def rows(requests: int = 512):
    reqs = _stream(requests)
    r_naive = _per_request(reqs)
    r_svc, stats = _service(reqs)
    speedup = r_svc / r_naive
    mix = f"b<=16 f={'/'.join(map(str, FEATURES))} dopri5 scan_adjoint"
    return [
        ("per_request_grad/solves_per_sec", r_naive,
         f"{mix} per-request jit(grad) b=1"),
        ("service_grad/solves_per_sec", r_svc,
         f"{mix} prewarmed speedup_vs_per_request={speedup:.1f}x"),
        ("service_grad/speedup_vs_per_request", speedup,
         "acceptance bar: >= 3x on CPU"),
        ("service_grad/pad_waste", stats["pad_waste"],
         f"pad rows fraction over {stats['n_batches']} batches"),
        ("service_grad/device_s_per_solve",
         stats["grad_device_s"] / max(1, stats["n_grad_solves"]),
         f"n_grad_solves={stats['n_grad_solves']}"),
    ]


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_training.json",
                        default=None, metavar="PATH",
                        help="also write rows to a JSON file")
    parser.add_argument("--requests", type=int, default=512,
                        help="timed requests in the stream")
    opts = parser.parse_args()

    records = []
    print("name,value,derived")
    t0 = time.time()
    for name, v, extra in rows(opts.requests):
        print(f"training/{name},{v:.4f},{extra}", flush=True)
        records.append({"suite": "training", "name": name, "value": v,
                        "derived": extra})
    records.append({"suite": "training", "name": "_suite_wall_s",
                    "value": time.time() - t0, "derived": ""})

    if opts.json:
        from .common import calibration_us

        payload = {"bench": "training", "unit": "grad solves/sec",
                   "calibration_us": calibration_us(), "rows": records}
        with open(opts.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(records)} rows to {opts.json}", flush=True)


if __name__ == "__main__":
    main()
