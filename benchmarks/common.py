"""Shared benchmark utilities.

The paper's headline metric is LOOP TIME: solver wall time per step,
excluding dynamics evaluation time (Appendix A).  We measure total solver
time, model (dynamics) time, and steps; loop = (total - model) / steps.

The torchdiffeq/TorchDyn baseline semantics ("joint batching": one shared
step size for the whole batch) is reproduced by flattening the batch into a
single solver instance -- the exact construction in paper SS4.1.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solve_ivp


def vdp(t, y, mu):
    """The Van der Pol RHS shared by the VdP-based suites (Table 3, stiff)."""
    x, xd = y[..., 0], y[..., 1]
    return jnp.stack((xd, mu * (1 - x**2) * xd - x), axis=-1)


def timed(fn, *args, repeats=3, warmup=1, reduce="mean"):
    """Times ``fn(*args)`` over ``repeats`` runs.  ``reduce="min"`` reports the
    fastest run instead of the mean -- the robust choice for RATIO metrics
    (fused/unfused speedups), where one descheduled run in either numerator
    or denominator skews a mean-of-3 by tens of percent."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    agg = np.min if reduce == "min" else np.mean
    return float(agg(ts)), float(np.std(ts))


def calibration_us(repeats: int = 5) -> float:
    """Wall time (microseconds) of a fixed jitted reference workload.

    Stored alongside every benchmark payload so ``compare.py`` can normalize
    a fresh run against a baseline recorded on a DIFFERENT machine: the gate
    compares ``fresh / (fresh_cal / base_cal)`` instead of raw wall time, so
    a uniformly slower CI box does not trip the regression threshold.  The
    workload (a chain of small matmuls) is deliberately solver-free: it moves
    with the machine/XLA, not with this repo's code under test.
    """
    x = jnp.eye(64, dtype=jnp.float32) + 0.01

    @jax.jit
    def work(m):
        for _ in range(32):
            m = jnp.tanh(m @ m) + 0.1
        return m

    mean_s, _ = timed(work, x, repeats=repeats, warmup=2)
    return float(mean_s * 1e6)


def joint_wrap(f, batch, feat):
    """Wrap batched dynamics f into a SINGLE-instance joint problem
    (torchdiffeq-style: shared step size and error estimate)."""

    def fj(t, y, args):
        yb = y.reshape(batch, feat)
        tb = jnp.broadcast_to(t[0], (batch,))
        return f(tb, yb, args).reshape(1, batch * feat)

    return fj


def solve_joint(f, y0, t_eval, **kw):
    b, feat = y0.shape
    fj = joint_wrap(f, b, feat)
    te = t_eval if t_eval is None else jnp.asarray(t_eval)
    sol = solve_ivp(fj, y0.reshape(1, b * feat), te, **kw)
    return sol


def count_evals_time(solve_fn, n_evals_fn, *args, repeats=3):
    """Returns (total_s, model_s_estimate, steps).  Model time is estimated by
    timing the dynamics alone for the recorded number of evaluations."""
    total, _ = timed(solve_fn, *args, repeats=repeats)
    return total
