"""Shared benchmark utilities.

The paper's headline metric is LOOP TIME: solver wall time per step,
excluding dynamics evaluation time (Appendix A).  We measure total solver
time, model (dynamics) time, and steps; loop = (total - model) / steps.

The torchdiffeq/TorchDyn baseline semantics ("joint batching": one shared
step size for the whole batch) is reproduced by flattening the batch into a
single solver instance -- the exact construction in paper SS4.1.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import solve_ivp


def vdp(t, y, mu):
    """The Van der Pol RHS shared by the VdP-based suites (Table 3, stiff)."""
    x, xd = y[..., 0], y[..., 1]
    return jnp.stack((xd, mu * (1 - x**2) * xd - x), axis=-1)


def timed(fn, *args, repeats=3, warmup=1):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.mean(ts)), float(np.std(ts))


def joint_wrap(f, batch, feat):
    """Wrap batched dynamics f into a SINGLE-instance joint problem
    (torchdiffeq-style: shared step size and error estimate)."""

    def fj(t, y, args):
        yb = y.reshape(batch, feat)
        tb = jnp.broadcast_to(t[0], (batch,))
        return f(tb, yb, args).reshape(1, batch * feat)

    return fj


def solve_joint(f, y0, t_eval, **kw):
    b, feat = y0.shape
    fj = joint_wrap(f, b, feat)
    te = t_eval if t_eval is None else jnp.asarray(t_eval)
    sol = solve_ivp(fj, y0.reshape(1, b * feat), te, **kw)
    return sol


def count_evals_time(solve_fn, n_evals_fn, *args, repeats=3):
    """Returns (total_s, model_s_estimate, steps).  Model time is estimated by
    timing the dynamics alone for the recorded number of evaluations."""
    total, _ = timed(solve_fn, *args, repeats=repeats)
    return total
