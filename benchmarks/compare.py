"""Benchmark-regression gate: diff fresh BENCH_*.json runs against baselines.

  python -m benchmarks.compare BASELINE FRESH [BASELINE FRESH ...]
      [--threshold 0.25] [--normalize] [--update]

Every ``(suite, name)`` row present in both files is checked with a
direction-aware rule:

  *_time rows        lower is better: fail when fresh > base * (1 + threshold)
  *per_sec rows      higher is better: fail when fresh < base / (1 + threshold)
  everything else    informational only (counts, ratios, _suite_wall_s)

The default threshold (25%, override with ``--threshold`` or the
``BENCH_COMPARE_THRESHOLD`` env var) is deliberately loose: CI machines are
noisy, and the gate exists to catch real regressions -- a dispatch-cache
breakage turns a solves/sec row into a cliff, not a wobble.  Rows missing
from the fresh run (or baselines with no comparable rows at all) fail the
gate: a silently dropped metric must not read as green.

``--normalize`` (or ``BENCH_COMPARE_NORMALIZE=1``) divides the fresh wall
times by the ratio of the two payloads' ``calibration_us`` fields (a fixed
solver-free jitted workload recorded at ``--json`` time; see
``benchmarks.common.calibration_us``) before gating, so a baseline committed
from one machine can gate runs on uniformly faster/slower hardware.  Pairs
where either payload lacks the field fall back to raw comparison with a
warning -- normalization must never silently weaken the gate.

``--update`` rewrites each baseline from its fresh run instead of comparing
(use after an intentional perf change, then commit the new baselines).

Exit code 0 = no regressions, 1 = regression or malformed input.
"""

from __future__ import annotations

import argparse
import json
import os
import shutil
import sys


def _rows(payload: dict) -> dict[tuple[str, str], float]:
    out = {}
    for row in payload["rows"]:
        name = row["name"]
        if name.startswith("_"):
            continue  # bookkeeping rows (wall time) are never gated
        out[(row["suite"], name)] = float(row["value"])
    return out


def _direction(name: str) -> str | None:
    """'lower' / 'higher' for gated rows, None for informational ones."""
    if name.endswith("_time") or "_time/" in name:
        return "lower"
    if name.endswith("per_sec"):
        return "higher"
    return None


def compare_rows(
    base: dict[tuple[str, str], float],
    fresh: dict[tuple[str, str], float],
    threshold: float,
    scale: float = 1.0,
) -> tuple[list[str], int]:
    """Returns (failure messages, number of rows actually gated).

    ``scale`` is the machine-speed ratio fresh_cal/base_cal: fresh wall times
    are divided by it (and fresh throughputs multiplied) before gating, so a
    uniformly slower fresh machine compares fairly against the baseline.
    ``scale=1.0`` (the default) is the raw comparison."""
    failures = []
    n_gated = 0
    for key, base_v in sorted(base.items()):
        direction = _direction(key[1])
        if direction is None:
            continue
        if key not in fresh:
            failures.append(f"{key[0]}/{key[1]}: gated row missing from fresh run")
            continue
        n_gated += 1
        fresh_v = fresh[key]
        if base_v <= 0 or fresh_v <= 0:
            failures.append(
                f"{key[0]}/{key[1]}: non-positive value (base={base_v}, "
                f"fresh={fresh_v})"
            )
            continue
        if direction == "lower":
            slowdown = (fresh_v / scale) / base_v - 1.0
        else:
            slowdown = base_v / (fresh_v * scale) - 1.0
        if slowdown > threshold:
            failures.append(
                f"{key[0]}/{key[1]}: {slowdown * 100:.1f}% slowdown "
                f"(base={base_v:.4g}, fresh={fresh_v:.4g}, "
                f"{direction}-is-better, threshold {threshold * 100:.0f}%"
                + (f", machine scale {scale:.3f}" if scale != 1.0 else "")
                + ")"
            )
    return failures, n_gated


def calibration_scale(base_payload: dict, fresh_payload: dict) -> tuple[float, str | None]:
    """The machine-speed ratio fresh/base from the payloads' calibration
    fields, clamped to a sane band.  Returns ``(scale, warning_or_None)``;
    on any problem the scale is 1.0 (raw comparison) with a warning."""
    base_cal = base_payload.get("calibration_us")
    fresh_cal = fresh_payload.get("calibration_us")
    if base_cal is None or fresh_cal is None:
        return 1.0, "calibration_us missing from payload; comparing raw values"
    try:
        scale = float(fresh_cal) / float(base_cal)
    except (TypeError, ValueError, ZeroDivisionError):
        return 1.0, "calibration_us malformed; comparing raw values"
    if not (0.05 <= scale <= 20.0):
        # A 20x "machine speed" difference is not a machine: it's a broken
        # calibration run.  Refuse to normalize rather than wash out a
        # genuine cliff.
        return 1.0, f"calibration ratio {scale:.3g} out of range; comparing raw values"
    return scale, None


def compare_files(
    base_path: str, fresh_path: str, threshold: float, normalize: bool = False
) -> list[str]:
    try:
        with open(base_path) as fh:
            base_payload = json.load(fh)
        with open(fresh_path) as fh:
            fresh_payload = json.load(fh)
        base = _rows(base_payload)
        fresh = _rows(fresh_payload)
    except (OSError, KeyError, ValueError, TypeError) as e:
        return [f"{base_path} vs {fresh_path}: unreadable ({e!r})"]
    scale = 1.0
    if normalize:
        scale, warning = calibration_scale(base_payload, fresh_payload)
        if warning:
            print(f"    warning: {base_path} vs {fresh_path}: {warning}")
    failures, n_gated = compare_rows(base, fresh, threshold, scale=scale)
    if n_gated == 0 and not failures:
        return [f"{base_path} vs {fresh_path}: no gated rows in common -- "
                "wrong file pairing?"]
    return failures


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("pairs", nargs="+", metavar="BASELINE FRESH",
                        help="baseline/fresh JSON file pairs")
    parser.add_argument("--threshold", type=float,
                        default=float(os.environ.get("BENCH_COMPARE_THRESHOLD",
                                                     "0.25")),
                        help="allowed fractional slowdown (default 0.25)")
    parser.add_argument("--normalize", action="store_true",
                        default=os.environ.get("BENCH_COMPARE_NORMALIZE", "") == "1",
                        help="normalize fresh values by the calibration_us "
                             "ratio of the two payloads (cross-machine gate)")
    parser.add_argument("--update", action="store_true",
                        help="overwrite each BASELINE with its FRESH run")
    opts = parser.parse_args(argv)
    if len(opts.pairs) % 2 != 0:
        parser.error("expected BASELINE FRESH pairs")

    pairs = list(zip(opts.pairs[::2], opts.pairs[1::2]))
    if opts.update:
        for base_path, fresh_path in pairs:
            shutil.copyfile(fresh_path, base_path)
            print(f"updated {base_path} from {fresh_path}")
        return 0

    all_failures = []
    for base_path, fresh_path in pairs:
        failures = compare_files(base_path, fresh_path, opts.threshold,
                                 normalize=opts.normalize)
        status = "FAIL" if failures else "ok"
        print(f"[{status}] {base_path} vs {fresh_path}")
        for msg in failures:
            print(f"    {msg}")
        all_failures += failures
    if all_failures:
        print(f"{len(all_failures)} benchmark regression(s) above "
              f"{opts.threshold * 100:.0f}%")
        return 1
    print("no benchmark regressions")
    return 0


if __name__ == "__main__":
    sys.exit(main())
