"""Fused-megakernel step benchmark: per-step wall time, fused vs unfused.

The regime is the paper's launch-overhead argument (Sec. 4) taken to the
kernel level: for small/medium problems the solver loop is bound by *op
dispatch* -- each unfused step attempt issues ~8 separate registry ops
(stage accumulations, b_sol/b_err combine, error norm, controller update,
masked commits, interpolation coefficients), while the fused path issues ONE
``fused_step_poly`` megakernel per attempt (zero vf launches: the linear
dynamics fuse into the kernel as a closed-form polynomial).

Two backends, same numerics:

  ref        pure-jnp ops inside one jitted loop.  XLA:CPU already fuses
             across op boundaries, so fused ~ unfused here (sanity rows).
  interpret  every registry op is a Pallas call in interpret mode, so per-op
             invocation overhead dominates exactly like kernel-launch
             overhead does on an accelerator.  The fused/unfused ratio on
             these rows is the launch-count proxy the tentpole targets
             (acceptance bar: >= 2x steps/sec on at least one point).

Problem: exponential decay ``dy/dt = -y`` via ``polynomial_term``, final-state
regime (dense output off), jitted end to end.  The default rows run dopri5 +
PID; dedicated rows cover the non-FSAL trailing-evaluation path (heun), the
fixed-step controller mode (rk4 + FixedController) and the feature-tiled
kernel schedule (f = 256 > the 128-lane tile on the interpret backend).

Timing is min-of-N (see ``common.timed``): the headline metric is a RATIO of
two wall times, and a single descheduled run in either leg skews a mean-of-3
by tens of percent -- exactly the noise that once recorded a spurious 0.81x
at (256, 256).

Usage: python -m benchmarks.step_bench [--json [PATH]] [--bars]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AutoDiffAdjoint,
    FixedController,
    Stepper,
    pid_controller,
    polynomial_term,
)
from repro.kernels import ops

from .common import timed

# (backend, batch, features, method, controller-kind, speedup bar): the ref
# rows sweep the paper's small-problem grid; the interpret rows stay modest
# because interpret mode is slow by design (it is the launch-overhead proxy,
# not a production path).  The bar is the fused/unfused steps/sec floor
# enforced by --bars when refreshing the committed baseline: >= 2x where
# dispatch dominates (small f, interpret), >= 1.0x ("never lose") at the
# headline (256, 256) point on the XLA-fused ref backend.  The remaining ref
# rows are parity sanity rows -- XLA:CPU fuses across op boundaries anyway,
# so their true ratio is ~1.0 and the 0.9 bar only catches a genuine cliff,
# not the +/-3% container-noise band the ratio lives in.
POINTS = (
    ("ref", 16, 16, "dopri5", "pid", 0.9),
    ("ref", 64, 64, "dopri5", "pid", 0.9),
    ("ref", 256, 256, "dopri5", "pid", 1.0),
    ("ref", 64, 64, "heun", "pid", 0.9),
    ("ref", 64, 64, "rk4", "fixed", 0.9),
    ("interpret", 16, 16, "dopri5", "pid", 2.0),
    ("interpret", 256, 256, "dopri5", "pid", 1.5),
)


def _make_solve(fused: bool, method: str, ctrl: str):
    controller = pid_controller() if ctrl == "pid" else FixedController()
    solver = AutoDiffAdjoint(
        Stepper(method), controller,
        rtol=1e-4, atol=1e-6, dense=False, fused=fused,
    )
    term = polynomial_term(0.0, -1.0)
    # FixedController keeps dt0 forever; 0.01 gives a 200-step loop, the same
    # order of work as the adaptive rows.
    dt0 = 0.01 if ctrl == "fixed" else None

    @jax.jit
    def run(y0):
        return solver.solve(term, y0, t_start=0.0, t_end=2.0, dt0=dt0)

    return run


def _bench_point(backend, b, f, method, ctrl, fused, repeats):
    ops.set_backend(backend)
    run = _make_solve(fused, method, ctrl)
    y0 = jnp.asarray(
        np.linspace(0.5, 1.5, b * f, dtype=np.float32).reshape(b, f)
    )
    sol = jax.block_until_ready(run(y0))
    # Loop iterations: the batch steps in lockstep, so the longest-running
    # instance's step count is the number of loop bodies executed.
    n_loop = int(np.max(np.asarray(sol.stats["n_steps"])))
    if fused:
        assert "n_fused_steps" in sol.stats, "fused path did not engage"
    best_s, _ = timed(run, y0, repeats=repeats, reduce="min")
    step_us = best_s / n_loop * 1e6
    return step_us, n_loop / best_s, n_loop


def _tag(backend, b, f, method, ctrl):
    tag = f"{backend}_b{b}_f{f}"
    if method != "dopri5":
        tag += f"_{method}"
    if ctrl != "pid":
        tag += f"_{ctrl}"
    return tag


def rows(repeats: int = 3):
    prev = ops.backend()
    try:
        for backend, b, f, method, ctrl, bar in POINTS:
            tag = _tag(backend, b, f, method, ctrl)
            per_sec = {}
            for fused in (False, True):
                label = "fused" if fused else "unfused"
                step_us, sps, n_loop = _bench_point(
                    backend, b, f, method, ctrl, fused, repeats)
                per_sec[label] = sps
                yield f"{tag}_{label}_step_time", step_us, f"{n_loop} loop steps"
                yield f"{tag}_{label}_steps_per_sec", sps, ""
            yield (
                f"{tag}_fused_speedup", per_sec["fused"] / per_sec["unfused"],
                f"steps/sec ratio, fused over unfused (bar {bar}x)",
            )
    finally:
        ops.set_backend(prev)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_step.json", default=None,
                        metavar="PATH")
    parser.add_argument("--repeats", type=int, default=3)
    parser.add_argument("--bars", action="store_true",
                        help="fail if any _fused_speedup row misses its floor "
                             "(use when refreshing the committed baseline)")
    opts = parser.parse_args()

    bars = {f"{_tag(*p[:5])}_fused_speedup": p[5] for p in POINTS}
    records = []
    missed = []
    print("name,value,derived")
    for name, v, extra in rows(repeats=opts.repeats):
        print(f"step/{name},{v},{extra}", flush=True)
        records.append({"suite": "step", "name": name, "value": v, "derived": extra})
        if opts.bars and name in bars and v < bars[name]:
            missed.append(f"{name}: {v:.3f}x < bar {bars[name]}x")

    if opts.json:
        from .common import calibration_us

        payload = {"bench": "step", "unit": "us for *_time rows",
                   "calibration_us": calibration_us(), "rows": records}
        with open(opts.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(records)} rows to {opts.json}")

    if missed:
        raise SystemExit("speedup below bar:\n  " + "\n  ".join(missed))


if __name__ == "__main__":
    main()
