"""Fused-megakernel step benchmark: per-step wall time, fused vs unfused.

The regime is the paper's launch-overhead argument (Sec. 4) taken to the
kernel level: for small/medium problems the solver loop is bound by *op
dispatch* -- each unfused step attempt issues ~8 separate registry ops
(stage accumulations, b_sol/b_err combine, error norm, controller update,
masked commits, interpolation coefficients), while the fused path issues ONE
``fused_step_poly`` megakernel per attempt (zero vf launches: the linear
dynamics fuse into the kernel as a closed-form polynomial).

Two backends, same numerics:

  ref        pure-jnp ops inside one jitted loop.  XLA:CPU already fuses
             across op boundaries, so fused ~ unfused here (sanity rows).
  interpret  every registry op is a Pallas call in interpret mode, so per-op
             invocation overhead dominates exactly like kernel-launch
             overhead does on an accelerator.  The fused/unfused ratio on
             these rows is the launch-count proxy the tentpole targets
             (acceptance bar: >= 2x steps/sec on at least one point).

Problem: exponential decay ``dy/dt = -y`` via ``polynomial_term``, dopri5 +
PID controller, final-state regime (dense output off), jitted end to end.

Usage: python -m benchmarks.step_bench [--json [PATH]]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AutoDiffAdjoint, Stepper, pid_controller, polynomial_term
from repro.kernels import ops

from .common import timed

# (backend, batch, features): the ref rows sweep the paper's small-problem
# grid; the interpret rows stay small because interpret mode is slow by
# design (it is the launch-overhead proxy, not a production path).
POINTS = (
    ("ref", 16, 16),
    ("ref", 64, 64),
    ("ref", 256, 256),
    ("interpret", 16, 16),
)


def _make_solve(fused: bool):
    solver = AutoDiffAdjoint(
        Stepper("dopri5"), pid_controller(),
        rtol=1e-4, atol=1e-6, dense=False, fused=fused,
    )
    term = polynomial_term(0.0, -1.0)

    @jax.jit
    def run(y0):
        return solver.solve(term, y0, t_start=0.0, t_end=2.0)

    return run


def _bench_point(backend: str, b: int, f: int, fused: bool, repeats: int):
    ops.set_backend(backend)
    run = _make_solve(fused)
    y0 = jnp.asarray(
        np.linspace(0.5, 1.5, b * f, dtype=np.float32).reshape(b, f)
    )
    sol = jax.block_until_ready(run(y0))
    # Loop iterations: the batch steps in lockstep, so the longest-running
    # instance's step count is the number of loop bodies executed.
    n_loop = int(np.max(np.asarray(sol.stats["n_steps"])))
    if fused:
        assert "n_fused_steps" in sol.stats, "fused path did not engage"
    mean_s, _ = timed(run, y0, repeats=repeats)
    step_us = mean_s / n_loop * 1e6
    return step_us, n_loop / mean_s, n_loop


def rows(repeats: int = 3):
    prev = ops.backend()
    try:
        for backend, b, f in POINTS:
            tag = f"{backend}_b{b}_f{f}"
            per_sec = {}
            for fused in (False, True):
                label = "fused" if fused else "unfused"
                step_us, sps, n_loop = _bench_point(backend, b, f, fused, repeats)
                per_sec[label] = sps
                yield f"{tag}_{label}_step_time", step_us, f"{n_loop} loop steps"
                yield f"{tag}_{label}_steps_per_sec", sps, ""
            yield (
                f"{tag}_fused_speedup", per_sec["fused"] / per_sec["unfused"],
                "steps/sec ratio, fused over unfused",
            )
    finally:
        ops.set_backend(prev)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--json", nargs="?", const="BENCH_step.json", default=None,
                        metavar="PATH")
    parser.add_argument("--repeats", type=int, default=3)
    opts = parser.parse_args()

    records = []
    print("name,value,derived")
    for name, v, extra in rows(repeats=opts.repeats):
        print(f"step/{name},{v},{extra}", flush=True)
        records.append({"suite": "step", "name": name, "value": v, "derived": extra})

    if opts.json:
        from .common import calibration_us

        payload = {"bench": "step", "unit": "us for *_time rows",
                   "calibration_us": calibration_us(), "rows": records}
        with open(opts.json, "w") as fh:
            json.dump(payload, fh, indent=2)
        print(f"# wrote {len(records)} rows to {opts.json}")


if __name__ == "__main__":
    main()
