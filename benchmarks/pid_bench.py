"""Figure 2 / Appendix C: PID vs integral controller step counts on VdP.

Sweeps damping mu (stiffness) and several PID coefficient sets (from diffrax's
documentation, as the paper does), reporting steps relative to the I
controller.  Expected reproduction: PID costs a few % at low mu and saves
3-5% beyond mu ~ 25.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import PIDController, integral_controller, solve_ivp

from .vdp_bench import vdp

COEFFS = {
    "I": integral_controller(),
    "PI-0.3/0.4": PIDController(pcoeff=0.3, icoeff=0.4),
    "PID-0.2/0.3/0.1": PIDController(pcoeff=0.2, icoeff=0.3, dcoeff=0.1),
    "PID-0.1/0.3/0": PIDController(pcoeff=0.1, icoeff=0.3, dcoeff=0.0),
}


def run(mus=(1.0, 5.0, 15.0, 25.0, 40.0), tol=1e-6):
    out = {}
    for mu in mus:
        t_end = max(2.0 * mu, 6.5)  # ~one cycle
        y0 = jnp.array([[2.0, 0.0]])
        row = {}
        for name, ctrl in COEFFS.items():
            sol = solve_ivp(vdp, y0, None, t_start=0.0, t_end=float(t_end),
                            args=float(mu), atol=tol, rtol=tol,
                            controller=ctrl, max_steps=100_000)
            row[name] = int(np.asarray(sol.stats["n_steps"])[0])
        out[mu] = row
    return out


def rows():
    r = run()
    out = []
    for mu, row in r.items():
        base = row["I"]
        for name, steps in row.items():
            out.append((f"pid/mu{mu:g}/{name}", steps,
                        f"vs I: {100*(steps-base)/base:+.1f}%"))
    return out


if __name__ == "__main__":
    for name, v, extra in rows():
        print(f"{name},{v},{extra}")
