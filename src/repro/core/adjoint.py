"""Adjoint-equation (optimize-then-discretize) gradients.

torchode's Table 5 finding, reproduced here as two first-class modes:

  - ``per_instance``: every batch element solves its OWN adjoint ODE with its
    own step size -- state size b*(2f + p).  Faithful to "no within-batch
    interaction" but the parameter adjoint is replicated per instance, which
    is why torchode's default backward was slow (58 ms loop time).
  - ``joint``: the whole batch is ONE solver instance of size 2bf + p -- the
    paper's fast ``torchode-joint`` backward (2.38 ms, 3.1x over torchdiffeq).

Unlike PyTorch (whose JIT cannot compile custom autograd Functions -- the
paper's stated reason Table 5 has no JIT column), ``jax.custom_vjp`` composes
with ``jax.jit``, so in this implementation the adjoint backward IS jit- and
XLA-compiled.  This is a hardware/ecosystem adaptation win recorded in
DESIGN.md.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .loop import solve_ivp
from .stepper import AbstractStepper


def make_adjoint_solve(
    f: Callable,
    *,
    method: str | AbstractStepper = "dopri5",
    rtol=1e-3,
    atol=1e-6,
    max_steps: int = 10_000,
    mode: str = "joint",
    controller=None,
    batched_args: bool = False,
):
    """Returns ``solve(y0, t_start, t_end, params) -> y(t_end)`` whose VJP
    solves the adjoint ODE backwards in time (O(1) memory in solver steps).

    ``f(t, y, params)`` is the batched dynamics; ``params`` any pytree.
    ``method`` is a tableau name or a stepper (explicit or implicit -- the
    backward adjoint solve reuses the same method).  ``mode`` is "joint"
    (single fused adjoint problem, paper's recommended default) or
    "per_instance" (fully independent adjoint solves).

    ``batched_args=True`` declares that every ``params`` leaf carries the
    batch as its *leading axis* and instance ``i`` owns row ``i`` -- the
    serving layer's per-request parameter rows (``ODETerm.batched_args``).
    Joint mode needs no special handling (the whole stack ravels into the
    augmented state and the returned cotangent keeps the rows), but
    per-instance mode must thread each instance's OWN row through the ravel
    boundary: its augmented state carries a row-sized parameter adjoint and
    the single-instance VJP closes over that row alone.  Without the flag the
    old behaviour silently handed the *full* stack to every instance-1
    evaluation -- a shape error at best, a wrong broadcastdown gradient at
    worst.
    """
    assert mode in ("joint", "per_instance")
    # ``method`` may be a stepper object: it is passed through to solve_ivp
    # unchanged (coerce returns it as-is), so custom tableaus AND stepper
    # configuration (e.g. an implicit stepper's Newton knobs) apply to both
    # the forward and the backward adjoint solve.

    @jax.custom_vjp
    def _solve(y0, t_start, t_end, params):
        sol = solve_ivp(
            f,
            y0,
            None,
            t_start=t_start,
            t_end=t_end,
            method=method,
            rtol=rtol,
            atol=atol,
            max_steps=max_steps,
            controller=controller,
            args=params,
        )
        return sol.ys

    def _fwd(y0, t_start, t_end, params):
        y1 = _solve(y0, t_start, t_end, params)
        return y1, (y1, t_start, t_end, params)

    def _bwd(res, g):
        y1, t_start, t_end, params = res
        b, feat = y1.shape
        per_row = (mode == "per_instance" and batched_args
                   and len(jax.tree_util.tree_leaves(params)) > 0)
        if not per_row:
            flat_params, unravel = ravel_pytree(params)
            p = flat_params.shape[0]

        if per_row:
            # Per-request parameter rows: instance i's augmented state carries
            # the adjoint of ITS row only, and the single-instance VJP closes
            # over that row (re-batched to axis size 1 for the batched f).
            _, unravel_row = ravel_pytree(
                jax.tree_util.tree_map(lambda x: x[0], params)
            )
            flat_rows = jax.vmap(lambda row: ravel_pytree(row)[0])(params)
            p = flat_rows.shape[1]
            aug0 = jnp.concatenate(
                [y1, g, jnp.zeros((b, p), dtype=y1.dtype)], axis=-1
            )

            def aug_dyn(t, s, _):
                y = s[:, :feat]
                a = s[:, feat : 2 * feat]

                def single(ti, yi, ai, fpi):
                    def fi(ti_, yi_, fp):
                        row = jax.tree_util.tree_map(
                            lambda x: x[None], unravel_row(fp)
                        )
                        return f(ti_[None], yi_[None], row)[0]

                    fv, vjp_fn = jax.vjp(fi, ti, yi, fpi)
                    _, dy_bar, dp_bar = vjp_fn(ai)
                    return fv, dy_bar, dp_bar

                fv, dy_bar, dp_bar = jax.vmap(single)(t, y, a, flat_rows)
                return jnp.concatenate([fv, -dy_bar, -dp_bar], axis=-1)

            sol = solve_ivp(
                aug_dyn,
                aug0,
                None,
                t_start=t_end,
                t_end=t_start,
                method=method,
                rtol=rtol,
                atol=atol,
                max_steps=max_steps,
                controller=controller,
            )
            a0 = sol.ys[:, feat : 2 * feat]
            dp_rows = sol.ys[:, 2 * feat :]
        elif mode == "per_instance":
            aug0 = jnp.concatenate(
                [y1, g, jnp.zeros((b, p), dtype=y1.dtype)], axis=-1
            )

            def aug_dyn(t, s, _):
                y = s[:, :feat]
                a = s[:, feat : 2 * feat]

                def single(ti, yi, ai):
                    def fi(ti_, yi_, fp):
                        return f(ti_[None], yi_[None], unravel(fp))[0]

                    fv, vjp_fn = jax.vjp(fi, ti, yi, flat_params)
                    _, dy_bar, dp_bar = vjp_fn(ai)
                    return fv, dy_bar, dp_bar

                fv, dy_bar, dp_bar = jax.vmap(single)(t, y, a)
                return jnp.concatenate([fv, -dy_bar, -dp_bar], axis=-1)

            sol = solve_ivp(
                aug_dyn,
                aug0,
                None,
                t_start=t_end,
                t_end=t_start,
                method=method,
                rtol=rtol,
                atol=atol,
                max_steps=max_steps,
                controller=controller,
            )
            a0 = sol.ys[:, feat : 2 * feat]
            dp = jnp.sum(sol.ys[:, 2 * feat :], axis=0)
        else:  # joint: one solver instance of size 2bf + p
            # The backward problem is a SINGLE stacked instance, so per-row
            # (b,)-shaped tolerances cannot apply per instance -- collapse to
            # the strictest row.  (The forward solve above still honours the
            # per-instance rows.)
            bwd_rtol = jnp.min(rtol) if jnp.ndim(rtol) else rtol
            bwd_atol = jnp.min(atol) if jnp.ndim(atol) else atol
            aug0 = jnp.concatenate(
                [y1.ravel(), g.ravel(), jnp.zeros((p,), dtype=y1.dtype)]
            )[None, :]

            def aug_dyn(t, s, _):
                y = s[0, : b * feat].reshape(b, feat)
                a = s[0, b * feat : 2 * b * feat].reshape(b, feat)
                tb = jnp.broadcast_to(t[0], (b,))

                def fy(y_, fp):
                    return f(tb, y_, unravel(fp))

                fv, vjp_fn = jax.vjp(fy, y, flat_params)
                dy_bar, dp_bar = vjp_fn(a)
                out = jnp.concatenate([fv.ravel(), -dy_bar.ravel(), -dp_bar])
                return out[None, :]

            # Joint mode requires a batch-shared integration range.
            sol = solve_ivp(
                aug_dyn,
                aug0,
                None,
                t_start=t_end[:1],
                t_end=t_start[:1],
                method=method,
                rtol=bwd_rtol,
                atol=bwd_atol,
                max_steps=max_steps,
                controller=controller,
            )
            a0 = sol.ys[0, b * feat : 2 * b * feat].reshape(b, feat)
            dp = sol.ys[0, 2 * b * feat :]

        if per_row:
            # One gradient row per instance -- no cross-instance sum.
            dparams = jax.vmap(unravel_row)(dp_rows)
        else:
            dparams = unravel(dp)
        # Boundary-time gradients: dL/dt_end = g . f(t_end, y1), and
        # dL/dt_start = -a(t_start) . f(t_start, y(t_start)).
        f_end = f(t_end, y1, params)
        dt_end = jnp.sum(g * f_end, axis=-1)
        if mode == "per_instance":
            y_at_start = sol.ys[:, :feat]
        else:
            y_at_start = sol.ys[0, : b * feat].reshape(b, feat)
        f_start = f(t_start, y_at_start, params)
        dt_start = -jnp.sum(a0 * f_start, axis=-1)
        return a0, dt_start, dt_end, dparams

    _solve.defvjp(_fwd, _bwd)

    def solve(y0, t_start, t_end, params):
        y0 = jnp.asarray(y0)
        b = y0.shape[0]
        t_start = jnp.broadcast_to(jnp.asarray(t_start, y0.dtype), (b,))
        t_end = jnp.broadcast_to(jnp.asarray(t_end, y0.dtype), (b,))
        return _solve(y0, t_start, t_end, params)

    return solve


def adjoint_backsolve_problem(f, y1, g, t_start, t_end, params, *, mode="joint"):
    """Expose the augmented backward IVP itself (initial state + dynamics +
    range) so benchmarks can measure backward loop time / step counts with full
    solver statistics -- the quantity in the paper's Table 5."""
    b, feat = y1.shape
    flat_params, unravel = ravel_pytree(params)
    p = flat_params.shape[0]
    if mode == "per_instance":
        aug0 = jnp.concatenate([y1, g, jnp.zeros((b, p), dtype=y1.dtype)], axis=-1)

        def aug_dyn(t, s, _):
            y = s[:, :feat]
            a = s[:, feat : 2 * feat]

            def single(ti, yi, ai):
                def fi(ti_, yi_, fp):
                    return f(ti_[None], yi_[None], unravel(fp))[0]

                fv, vjp_fn = jax.vjp(fi, ti, yi, flat_params)
                _, dy_bar, dp_bar = vjp_fn(ai)
                return fv, dy_bar, dp_bar

            fv, dy_bar, dp_bar = jax.vmap(single)(t, y, a)
            return jnp.concatenate([fv, -dy_bar, -dp_bar], axis=-1)

        return aug_dyn, aug0, t_end, t_start
    else:
        aug0 = jnp.concatenate([y1.ravel(), g.ravel(), jnp.zeros((p,), y1.dtype)])[None]

        def aug_dyn(t, s, _):
            y = s[0, : b * feat].reshape(b, feat)
            a = s[0, b * feat : 2 * b * feat].reshape(b, feat)
            tb = jnp.broadcast_to(t[0], (b,))

            def fy(y_, fp):
                return f(tb, y_, unravel(fp))

            fv, vjp_fn = jax.vjp(fy, y, flat_params)
            dy_bar, dp_bar = vjp_fn(a)
            return jnp.concatenate([fv.ravel(), -dy_bar.ravel(), -dp_bar])[None, :]

        return aug_dyn, aug0, jnp.asarray(t_end)[:1], jnp.asarray(t_start)[:1]


def make_adjoint_solve_dense(
    f: Callable,
    *,
    method: str = "dopri5",
    rtol=1e-3,
    atol=1e-6,
    max_steps: int = 10_000,
    controller=None,
):
    """Adjoint solve WITH evaluation points: ``solve(y0, t_eval, params) ->
    ys (b, n, f)``, differentiable w.r.t. y0 and params.

    The backward pass integrates the joint augmented ODE SEGMENT-WISE from
    t_n back to t_0 (a ``lax.scan`` over segments, each segment a full
    adaptive backsolve), injecting the incoming cotangent g[:, i] at each
    evaluation point -- torchode's dense-output adjoint, in JAX.  t_eval is
    shared across the batch (joint mode).
    """

    @jax.custom_vjp
    def _solve(y0, t_eval, params):
        sol = solve_ivp(
            f, y0, t_eval, method=method, rtol=rtol, atol=atol,
            max_steps=max_steps, controller=controller, args=params,
        )
        return sol.ys

    def _fwd(y0, t_eval, params):
        ys = _solve(y0, t_eval, params)
        return ys, (ys, t_eval, params)

    def _bwd(res, g):
        ys, t_eval, params = res
        b, n, feat = ys.shape
        flat_params, unravel = ravel_pytree(params)
        p = flat_params.shape[0]
        te = t_eval[0] if t_eval.ndim == 2 else t_eval  # joint: shared grid

        def aug_dyn(t, s, _):
            y = s[0, : b * feat].reshape(b, feat)
            a = s[0, b * feat : 2 * b * feat].reshape(b, feat)
            tb = jnp.broadcast_to(t[0], (b,))

            def fy(y_, fp):
                return f(tb, y_, unravel(fp))

            fv, vjp_fn = jax.vjp(fy, y, flat_params)
            dy_bar, dp_bar = vjp_fn(a)
            return jnp.concatenate([fv.ravel(), -dy_bar.ravel(), -dp_bar])[None, :]

        def segment(carry, xs):
            a, ap = carry  # (b, f), (p,)
            i = xs  # segment index, integrating te[i+1] -> te[i]
            a = a + g[:, i + 1]  # inject cotangent at the segment's right end
            y_seg = jax.lax.dynamic_index_in_dim(ys, i + 1, 1, keepdims=False)
            aug0 = jnp.concatenate([y_seg.ravel(), a.ravel(), ap])[None, :]
            sol = solve_ivp(
                aug_dyn, aug0, None, t_start=te[i + 1][None], t_end=te[i][None],
                method=method, rtol=rtol, atol=atol, max_steps=max_steps,
                controller=controller,
            )
            a_new = sol.ys[0, b * feat : 2 * b * feat].reshape(b, feat)
            ap_new = sol.ys[0, 2 * b * feat :]
            return (a_new, ap_new), None

        a0 = jnp.zeros((b, feat), ys.dtype)
        ap0 = jnp.zeros((p,), ys.dtype)
        (a_fin, ap_fin), _ = jax.lax.scan(
            segment, (a0, ap0), jnp.arange(n - 2, -1, -1)
        )
        a_fin = a_fin + g[:, 0]  # cotangent of the initial point (ys[:,0] == y0)
        return a_fin, jnp.zeros_like(t_eval), unravel(ap_fin)

    _solve.defvjp(_fwd, _bwd)

    def solve(y0, t_eval, params):
        y0 = jnp.asarray(y0)
        t_eval = jnp.asarray(t_eval)
        return _solve(y0, t_eval, params)

    return solve
