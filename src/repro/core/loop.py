"""Compatibility wrappers over the componentized solver core.

The monolithic while/scan solver that used to live here is decomposed into
``step.py`` (``StepFunction``: the shared ``init/step/finish`` triple,
``LoopState`` with the statistics registry) and ``drivers.py``
(``AutoDiffAdjoint`` / ``ScanAdjoint`` / ``BacksolveAdjoint``).  The
functions below preserve the original one-call API with unchanged signatures;
new code should compose the components directly::

    solver = AutoDiffAdjoint(Stepper("tsit5"), pid_controller())
    sol = solver.solve(f, y0, t_eval, args=args)
"""

from __future__ import annotations

import warnings
from typing import Any

from .drivers import AutoDiffAdjoint, ScanAdjoint
from .solution import Solution

# LoopState keeps its historical import path, but note its counter fields
# (n_steps/n_accepted/...) moved into the ``stats`` registry dict.
from .step import LoopState, StepFunction  # noqa: F401
from .stepper import AbstractStepper
from .terms import as_term


def make_solver(
    f,
    *,
    method: str = "dopri5",
    rtol=1e-3,
    atol=1e-6,
    controller=None,
    max_steps: int = 10_000,
    batched_term: bool = True,
    dense: bool = True,
    dense_window: int = 0,
    events=None,
    event_bisect_iters: int = 30,
    fused: bool = False,
):
    """Build (init_fn, body_fn, finish_fn) shared by the while_loop and scan
    drivers.  Compatibility shim over ``StepFunction``.

    ``max_steps`` is accepted for signature stability only: ``make_solver``
    hands back the bare function triple and the *caller* owns the loop, so the
    caller's loop bound is the only one that exists (compare
    ``AutoDiffAdjoint(..., max_steps=...)``, where the driver owns the loop).
    A non-default value would be silently ignored -- warn instead.
    """
    if max_steps != 10_000:
        warnings.warn(
            "make_solver ignores max_steps: it returns (init, step, finish) and "
            "the iteration bound belongs to the caller's loop. Bound your own "
            "while_loop/scan, or use solve_ivp / AutoDiffAdjoint(max_steps=...) "
            "which own their loop.",
            UserWarning,
            stacklevel=2,
        )
    del max_steps
    step_fn = StepFunction(
        as_term(f, batched=batched_term),
        AbstractStepper.coerce(method),
        controller,
        rtol=rtol,
        atol=atol,
        dense=dense,
        dense_window=dense_window,
        events=events,
        event_bisect_iters=event_bisect_iters,
        fused=fused,
    )
    return step_fn.init, step_fn.step, step_fn.finish


def solve_ivp(
    f,
    y0,
    t_eval=None,
    *,
    t_start=None,
    t_end=None,
    method: str = "dopri5",
    rtol=1e-3,
    atol=1e-6,
    controller=None,
    dt0=None,
    max_steps: int = 10_000,
    args: Any = None,
    batched_term: bool = True,
    dense: bool = True,
    dense_window: int = 0,
    events=None,
    event_bisect_iters: int = 30,
    fused: bool = False,
) -> Solution:
    """Solve a batch of IVPs in parallel with independent per-instance state.

    y0:     (batch, features) initial conditions, or any PyTree whose leaves
            carry the batch as their leading axis (ravelled at the term
            boundary; the vector field then receives per-instance PyTrees)
    t_eval: (n,) shared or (batch, n) per-instance evaluation points, or None to
            track only the final state (fastest; the CNF case in the paper)
    t_start/t_end: scalars or (batch,) vectors; default to t_eval boundaries.
            Integration ranges may differ per instance, including direction.
    method: a tableau name -- explicit ("dopri5", "tsit5", ...) or implicit
            ("kvaerno5", "kvaerno3", "trbdf2", "implicit_euler") for stiff
            problems; implicit names route through ``DiagonallyImplicitRK``.
    rtol/atol: scalars shared by the batch, or per-instance (b,) vectors --
            each instance is then held to its own tolerance by the error norm
            and the step-size controller (torchode's per-instance tolerances).
    events: an ``Event`` (or sequence of them) with per-instance scalar
            conditions ``cond_fn(t, y, args)``; terminal events stop each
            instance independently at its localized crossing time
            (``Status.EVENT``), and the Solution carries per-instance
            ``event_t`` / ``event_y`` / ``event_mask``.
    fused:  opt into the fused step megakernel fast path (one kernel-registry
            op per step attempt around the vf calls, zero vf launches for
            ``polynomial_term`` dynamics).  Engages for every explicit
            tableau (FSAL or not, adaptive or fixed-step) and for
            ``DiagonallyImplicitRK`` (factor-once chord Newton: one LU
            factorization per step, one fused launch per Newton iteration)
            under PID-family or fixed controllers, falling back transparently
            otherwise; ``stats["n_fused_steps"]`` reports whether it ran and
            ``stats["fused_fallback_reason"]`` (a ``FusedFallbackReason``
            value) reports why it did not.

    Returns a ``Solution`` with per-instance status and statistics.
    """
    driver = AutoDiffAdjoint(
        AbstractStepper.coerce(method),
        controller,
        rtol=rtol,
        atol=atol,
        max_steps=max_steps,
        dense=dense,
        dense_window=dense_window,
        batched_term=batched_term,
        events=events,
        event_bisect_iters=event_bisect_iters,
        fused=fused,
    )
    return driver.solve(f, y0, t_eval, t_start=t_start, t_end=t_end, dt0=dt0, args=args)


def solve_ivp_scan(
    f,
    y0,
    t_eval=None,
    *,
    t_start=None,
    t_end=None,
    method: str = "dopri5",
    rtol=1e-3,
    atol=1e-6,
    controller=None,
    dt0=None,
    max_steps: int = 256,
    args: Any = None,
    batched_term: bool = True,
    dense: bool = True,
    dense_window: int = 0,
    checkpoint_every: int = 0,
    events=None,
    event_bisect_iters: int = 30,
    fused: bool = False,
) -> Solution:
    """Reverse-mode-differentiable variant: a bounded ``lax.scan`` over
    ``max_steps`` iterations with masked no-op steps after termination
    (discretize-then-optimize).  ``checkpoint_every`` > 0 wraps blocks of steps
    in ``jax.checkpoint`` to trade recompute for memory on long solves.
    """
    driver = ScanAdjoint(
        AbstractStepper.coerce(method),
        controller,
        rtol=rtol,
        atol=atol,
        max_steps=max_steps,
        dense=dense,
        dense_window=dense_window,
        batched_term=batched_term,
        checkpoint_every=checkpoint_every,
        events=events,
        event_bisect_iters=event_bisect_iters,
        fused=fused,
    )
    return driver.solve(f, y0, t_eval, t_start=t_start, t_end=t_end, dt0=dt0, args=args)
