"""The batch-parallel adaptive solver loop (the paper's core contribution).

Every instance in the batch carries its own time, step size, controller
history, accept/reject decision and termination status.  The loop body is a
single fused XLA program driven by ``jax.lax.while_loop`` -- termination is an
on-device reduction, so there is never a host<->device synchronization inside
the loop (the GPU-sync avoidance torchode implements by hand in PyTorch).

Instances that finish early keep being *evaluated* (the dynamics run on the
full batch -- torchode's "overhanging evaluations") but their state is frozen
by masking, so results are unaffected.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .controller import ControllerState, FixedController, PIDController, integral_controller
from .solution import Solution, Status
from .stepper import initial_step_size, rk_step
from .tableau import get_tableau
from .terms import as_term


class LoopState(NamedTuple):
    t: jax.Array  # (b,) current time
    dt: jax.Array  # (b,) signed step proposal for the next attempt
    y: jax.Array  # (b, f)
    f0: jax.Array  # (b, f) FSAL derivative cache at (t, y)
    cstate: ControllerState
    running: jax.Array  # (b,) bool
    status: jax.Array  # (b,) int32
    n_steps: jax.Array  # (b,) int32
    n_accepted: jax.Array  # (b,) int32
    n_f_evals: jax.Array  # (b,) int32
    n_initialized: jax.Array  # (b,) int32
    ys: jax.Array  # (b, n, f) dense output buffer (or (b, 0, f) when unused)
    it: jax.Array  # () int32 global iteration counter


def _normalize_times(y0, t_eval, t_start, t_end, dtype):
    b = y0.shape[0]
    if t_eval is not None:
        t_eval = jnp.asarray(t_eval, dtype=dtype)
        if t_eval.ndim == 1:
            t_eval = jnp.broadcast_to(t_eval[None, :], (b, t_eval.shape[0]))
        if t_start is None:
            t_start = t_eval[:, 0]
        if t_end is None:
            t_end = t_eval[:, -1]
    if t_start is None or t_end is None:
        raise ValueError("need t_eval or (t_start, t_end)")
    t_start = jnp.broadcast_to(jnp.asarray(t_start, dtype=dtype), (b,))
    t_end = jnp.broadcast_to(jnp.asarray(t_end, dtype=dtype), (b,))
    return t_eval, t_start, t_end


def make_solver(
    f,
    *,
    method: str = "dopri5",
    rtol=1e-3,
    atol=1e-6,
    controller: PIDController | FixedController | None = None,
    max_steps: int = 10_000,
    batched_term: bool = True,
    dense: bool = True,
    dense_window: int = 0,
):
    """Build (init_fn, body_fn, finish_fn) shared by the while_loop and scan drivers."""
    term = as_term(f, batched=batched_term)
    tab = get_tableau(method)
    if controller is None:
        controller = FixedController() if tab.b_err is None else integral_controller()
    k = tab.error_order

    def init(y0, t_eval, t_start, t_end, dt0, args):
        y0 = jnp.asarray(y0)
        dtype = y0.dtype
        b, feat = y0.shape
        t_eval, t_start, t_end = _normalize_times(y0, t_eval, t_start, t_end, dtype)
        direction = jnp.sign(t_end - t_start)
        direction = jnp.where(direction == 0, jnp.ones_like(direction), direction)

        f0 = term.vf(t_start, y0, args)
        if dt0 is None:
            dt = initial_step_size(term, t_start, y0, f0, direction, tab.order, atol, rtol, args)
            n_init_evals = 2
        else:
            dt = jnp.broadcast_to(jnp.asarray(dt0, dtype=dtype), (b,)) * direction
            n_init_evals = 1

        if dense and t_eval is not None:
            n = t_eval.shape[1]
            ys = jnp.zeros((b, n, feat), dtype=dtype)
            # Pre-write all evaluation points at/before t_start (usually just the
            # first one) with the initial condition.
            pre = direction[:, None] * (t_eval - t_start[:, None]) <= 0.0
            ys = jnp.where(pre[:, :, None], y0[:, None, :], ys)
            n_initialized = pre.sum(axis=1).astype(jnp.int32)
        else:
            ys = jnp.zeros((b, 0, feat), dtype=dtype)
            n_initialized = jnp.zeros((b,), dtype=jnp.int32)

        state = LoopState(
            t=t_start,
            dt=dt,
            y=y0,
            f0=f0,
            cstate=controller.init(b, dtype),
            running=jnp.ones((b,), dtype=bool),
            status=jnp.zeros((b,), dtype=jnp.int32),
            n_steps=jnp.zeros((b,), dtype=jnp.int32),
            n_accepted=jnp.zeros((b,), dtype=jnp.int32),
            n_f_evals=jnp.full((b,), n_init_evals, dtype=jnp.int32),
            n_initialized=n_initialized,
            ys=ys,
            it=jnp.zeros((), dtype=jnp.int32),
        )
        return state, (t_eval, t_start, t_end, direction)

    def body(state: LoopState, consts, args) -> LoopState:
        t_eval, t_start, t_end, direction = consts
        tiny = jnp.asarray(jnp.finfo(state.y.dtype).tiny, state.y.dtype)
        eps = jnp.asarray(jnp.finfo(state.y.dtype).eps, state.y.dtype)

        any_running = jnp.any(state.running)

        windowed = dense and t_eval is not None and dense_window > 0
        if windowed:
            # --- windowed dense output (beyond-torchode optimization): only a
            # static window of W eval points at the per-instance cursor is
            # touched per step, instead of masking over ALL n points.  The
            # attempt is clamped so a step never crosses beyond the window's
            # last point (costs extra steps only when the solver could cross
            # >W points at once).  See EXPERIMENTS.md SSPerf (solver).
            n_pts = t_eval.shape[1]
            W = min(dense_window, n_pts)
            cursor = jnp.minimum(state.n_initialized, n_pts - W)  # (b,)
            t_win = jax.vmap(
                lambda te, c: jax.lax.dynamic_slice(te, (c,), (W,))
            )(t_eval, cursor)
            has_beyond = (state.n_initialized + W) < n_pts
            lim = jnp.where(has_beyond, t_win[:, -1] - state.t, t_end - state.t)
            clamp = has_beyond & (direction * lim > 0) & (jnp.abs(lim) < jnp.abs(state.dt))
            dt_prop = jnp.where(clamp, lim, state.dt)
        else:
            dt_prop = state.dt

        # --- clamp the attempt so the final step lands exactly on t_end ---
        rem = t_end - state.t
        will_finish = jnp.abs(dt_prop) >= jnp.abs(rem)
        dt_used = jnp.where(will_finish, rem, dt_prop)
        safe_dt = jnp.where(jnp.abs(dt_used) > tiny, dt_used, jnp.ones_like(dt_used))

        # --- one RK step for the whole batch ---
        res = rk_step(term, tab, state.t, safe_dt, state.y, state.f0, args)
        err_ratio = ops.error_norm(res.err, state.y, res.y1, atol, rtol)

        # --- per-instance accept/reject + next step proposal ---
        accept, dt_next, cstate_new = controller(err_ratio, state.dt, state.cstate, k)
        accept = accept & state.running

        t_new = jnp.where(will_finish, t_end, state.t + dt_used)
        done_now = accept & will_finish

        # step-size floor: instances whose step collapses are stopped
        dt_floor = 8.0 * eps * jnp.maximum(jnp.abs(state.t), jnp.abs(t_end))
        nonfinite_y = ~jnp.all(jnp.isfinite(res.y1), axis=-1)
        stopped = state.running & ~accept & (jnp.abs(dt_next) <= dt_floor)

        # --- dense output: write every eval point passed by this step ---
        ys = state.ys
        n_initialized = state.n_initialized
        if windowed:
            coeffs = ops.hermite_coeffs(state.y, res.y1, state.f0, res.f1, safe_dt)
            xw = jnp.clip((t_win - state.t[:, None]) / safe_dt[:, None], 0.0, 1.0)
            after_t = direction[:, None] * (t_win - state.t[:, None]) > 0.0
            upto_new = direction[:, None] * (t_win - t_new[:, None]) <= 0.0
            maskw = accept[:, None] & after_t & upto_new
            feat = ys.shape[-1]
            cur = jax.vmap(
                lambda row, c: jax.lax.dynamic_slice(row, (c, 0), (W, feat))
            )(ys, cursor)
            merged = ops.interp_eval(coeffs, xw, maskw, cur)
            ys = jax.vmap(
                lambda row, m, c: jax.lax.dynamic_update_slice(row, m, (c, 0))
            )(ys, merged, cursor)
            n_initialized = n_initialized + maskw.sum(axis=1).astype(jnp.int32)
        elif dense and t_eval is not None:
            coeffs = ops.hermite_coeffs(state.y, res.y1, state.f0, res.f1, safe_dt)
            x = (t_eval - state.t[:, None]) / safe_dt[:, None]
            x = jnp.clip(x, 0.0, 1.0)  # masked points stay finite (grad-safe)
            after_t = direction[:, None] * (t_eval - state.t[:, None]) > 0.0
            upto_new = direction[:, None] * (t_eval - t_new[:, None]) <= 0.0
            mask = accept[:, None] & after_t & upto_new
            ys = ops.interp_eval(coeffs, x, mask, ys)
            n_initialized = n_initialized + mask.sum(axis=1).astype(jnp.int32)

        # --- masked commit ---
        acc_f = accept[:, None]
        y = jnp.where(acc_f, res.y1, state.y)
        f0 = jnp.where(acc_f, res.f1, state.f0)
        t = jnp.where(accept, t_new, state.t)
        dt = jnp.where(state.running, dt_next, state.dt)

        running = state.running & ~done_now & ~stopped
        status = jnp.where(
            done_now,
            Status.SUCCESS.value,
            jnp.where(
                stopped,
                jnp.where(nonfinite_y, Status.INFINITE.value, Status.REACHED_DT_MIN.value),
                state.status,
            ),
        ).astype(jnp.int32)

        inc = jnp.where(any_running, 1, 0).astype(jnp.int32)
        return LoopState(
            t=t,
            dt=dt,
            y=y,
            f0=f0,
            cstate=cstate_new if not isinstance(controller, FixedController) else state.cstate,
            running=running,
            status=status,
            n_steps=state.n_steps + inc * state.running.astype(jnp.int32),
            n_accepted=state.n_accepted + accept.astype(jnp.int32),
            # torchode semantics: dynamics are evaluated on the full batch while
            # any instance is running ("overhanging evaluations"), so the count
            # is shared across the batch.
            n_f_evals=state.n_f_evals + inc * (res.n_f_evals),
            n_initialized=n_initialized,
            ys=ys,
            it=state.it + inc,
        )

    def finish(state: LoopState, consts) -> Solution:
        t_eval, t_start, t_end, direction = consts
        status = jnp.where(
            state.running, Status.REACHED_MAX_STEPS.value, state.status
        ).astype(jnp.int32)
        stats = {
            "n_steps": state.n_steps,
            "n_accepted": state.n_accepted,
            "n_f_evals": state.n_f_evals,
            "n_initialized": state.n_initialized,
        }
        if dense and t_eval is not None:
            return Solution(ts=t_eval, ys=state.ys, status=status, stats=stats)
        return Solution(ts=t_end, ys=state.y, status=status, stats=stats)

    return init, body, finish


def solve_ivp(
    f,
    y0,
    t_eval=None,
    *,
    t_start=None,
    t_end=None,
    method: str = "dopri5",
    rtol=1e-3,
    atol=1e-6,
    controller=None,
    dt0=None,
    max_steps: int = 10_000,
    args: Any = None,
    batched_term: bool = True,
    dense: bool = True,
    dense_window: int = 0,
) -> Solution:
    """Solve a batch of IVPs in parallel with independent per-instance state.

    y0:     (batch, features) initial conditions
    t_eval: (n,) shared or (batch, n) per-instance evaluation points, or None to
            track only the final state (fastest; the CNF case in the paper)
    t_start/t_end: scalars or (batch,) vectors; default to t_eval boundaries.
            Integration ranges may differ per instance, including direction.

    Returns a ``Solution`` with per-instance status and statistics.
    """
    init, body, finish = make_solver(
        f,
        method=method,
        rtol=rtol,
        atol=atol,
        controller=controller,
        max_steps=max_steps,
        batched_term=batched_term,
        dense=dense,
        dense_window=dense_window,
    )
    state, consts = init(jnp.asarray(y0), t_eval, t_start, t_end, dt0, args)

    state = jax.lax.while_loop(
        lambda s: jnp.any(s.running) & (s.it < max_steps),
        lambda s: body(s, consts, args),
        state,
    )
    return finish(state, consts)


def solve_ivp_scan(
    f,
    y0,
    t_eval=None,
    *,
    t_start=None,
    t_end=None,
    method: str = "dopri5",
    rtol=1e-3,
    atol=1e-6,
    controller=None,
    dt0=None,
    max_steps: int = 256,
    args: Any = None,
    batched_term: bool = True,
    dense: bool = True,
    dense_window: int = 0,
    checkpoint_every: int = 0,
) -> Solution:
    """Reverse-mode-differentiable variant: a bounded ``lax.scan`` over
    ``max_steps`` iterations with masked no-op steps after termination
    (discretize-then-optimize).  ``checkpoint_every`` > 0 wraps blocks of steps
    in ``jax.checkpoint`` to trade recompute for memory on long solves.
    """
    init, body, finish = make_solver(
        f,
        method=method,
        rtol=rtol,
        atol=atol,
        controller=controller,
        max_steps=max_steps,
        batched_term=batched_term,
        dense=dense,
        dense_window=dense_window,
    )
    state, consts = init(jnp.asarray(y0), t_eval, t_start, t_end, dt0, args)

    def scan_body(s, _):
        return body(s, consts, args), None

    if checkpoint_every and checkpoint_every > 0:
        blocks, rem = divmod(max_steps, checkpoint_every)

        def block_body(s, _):
            s, _ = jax.lax.scan(scan_body, s, None, length=checkpoint_every)
            return s, None

        state, _ = jax.lax.scan(jax.checkpoint(block_body), state, None, length=blocks)
        if rem:
            state, _ = jax.lax.scan(scan_body, state, None, length=rem)
    else:
        state, _ = jax.lax.scan(scan_body, state, None, length=max_steps)
    return finish(state, consts)
