"""The shared ``init/step/finish`` step function of the batch-parallel solver.

``StepFunction`` composes the three swappable components -- ``ODETerm``
(dynamics), a stepper (``ExplicitRK`` / ``DiagonallyImplicitRK``: tableau +
stage recursion + interpolant) and a controller -- into one adaptive solver
step for the whole batch.  Steppers may thread private cross-step state
(``LoopState.scarry``, e.g. the implicit stepper's reused Jacobian and its
per-instance refresh mask) and report per-instance nonlinear-solver failure,
which the loop turns into an ordinary controller reject by forcing that
instance's error ratio to infinity.  The drivers in
``drivers.py`` iterate it with ``lax.while_loop`` / bounded ``lax.scan``;
``make_solver`` in ``loop.py`` exposes the bare function triple for callers
that build their own loop.

Every instance in the batch carries its own time, step size, controller
history, accept/reject decision, termination status and (when events are
registered) event bookkeeping: sign changes of each event condition are
detected on accepted steps and localized by masked bisection on the step's
dense-output interpolant (``core/events.py``), and a fired terminal event
stops that instance at the interpolated event state with ``Status.EVENT``.  The body is a single
fused XLA program -- termination is an on-device reduction, so there is never
a host<->device synchronization inside the loop (the GPU-sync avoidance
torchode implements by hand in PyTorch).  Instances that finish early keep
being *evaluated* (the dynamics run on the full batch -- torchode's
"overhanging evaluations") but their state is frozen by masking, so results
are unaffected.

Statistics registry
-------------------
``LoopState.stats`` is a dict of named per-instance ``(b,)`` accumulators
instead of hard-coded counter fields.  Each component contributes entries via
an ``init_stats(batch) -> dict`` hook and advances them in
``update_stats(stats, ctx) -> dict``, where ``ctx`` is a ``StepContext``
describing the step just taken.  The stepper records ``n_f_evals``, the
controller ``n_accepted``, the step function itself ``n_steps`` and
``n_initialized``; user code can register additional contributors through
``extra_stats`` to record any solver-internal metric (paper Sec. 3's
per-instance stats, generalized).
"""

from __future__ import annotations

import enum
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .controller import (
    ControllerState,
    FixedController,
    _ControllerStats,
    integral_controller,
)
from .events import advance as advance_events
from .events import init_event_state, normalize_events
from .controller import PIDController
from .solution import Solution, Status
from .static import freeze, frozen_setattr, register_config_pytree
from .stepper import AbstractStepper, DiagonallyImplicitRK, ExplicitRK, _tableau_arrays
from .terms import ODETerm, as_term


class FusedFallbackReason(enum.IntEnum):
    """Machine-readable reason the ``fused=True`` fast path disengaged.

    Recorded per instance in ``Solution.stats["fused_fallback_reason"]``
    whenever ``fused=True`` was *requested* (ENGAGED means it actually ran),
    so callers can monitor silently-degraded configurations instead of
    diffing launch counts.  The codes are static config properties -- every
    instance in a batch carries the same value.
    """

    ENGAGED = 0
    # The stepper is not (exactly) ExplicitRK or DiagonallyImplicitRK:
    # subclasses may override the stage recursion the kernel bakes in.
    NOT_EXPLICIT_RK = 1
    # The controller is not (exactly) PIDController or FixedController:
    # the kernel bakes in those two accept/next-dt programs only, and
    # subclasses may override ``__call__``.
    UNSUPPORTED_CONTROLLER = 2
    # The stepper is a DiagonallyImplicitRK SUBCLASS: the fused implicit
    # path bakes in the exact factor-once chord-Newton stage sweep, which a
    # subclass may override.
    UNSUPPORTED_IMPLICIT = 3


class LoopState(NamedTuple):
    t: jax.Array  # (b,) current time
    dt: jax.Array  # (b,) signed step proposal for the next attempt
    y: jax.Array  # (b, f)
    f0: jax.Array  # (b, f) FSAL derivative cache at (t, y)
    scarry: Any  # stepper cross-step carry (() for explicit, Jacobian for DIRK)
    cstate: ControllerState
    running: jax.Array  # (b,) bool
    status: jax.Array  # (b,) int32
    stats: dict[str, jax.Array]  # named (b,) accumulators (statistics registry)
    ys: jax.Array  # (b, n, f) dense output buffer (or (b, 0, f) when unused)
    it: jax.Array  # () int32 global iteration counter
    estate: Any = ()  # per-instance event bookkeeping (EventState, or () without events)


class StepContext(NamedTuple):
    """What a statistics contributor may observe about the step just taken."""

    running: jax.Array  # (b,) bool: running mask *before* this step
    accept: jax.Array  # (b,) bool: accepted this step (masked by running)
    step_active: jax.Array  # () int32: 1 while any instance runs (overhanging evals)
    n_f_evals: Any  # dynamics-evaluation count of this step (int or () int32)
    n_written: jax.Array  # (b,) int32: dense-output points written this step
    err_ratio: jax.Array  # (b,) weighted RMS error ratio of this step
    aux: dict | None = None  # stepper-private extras (e.g. Newton iteration counts)
    n_events: jax.Array | None = None  # (b,) int32: events recorded this step


def _normalize_times(y0, t_eval, t_start, t_end, dtype):
    b = y0.shape[0]
    if t_eval is not None:
        t_eval = jnp.asarray(t_eval, dtype=dtype)
        if t_eval.ndim == 1:
            t_eval = jnp.broadcast_to(t_eval[None, :], (b, t_eval.shape[0]))
        if t_start is None:
            t_start = t_eval[:, 0]
        if t_end is None:
            t_end = t_eval[:, -1]
    if t_start is None or t_end is None:
        raise ValueError("need t_eval or (t_start, t_end)")
    t_start = jnp.broadcast_to(jnp.asarray(t_start, dtype=dtype), (b,))
    t_end = jnp.broadcast_to(jnp.asarray(t_end, dtype=dtype), (b,))
    return t_eval, t_start, t_end


class StepFunction:
    """One adaptive solver step for the whole batch, on flat (b, f) buffers.

    PyTree states are ravelled *before* they reach this class (see
    ``terms.ravel_state`` / the drivers); the hot loop and the Pallas kernels
    only ever see flat arrays.

    Static/dynamic split: a ``StepFunction`` is frozen after construction and
    pytree-registered so ``init``/``step``/``finish`` are pure functions of
    ``(static config, dynamic state)`` -- there is no mutable Python-object
    state in the hot path.  Flattening yields exactly two leaves, ``rtol`` and
    ``atol`` (scalars or per-instance vectors; free to vary without a
    retrace); the term, stepper, controller, event specs and layout flags ride
    in the treedef as hashable aux data, so passing a ``StepFunction`` (or a
    driver holding one) through ``jax.jit`` keys the compilation cache on the
    static config by value.
    """

    __setattr__ = frozen_setattr

    def __init__(
        self,
        term: ODETerm,
        stepper: AbstractStepper | str | None = None,
        controller=None,
        *,
        rtol=1e-3,
        atol=1e-6,
        dense: bool = True,
        dense_window: int = 0,
        events=None,
        event_bisect_iters: int = 30,
        extra_stats: tuple = (),
        fused: bool = False,
    ):
        self.term = as_term(term)
        stepper = self.stepper = AbstractStepper.coerce(stepper)
        if controller is None:
            controller = integral_controller() if stepper.is_adaptive else FixedController()
        self.controller = controller
        self.rtol = rtol
        self.atol = atol
        self.dense = dense
        self.dense_window = dense_window
        self.events = normalize_events(events)
        self.event_bisect_iters = event_bisect_iters
        self.extra_stats = tuple(extra_stats)
        self.fused = bool(fused)
        # The fused megakernel fast path engages for EVERY configuration the
        # kernel's baked-in programs cover: any explicit tableau (FSAL or
        # not, adaptive or fixed -- non-FSAL trailing evaluations fold in) OR
        # any diagonally-implicit tableau (the factor-once chord-Newton sweep
        # runs one ``fused_newton_iter`` launch per iteration and hands the
        # megakernel its ``failed`` mask), driven by exactly PIDController
        # (``ctrl_mode="pid"``) or exactly FixedController
        # (``ctrl_mode="fixed"``).  Exact-type checks, not isinstance:
        # subclasses may override ``__call__``/``step`` with programs the
        # kernel does not bake in.  Everything else falls back to the unfused
        # path transparently -- same results, one launch per op instead of
        # one per step -- and records why in ``fused_fallback_reason``.
        mode, why = None, FusedFallbackReason.ENGAGED
        implicit = False
        if type(stepper) is ExplicitRK:
            pass
        elif type(stepper) is DiagonallyImplicitRK:
            implicit = True
        elif isinstance(stepper, DiagonallyImplicitRK):
            why = FusedFallbackReason.UNSUPPORTED_IMPLICIT
        else:
            why = FusedFallbackReason.NOT_EXPLICIT_RK
        if why is FusedFallbackReason.ENGAGED:
            if type(self.controller) is PIDController:
                mode = "pid"
            elif type(self.controller) is FixedController:
                mode = "fixed"
            else:
                why = FusedFallbackReason.UNSUPPORTED_CONTROLLER
        self._fused_mode = mode if self.fused else None
        self._fused_fallback = int(why)
        self._fused_path = self._fused_mode is not None
        self._fused_implicit = implicit and self._fused_path
        self._rebuild_derived()
        freeze(self)

    def _rebuild_derived(self) -> None:
        """Build the statistics-contributor tuple (also called when a pytree
        unflatten reconstructs the instance: the tuple holds a back-reference
        to ``self``, so it cannot ride in the aux data).

        Registry order: component contributions first, loop bookkeeping last.
        Duck-typed controllers predating the registry (init/__call__ only)
        still get n_accepted recorded -- it was unconditional before and the
        Solution.stats contract promises it."""
        controller_stats = (
            self.controller if hasattr(self.controller, "init_stats") else _ControllerStats()
        )
        object.__setattr__(
            self, "stat_contributors",
            (self.stepper, controller_stats, self, *self.extra_stats),
        )

    # --- the step function's own statistics contribution ---
    def init_stats(self, batch: int) -> dict[str, jax.Array]:
        zeros = jnp.zeros((batch,), dtype=jnp.int32)
        out = {"n_steps": zeros, "n_initialized": zeros}
        if self.events:
            out["n_events"] = zeros
        if self.fused:
            # Why (or that) the requested fast path (dis)engaged -- a static
            # config property, broadcast so it lands in the per-instance
            # stats surface the serving stack already exports.
            out["fused_fallback_reason"] = jnp.full(
                (batch,), self._fused_fallback, dtype=jnp.int32
            )
        if self._fused_path:
            # Counts steps taken through the megakernel; equals n_steps while
            # the fast path is engaged (the observable proof it actually ran).
            out["n_fused_steps"] = zeros
        return out

    def update_stats(self, stats: dict, ctx: StepContext) -> dict:
        out = {
            **stats,
            "n_steps": stats["n_steps"] + ctx.step_active * ctx.running.astype(jnp.int32),
            "n_initialized": stats["n_initialized"] + ctx.n_written,
        }
        if ctx.n_events is not None:
            out["n_events"] = stats["n_events"] + ctx.n_events
        return out

    def _collect_init_stats(self, batch: int) -> dict[str, jax.Array]:
        stats: dict[str, jax.Array] = {}
        for c in self.stat_contributors:
            hook = getattr(c, "init_stats", None)
            if hook is not None:
                for name, acc in hook(batch).items():
                    if name in stats:
                        raise ValueError(f"duplicate statistic {name!r} in registry")
                    stats[name] = acc
        return stats

    def _apply_stat_updates(self, stats: dict, ctx: StepContext) -> dict:
        for c in self.stat_contributors:
            hook = getattr(c, "update_stats", None)
            if hook is not None:
                stats = hook(stats, ctx)
        return stats

    def _scale(self, y: jax.Array) -> jax.Array:
        """The (b, f) error scale atol + rtol*|y| shared by the acceptance
        test and the Newton convergence test.  Tolerances may be scalars,
        per-instance (b,) vectors or full (b, f) arrays."""
        atol, rtol = ops.broadcast_tolerances(self.atol, self.rtol, y.dtype)
        return atol + rtol * jnp.abs(y)

    def init(self, y0, t_eval=None, t_start=None, t_end=None, dt0=None, args=None):
        """Build the initial LoopState.  Returns ``(state, consts)`` where
        ``consts = (t_eval, t_start, t_end, direction)`` is loop-invariant."""
        y0 = jnp.asarray(y0)
        dtype = y0.dtype
        b, feat = y0.shape
        t_eval, t_start, t_end = _normalize_times(y0, t_eval, t_start, t_end, dtype)
        direction = jnp.sign(t_end - t_start)
        direction = jnp.where(direction == 0, jnp.ones_like(direction), direction)

        f0 = self.stepper.init(self.term, t_start, y0, args)
        if dt0 is None:
            # The proposal is clamped to the controller's step bounds so an
            # over-eager heuristic can never violate dt_min/dt_max.
            dt = self.stepper.initial_step_size(
                self.term, t_start, y0, f0, direction, self.atol, self.rtol, args,
                dt_min=getattr(self.controller, "dt_min", 0.0),
                dt_max=getattr(self.controller, "dt_max", float("inf")),
            )
            n_init_evals = 2
        else:
            dt = jnp.broadcast_to(jnp.asarray(dt0, dtype=dtype), (b,)) * direction
            n_init_evals = 1

        if self.dense and t_eval is not None:
            n = t_eval.shape[1]
            ys = jnp.zeros((b, n, feat), dtype=dtype)
            # Pre-write all evaluation points at/before t_start (usually just the
            # first one) with the initial condition.
            pre = direction[:, None] * (t_eval - t_start[:, None]) <= 0.0
            ys = jnp.where(pre[:, :, None], y0[:, None, :], ys)
            n_initialized = pre.sum(axis=1).astype(jnp.int32)
        else:
            ys = jnp.zeros((b, 0, feat), dtype=dtype)
            n_initialized = jnp.zeros((b,), dtype=jnp.int32)

        stats = self._collect_init_stats(b)
        stats["n_f_evals"] = stats["n_f_evals"] + n_init_evals
        stats["n_initialized"] = stats["n_initialized"] + n_initialized

        state = LoopState(
            t=t_start,
            dt=dt,
            y=y0,
            f0=f0,
            scarry=self.stepper.init_carry(self.term, t_start, y0, f0, args),
            cstate=self.controller.init(b, dtype),
            running=jnp.ones((b,), dtype=bool),
            status=jnp.zeros((b,), dtype=jnp.int32),
            stats=stats,
            ys=ys,
            it=jnp.zeros((), dtype=jnp.int32),
            estate=(
                init_event_state(self.events, t_start, y0, args) if self.events else ()
            ),
        )
        return state, (t_eval, t_start, t_end, direction)

    def _propose(self, state: LoopState, consts):
        """The per-instance step proposal -- the shared prologue of the fused
        and unfused paths.  Returns ``(dt_prop, cursor, t_win, W)``; the last
        three are ``(None, None, 0)`` unless windowed dense output is active
        (``t_win is not None`` is the windowed-mode flag downstream).

        Windowed dense output (beyond-torchode optimization): only a static
        window of W eval points at the per-instance cursor is touched per
        step, instead of masking over ALL n points.  The attempt is clamped
        so a step never crosses beyond the window's last point (costs extra
        steps only when the solver could cross >W points at once).  See
        EXPERIMENTS.md SSPerf (solver)."""
        t_eval, t_start, t_end, direction = consts
        if not (self.dense and t_eval is not None and self.dense_window > 0):
            return state.dt, None, None, 0
        n_pts = t_eval.shape[1]
        W = min(self.dense_window, n_pts)
        cursor = jnp.minimum(state.stats["n_initialized"], n_pts - W)  # (b,)
        t_win = jax.vmap(
            lambda te, c: jax.lax.dynamic_slice(te, (c,), (W,))
        )(t_eval, cursor)
        has_beyond = (state.stats["n_initialized"] + W) < n_pts
        lim = jnp.where(has_beyond, t_win[:, -1] - state.t, t_end - state.t)
        clamp = has_beyond & (direction * lim > 0) & (jnp.abs(lim) < jnp.abs(state.dt))
        return jnp.where(clamp, lim, state.dt), cursor, t_win, W

    def _write_dense(self, state, consts, coeffs, accept, t_stop, safe_dt, cursor, t_win, W):
        """Write every eval point passed by this step into the dense-output
        buffer (windowed or full-mask; shared by the fused and unfused
        paths).  Returns ``(ys, n_written)``."""
        t_eval, t_start, t_end, direction = consts
        ys = state.ys
        n_written = jnp.zeros_like(state.running, dtype=jnp.int32)
        if t_win is not None:
            xw = jnp.clip((t_win - state.t[:, None]) / safe_dt[:, None], 0.0, 1.0)
            after_t = direction[:, None] * (t_win - state.t[:, None]) > 0.0
            upto_new = direction[:, None] * (t_win - t_stop[:, None]) <= 0.0
            maskw = accept[:, None] & after_t & upto_new
            feat = ys.shape[-1]
            cur = jax.vmap(
                lambda row, c: jax.lax.dynamic_slice(row, (c, 0), (W, feat))
            )(ys, cursor)
            merged = ops.interp_eval(coeffs, xw, maskw, cur)
            ys = jax.vmap(
                lambda row, m, c: jax.lax.dynamic_update_slice(row, m, (c, 0))
            )(ys, merged, cursor)
            n_written = maskw.sum(axis=1).astype(jnp.int32)
        elif self.dense and t_eval is not None:
            x = (t_eval - state.t[:, None]) / safe_dt[:, None]
            x = jnp.clip(x, 0.0, 1.0)  # masked points stay finite (grad-safe)
            after_t = direction[:, None] * (t_eval - state.t[:, None]) > 0.0
            upto_new = direction[:, None] * (t_eval - t_stop[:, None]) <= 0.0
            mask = accept[:, None] & after_t & upto_new
            ys = ops.interp_eval(coeffs, x, mask, ys)
            n_written = mask.sum(axis=1).astype(jnp.int32)
        return ys, n_written

    def step(self, state: LoopState, consts, args) -> LoopState:
        if self._fused_path:
            return self._step_fused(state, consts, args)
        term, stepper, controller = self.term, self.stepper, self.controller
        k = stepper.error_order
        t_eval, t_start, t_end, direction = consts
        tiny = jnp.asarray(jnp.finfo(state.y.dtype).tiny, state.y.dtype)
        eps = jnp.asarray(jnp.finfo(state.y.dtype).eps, state.y.dtype)

        any_running = jnp.any(state.running)

        dt_prop, cursor, t_win, W = self._propose(state, consts)

        # --- clamp the attempt so the final step lands exactly on t_end ---
        rem = t_end - state.t
        will_finish = jnp.abs(dt_prop) >= jnp.abs(rem)
        dt_used = jnp.where(will_finish, rem, dt_prop)
        safe_dt = jnp.where(jnp.abs(dt_used) > tiny, dt_used, jnp.ones_like(dt_used))

        # --- one RK step for the whole batch ---
        res = stepper.step(
            term, state.t, safe_dt, state.y, state.f0, args,
            carry=state.scarry, scale=self._scale(state.y),
        )
        err_ratio = ops.error_norm(res.err, state.y, res.y1, self.atol, self.rtol)
        if res.solver_failed is not None:
            # Nonlinear-solver divergence flows through the ordinary
            # controller reject path: an infinite error ratio is a hard
            # reject that shrinks that instance's step and retries.
            err_ratio = jnp.where(res.solver_failed, jnp.inf, err_ratio)

        # --- per-instance accept/reject + next step proposal ---
        accept, dt_next, cstate_new = controller(err_ratio, state.dt, state.cstate, k)
        accept = accept & state.running
        if res.solver_failed is not None:
            # A failed nonlinear solve must never be committed, even by an
            # always-accept controller (FixedController): the iterate is
            # garbage.  Under a fixed step this retries until max_steps, a
            # visible failure instead of a silently wrong SUCCESS.
            accept = accept & ~res.solver_failed

        t_new = jnp.where(will_finish, t_end, state.t + dt_used)
        done_now = accept & will_finish

        # step-size floor: instances whose step collapses are stopped
        dt_floor = 8.0 * eps * jnp.maximum(jnp.abs(state.t), jnp.abs(t_end))
        nonfinite_y = ~jnp.all(jnp.isfinite(res.y1), axis=-1)
        stopped = state.running & ~accept & (jnp.abs(dt_next) <= dt_floor)

        # The dense-output interpolant of this step is shared by the eval-point
        # writer and the event localizer.
        dense_now = self.dense and t_eval is not None
        if dense_now or self.events:
            coeffs = stepper.interp_coeffs(state.y, res.y1, state.f0, res.f1, safe_dt)

        # --- events: detect sign changes on accepted steps, localize by
        # masked bisection on the interpolant (zero extra vf evaluations),
        # stop instances whose terminal event fired ---
        if self.events:
            adv = advance_events(
                self.events, state.estate, coeffs, state.t, safe_dt, t_new,
                res.y1, accept, args, self.event_bisect_iters,
            )
            estate, event_stop = adv.estate, adv.stop
            # Dense output and the committed state are truncated at the
            # earliest terminal event time.
            t_stop = jnp.where(event_stop, adv.t_stop, t_new)
        else:
            adv, estate = None, state.estate
            event_stop = jnp.zeros_like(accept)
            t_stop = t_new

        # --- dense output: write every eval point passed by this step ---
        ys, n_written = self._write_dense(
            state, consts, coeffs if (dense_now or self.events) else None,
            accept, t_stop, safe_dt, cursor, t_win, W,
        )

        # --- masked commit ---
        acc_f = accept[:, None]
        y = jnp.where(acc_f, res.y1, state.y)
        f0 = jnp.where(acc_f, res.f1, state.f0)
        t = jnp.where(accept, t_new, state.t)
        dt = jnp.where(state.running, dt_next, state.dt)
        if self.events:
            # An event-stopped instance rests AT the event: its committed
            # state is the interpolated (event_t, event_y), not (t_new, y1).
            y = jnp.where(event_stop[:, None], adv.y_stop, y)
            t = jnp.where(event_stop, t_stop, t)

        running = state.running & ~done_now & ~stopped & ~event_stop
        status = jnp.where(
            event_stop,
            Status.EVENT.value,
            jnp.where(
                done_now,
                Status.SUCCESS.value,
                jnp.where(
                    stopped,
                    jnp.where(nonfinite_y, Status.INFINITE.value, Status.REACHED_DT_MIN.value),
                    state.status,
                ),
            ),
        ).astype(jnp.int32)

        inc = jnp.where(any_running, 1, 0).astype(jnp.int32)
        ctx = StepContext(
            running=state.running,
            accept=accept,
            step_active=inc,
            n_f_evals=res.n_f_evals,
            n_written=n_written,
            err_ratio=err_ratio,
            aux=res.stats_aux,
            n_events=adv.n_new if adv is not None else None,
        )
        stats = self._apply_stat_updates(dict(state.stats), ctx)

        return LoopState(
            t=t,
            dt=dt,
            y=y,
            f0=f0,
            scarry=stepper.commit_carry(state.scarry, res.carry, accept, state.running),
            # Every controller returns its own next state (masking non-advances
            # internally), so the loop threads it uniformly -- no special cases.
            cstate=cstate_new,
            running=running,
            status=status,
            stats=stats,
            ys=ys,
            it=state.it + inc,
            estate=estate,
        )

    def _step_fused(self, state: LoopState, consts, args) -> LoopState:
        """The fused fast path: everything between the stage evaluations and
        the loop-state rebuild -- b_sol/b_err combination, WRMS error norm,
        PI controller accept/next-dt, masked commit of (t, y, f) against the
        ``running`` mask, and the Hermite coefficient build -- is ONE
        kernel-registry op (``ops.fused_step``).  For ``PolynomialTerm``
        dynamics the stage evaluations fuse too (``ops.fused_step_poly``):
        the whole step attempt is a single launch with zero vf dispatches.

        Mirrors ``step`` expression-for-expression (the ref-backend op is
        composed of the same primitives in the same order, so fused and
        unfused solves are bitwise-identical there); only engaged when
        ``_fused_path`` holds (``ExplicitRK`` or ``DiagonallyImplicitRK`` --
        any registered tableau -- driven by ``PIDController`` or
        ``FixedController``).  Non-FSAL tableaus fold their trailing
        evaluation in: the polynomial megakernel runs it as one more
        in-kernel Horner pass, general terms evaluate ``vf`` once between
        the stage sweep and the kernel (exactly like ``rk_step``, on every
        attempt).  Diagonally-implicit steppers run the factor-once
        chord-Newton sweep (``fused_stage_parts``) and thread the
        per-instance ``solver_failed`` mask through the kernel's ``failed=``
        input, which forces an infinite error ratio BEFORE the controller
        and excludes those instances from ``accept`` -- the same
        divergence-to-reject contract as the unfused path, kept in-kernel.
        """
        term, stepper, controller = self.term, self.stepper, self.controller
        t_eval, t_start, t_end, direction = consts
        tiny = jnp.asarray(jnp.finfo(state.y.dtype).tiny, state.y.dtype)
        eps = jnp.asarray(jnp.finfo(state.y.dtype).eps, state.y.dtype)

        any_running = jnp.any(state.running)
        dt_prop, cursor, t_win, W = self._propose(state, consts)

        rem = t_end - state.t
        will_finish = jnp.abs(dt_prop) >= jnp.abs(rem)
        dt_used = jnp.where(will_finish, rem, dt_prop)
        safe_dt = jnp.where(jnp.abs(dt_used) > tiny, dt_used, jnp.ones_like(dt_used))
        t_new = jnp.where(will_finish, t_end, state.t + dt_used)

        dense_now = self.dense and t_eval is not None
        want_coeffs = bool(dense_now or self.events)
        tab = stepper.tableau
        mode = self._fused_mode
        ctrl = controller.filter_params(stepper.error_order)
        # Fixed-step tableaus have no embedded estimate: zero error weights
        # (the in-kernel norm is then 0, exactly like the unfused path).
        _, _, b_sol_w, b_err_w = _tableau_arrays(tab, state.y.dtype)
        common = (
            state.t, t_new, state.dt, safe_dt, state.running,
            state.cstate.prev_inv_ratio, state.cstate.prev2_inv_ratio,
            self.atol, self.rtol,
        )
        poly = getattr(term, "poly_coeffs", ()) if not self._fused_implicit else ()
        scarry_new, solver_failed, stats_aux = state.scarry, None, None
        if self._fused_implicit:
            (K, f1, n_f_evals, carry_prop, solver_failed,
             stats_aux) = stepper.fused_stage_parts(
                term, state.t, safe_dt, state.y, state.f0, args,
                carry=state.scarry, scale=self._scale(state.y),
            )
            out = ops.fused_step(
                state.y, K, f1, *common,
                b_sol=b_sol_w, b_err=b_err_w, ctrl=ctrl,
                want_coeffs=want_coeffs, ctrl_mode=mode, failed=solver_failed,
            )
        elif poly:
            out = ops.fused_step_poly(
                state.y, state.f0, *common,
                a=tab.a, c=tab.c, b_sol=b_sol_w, b_err=b_err_w,
                poly=poly, ctrl=ctrl, want_coeffs=want_coeffs,
                fsal=tab.fsal, ctrl_mode=mode,
            )
            # The in-kernel stage evaluations count exactly like the unfused
            # vf calls they replace (FSAL: the first stage is the cache;
            # non-FSAL: one more for the in-kernel trailing evaluation).
            n_f_evals = tab.stages - 1 + (0 if tab.fsal else 1)
        else:
            K, n_f_evals = stepper.stage_derivatives(
                term, state.t, safe_dt, state.y, state.f0, args
            )
            if tab.fsal:
                f1 = K[-1]
            else:
                # User vector fields cannot fuse: the trailing evaluation is
                # the one launch between the stage sweep and the megakernel.
                f1, extra = stepper.trailing_derivative(
                    term, state.t, safe_dt, state.y, K, args
                )
                n_f_evals += extra
            out = ops.fused_step(
                state.y, K, f1, *common,
                b_sol=b_sol_w, b_err=b_err_w, ctrl=ctrl,
                want_coeffs=want_coeffs, ctrl_mode=mode,
            )
        (y1, err_ratio, accept, y_out, f_out, t_out, dt_out,
         new_inv, new_inv2, coeffs) = out
        cstate_new = ControllerState(new_inv, new_inv2)
        if self._fused_implicit:
            scarry_new = stepper.commit_carry(
                state.scarry, carry_prop, accept, state.running
            )

        done_now = accept & will_finish
        dt_floor = 8.0 * eps * jnp.maximum(jnp.abs(state.t), jnp.abs(t_end))
        nonfinite_y = ~jnp.all(jnp.isfinite(y1), axis=-1)
        # Where ``running`` holds, dt_out IS the controller's dt_next (the
        # kernel commits dt_next under the same mask the unfused path uses).
        stopped = state.running & ~accept & (jnp.abs(dt_out) <= dt_floor)

        if self.events:
            adv = advance_events(
                self.events, state.estate, coeffs, state.t, safe_dt, t_new,
                y1, accept, args, self.event_bisect_iters,
            )
            estate, event_stop = adv.estate, adv.stop
            t_stop = jnp.where(event_stop, adv.t_stop, t_new)
        else:
            adv, estate = None, state.estate
            event_stop = jnp.zeros_like(accept)
            t_stop = t_new

        ys, n_written = self._write_dense(
            state, consts, coeffs, accept, t_stop, safe_dt, cursor, t_win, W
        )

        # --- masked commit: already done in-kernel; events override on top ---
        y, f0, t, dt = y_out, f_out, t_out, dt_out
        if self.events:
            y = jnp.where(event_stop[:, None], adv.y_stop, y)
            t = jnp.where(event_stop, t_stop, t)

        running = state.running & ~done_now & ~stopped & ~event_stop
        status = jnp.where(
            event_stop,
            Status.EVENT.value,
            jnp.where(
                done_now,
                Status.SUCCESS.value,
                jnp.where(
                    stopped,
                    jnp.where(nonfinite_y, Status.INFINITE.value, Status.REACHED_DT_MIN.value),
                    state.status,
                ),
            ),
        ).astype(jnp.int32)

        inc = jnp.where(any_running, 1, 0).astype(jnp.int32)
        ctx = StepContext(
            running=state.running,
            accept=accept,
            step_active=inc,
            n_f_evals=n_f_evals,
            n_written=n_written,
            err_ratio=err_ratio,
            aux=stats_aux,
            n_events=adv.n_new if adv is not None else None,
        )
        stats = self._apply_stat_updates(dict(state.stats), ctx)
        stats["n_fused_steps"] = (
            stats["n_fused_steps"] + inc * state.running.astype(jnp.int32)
        )

        return LoopState(
            t=t,
            dt=dt,
            y=y,
            f0=f0,
            # Explicit steppers carry () across steps; the implicit fast path
            # commits its Jacobian carry exactly like the unfused step.
            scarry=scarry_new,
            cstate=cstate_new,
            running=running,
            status=status,
            stats=stats,
            ys=ys,
            it=state.it + inc,
            estate=estate,
        )

    def finish(self, state: LoopState, consts) -> Solution:
        t_eval, t_start, t_end, direction = consts
        status = jnp.where(
            state.running, Status.REACHED_MAX_STEPS.value, state.status
        ).astype(jnp.int32)
        stats = dict(state.stats)
        extra = {}
        if self.events:
            extra = dict(
                event_t=state.estate.t,
                event_y=state.estate.y,
                event_mask=state.estate.fired,
            )
        if self.dense and t_eval is not None:
            return Solution(ts=t_eval, ys=state.ys, status=status, stats=stats, **extra)
        # Without t_eval, report the per-instance time actually reached:
        # t_end on SUCCESS (the final step lands there exactly), the event
        # time on EVENT, and the last accepted time for early stops
        # (REACHED_DT_MIN / INFINITE / REACHED_MAX_STEPS).
        return Solution(ts=state.t, ys=state.y, status=status, stats=stats, **extra)


# Leaves: the tolerances (dynamic -- per-instance vectors vary freely between
# solves of one compiled program).  Aux: everything else, hashable by value.
register_config_pytree(StepFunction, ("rtol", "atol"), ("stat_contributors",))
