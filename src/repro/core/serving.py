"""Shape-bucketed request coalescing: individual ODE solves served as batches.

The paper's core economics -- amortize solver overhead by batching many
independent IVPs into one program -- only pays off if something *builds*
those batches.  A serving deployment sees the opposite shape of traffic: a
stream of single-instance requests, each with its own initial state, time
span, tolerances and solver configuration.  ``SolveService`` closes that gap:

1.  ``submit(SolveRequest(...))`` normalizes a request and drops it into a
    **bucket** keyed by everything that selects a compiled program: the
    driver's static config (stepper/controller/layout, hashed through
    ``static.tree_key``), the dynamics' identity, the state PyTree structure
    and leaf shapes/dtypes, the padded eval-grid length class and the args
    structure.  Requests in one bucket are exactly the requests that can
    share one executable -- the bucket key is ``CompiledSolver.cache_key``
    identity by construction.
2.  A bucket flushes when it reaches ``max_batch`` requests (flush-on-size)
    or when its oldest request has waited ``max_delay`` seconds
    (flush-on-deadline, checked on every ``submit``/``poll``/``result`` --
    the service is single-threaded and deterministic by design; drive
    ``poll()`` from your event loop).  The total backlog is bounded by
    ``max_queue``: a submit that would exceed it first drains every bucket.
3.  Flushing pads the batch to a **power-of-two batch-size class** (so at
    most ``log2(max_batch)+1`` programs exist per bucket, all prewarmable)
    by replicating the first request's row, stacks rows into batched arrays
    placed on the **next device in round-robin order**, and *launches* the
    per-device ``CompiledSolver`` program without waiting for it: JAX
    dispatch is asynchronous, so the host returns to packing the next bucket
    while the device integrates this one.  One process drives the whole
    mesh -- concurrent buckets land on different devices.
4.  Launched batches sit in a bounded **in-flight window** (``max_inflight``;
    exceeding it blocks on the oldest launch -- backpressure, so device
    memory holds at most ``max_inflight`` batches of results).  Completed
    batches are **harvested** -- without blocking -- on every ``submit``/
    ``poll``/``done()`` (or blocking via ``drain()``/``result()``): one
    device-to-host transfer per field, then the batched ``Solution`` is
    sliced into per-request solutions (``Solution.slice_batch`` /
    ``truncate_eval``) and the futures resolve.  ``max_inflight=0`` disables
    the pipeline entirely (launch + harvest inline -- the blocking service).
    Padding can never perturb real requests: instances do not interact (the
    batch-invariance property the solver's test suite enforces), so a padded
    row only costs the wasted FLOPs tracked in ``stats()['pad_waste']``.

Padding policy:

* batch axis -- padded up to the next power of two with copies of request 0;
  sliced off at unpack.  For explicit steppers the realized per-request
  results are bitwise identical to solving each request alone through
  ``CompiledSolver`` in the final-state regime (and identical to rounding in
  the dense regime, where XLA's batched interpolant contractions are
  batch-size dependent).
* eval grid -- each request's ``t_eval`` is padded to its power-of-two
  length class by repeating the final time; the duplicate columns are pure
  interpolant re-evaluations, cut off by ``truncate_eval``.
* tolerances, ``t0``/``t1``, ``dt0`` -- per-request scalars stacked into
  per-instance ``(b,)`` vectors (dynamic arguments: they never retrace).

What requests may vary *within* one bucket: ``y0`` values, ``t0``/``t1``,
``rtol``/``atol``, ``args`` values, eval-grid values (up to the length
class).  What splits buckets: the vector field object, driver/stepper/
controller config, state structure or leaf shapes/dtypes, eval-grid length
class, args structure, presence of ``dt0``.

The per-request vector-field contract is the library's usual one: requests
carry *unbatched* states (1-D arrays or PyTrees of unbatched leaves) and the
service stacks them, so a flat-state ``f`` sees ``(b,)`` times, ``(b, f)``
states and args with a leading batch axis (per-request args are stacked).
PyTree states go through the drivers' per-instance convention; per-request
``args`` for them ride the ravel boundary (``ODETerm.batched_args``): each
leaf is stacked along a new leading batch axis and vmapped per instance, so
requests with *different parameter values* share one bucket and one compiled
program instead of splitting the cache key per parameter set.

Gradient serving: a request with ``grad=True`` (or constructed as a
``GradRequest``, or carrying an explicit ``cotangent``) routes through the
same batcher into a *gradient bucket*: its rows -- including the per-request
cotangents -- pack into the same padded power-of-two batches, but the bucket
key carries the adjoint program's identity (the driver's static config hashes
the driver class, ``ScanAdjoint.checkpoint_every``, ``BacksolveAdjoint.mode``,
...), and the compiled artifact is the VJP-wrapped solve
(``CompiledSolver.solve(cotangent=...)``), traced once per (config, batch
class, device) and prewarmable exactly like a forward program.  Gradient
futures resolve to ``(solution_view, Grads(y0=..., args=...))`` -- the
per-request gradient rows sliced out of the coalesced backward solve.
Gradient requests track only the final state (no ``t_eval``); the default
gradient driver is ``ScanAdjoint`` (reverse-differentiable bounded scan;
``AutoDiffAdjoint``'s while_loop has no reverse rule), overridable per
request via ``method=`` or service-wide via ``default_grad_method``.

Statistics: ``stats()`` exposes the serving counters (queue depth, batches,
pad waste, solves/sec, gradient solves ``n_grad_solves`` and their device
time ``grad_device_s``, in-flight window, compiled-program cache hits/misses)
and the async time split -- ``queue_s`` (submit to launch), ``pack_s`` (host
stacking + dispatch), ``device_s`` (launch to observed completion) -- plus
the summed per-instance accumulators of every ``Solution`` served, so
anything a component contributes through the statistics registry
(``n_steps``, ``n_f_evals``, ``n_newton_iters``, user extras) aggregates
across the service for free under ``solver/<name>``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from .compiled import CompiledSolver, _f_key
from .drivers import AutoDiffAdjoint, BacksolveAdjoint, ScanAdjoint, _Driver
from .solution import Solution
from .static import tree_key
from .stepper import AbstractStepper
from .terms import ODETerm


def next_pow2(n: int) -> int:
    """The smallest power of two >= n (the batch/eval-grid size classes)."""
    if n < 1:
        raise ValueError(f"need a positive size, got {n}")
    return 1 << (n - 1).bit_length()


@dataclasses.dataclass(frozen=True)
class SolveRequest:
    """One IVP to solve: a single instance, not a batch.

    f:        the vector field (callable or ``ODETerm``).  Requests sharing a
              bucket must reuse the *same object* -- identity is program
              identity (as everywhere in the compiled front end).
    y0:       unbatched initial state: a 1-D ``(f,)`` array, or a PyTree of
              unbatched leaves (reshape bare matrix states to 1-D or nest
              them in a PyTree).
    t0, t1:   the integration span (scalars; backward spans allowed).
    t_eval:   optional 1-D evaluation grid (its own length per request --
              grids bucket by power-of-two length class).  ``None`` requests
              only the final state.
    args:     optional per-request dynamics arguments (PyTree; leaves are
              stacked along a new leading batch axis across the bucket).
    rtol, atol: per-request tolerances; default to the method's configuration.
    method:   stepper name / ``AbstractStepper`` / configured driver; default
              is the service's ``default_method`` (``default_grad_method``
              for gradient requests, which need a reverse-differentiable
              driver -- ``ScanAdjoint`` or ``BacksolveAdjoint``).
    dt0:      optional fixed initial step size.
    grad:     request gradients: the future resolves to ``(solution_view,
              Grads(y0=..., args=...))`` -- the VJP of the final state pulled
              back through the solve, coalesced with the other gradient
              requests of the same bucket.  Implied by a non-None
              ``cotangent``.  Gradient requests track only the final state
              (``t_eval`` must be ``None``).
    cotangent: the output cotangent to pull back -- same structure and leaf
              shapes as ``y0`` (e.g. the loss gradient w.r.t. the final
              state).  Defaults to ones, which sums the gradient over state
              features.
    """

    f: Any
    y0: Any
    t0: float
    t1: float
    t_eval: Any = None
    args: Any = None
    rtol: float | None = None
    atol: float | None = None
    method: Any = None
    dt0: float | None = None
    grad: bool = False
    cotangent: Any = None


@dataclasses.dataclass(frozen=True)
class GradRequest(SolveRequest):
    """A ``SolveRequest`` that asks for gradients (``grad=True`` by default):
    ``GradRequest(f, y0, t0, t1, cotangent=dL_dy1, args=theta)`` resolves to
    ``(solution_view, Grads(y0=dL/dy0, args=dL/dtheta))``."""

    grad: bool = True


class _Item:
    """A normalized, validated request queued in a bucket."""

    __slots__ = ("f", "y0", "t0", "t1", "t_eval", "n_eval", "args",
                 "rtol", "atol", "dt0", "grad", "cotangent", "t_enq")

    def __init__(self, f, y0, t0, t1, t_eval, n_eval, args, rtol, atol, dt0,
                 grad=False, cotangent=None):
        self.f = f
        self.y0 = y0
        self.t0 = t0
        self.t1 = t1
        self.t_eval = t_eval
        self.n_eval = n_eval  # the request's true grid length (pre-padding)
        self.args = args
        self.rtol = rtol
        self.atol = atol
        self.dt0 = dt0
        self.grad = grad
        self.cotangent = cotangent  # validated to mirror y0; None iff not grad
        self.t_enq = 0.0  # service clock at submit, for the queue_s split


class _Inflight:
    """One launched-but-unharvested batch: the handle the async engine keeps
    between dispatch and delivery."""

    __slots__ = ("batch", "bucket", "sol", "n_rows", "launch_pc", "device")

    def __init__(self, batch, bucket, sol, n_rows, launch_pc, device):
        self.batch = batch          # [(item, future), ...] in submit order
        self.bucket = bucket
        self.sol = sol              # batched Solution of device arrays
        self.n_rows = n_rows        # padded batch size
        self.launch_pc = launch_pc  # perf_counter at dispatch return
        self.device = device


class SolveFuture:
    """Handle to one submitted request.

    A request moves through three states: *queued* (waiting in its bucket),
    *in-flight* (its batch launched on a device, result not yet harvested)
    and *done*.  ``done()`` is non-blocking: it harvests any in-flight
    batches whose device work has finished, then reports whether this one
    resolved.

    ``result()`` returns the request's ``Solution`` view (batch axis kept,
    with exactly one instance: ``ys`` leaves are ``(1, ...)``, stats are
    ``(1,)`` -- the same container contract as every other solve), with
    fields delivered as host NumPy arrays: serving results leave the device
    in one transfer per batch, and the per-request views are zero-copy
    slices of it.  If the request is in-flight, ``result()`` blocks until
    its batch completes; if it is still *queued*, ``result()`` flushes its
    bucket first (pass ``flush=False`` to get an error instead, e.g. from
    latency-sensitive callers that only want already-launched work).

    For a gradient request, ``result()`` returns ``(view, grads)``: the same
    per-request ``Solution`` view plus a ``Grads(y0=..., args=...)`` record
    with the batch axis stripped (``y0`` mirrors the request's ``y0``
    structure; ``args`` its ``args``, or ``None`` when the request carried
    none) -- the unbatched gradients a training step consumes directly.
    """

    __slots__ = ("_service", "_bucket", "_inflight", "_solution", "_error",
                 "_grad")

    def __init__(self, service: "SolveService", bucket: "_Bucket",
                 grad: bool = False):
        self._service = service
        self._bucket = bucket
        self._inflight: _Inflight | None = None
        self._solution: Solution | None = None
        self._error: BaseException | None = None
        self._grad = grad

    def done(self) -> bool:
        if self._solution is None and self._error is None:
            self._service._harvest_ready()
        return self._solution is not None or self._error is not None

    def result(self, flush: bool = True) -> Solution:
        if self._solution is None and self._error is None:
            if self._inflight is None:
                if not flush:
                    raise RuntimeError(
                        "request still queued; pass flush=True or call "
                        "SolveService.flush()/poll() first")
                self._service._execute(self._bucket)
            if self._inflight is not None:
                self._service._harvest(self._inflight, block=True)
        if self._error is not None:
            raise self._error
        if self._grad:
            grads = jax.tree_util.tree_map(lambda x: x[0], self._solution.grads)
            return self._solution, grads
        return self._solution


class _Bucket:
    """All queued requests that can share one compiled program."""

    __slots__ = ("key", "driver", "solver", "f", "time_dtype", "n_eval_class",
                 "has_args", "has_dt0", "grad", "pending", "oldest")

    def __init__(self, key, driver, solver, f, time_dtype, n_eval_class,
                 has_args, has_dt0, grad=False):
        self.key = key
        self.driver = driver
        self.solver = solver
        self.f = f
        self.time_dtype = time_dtype
        self.n_eval_class = n_eval_class  # padded grid length, or None
        self.has_args = has_args
        self.has_dt0 = has_dt0
        self.grad = grad  # gradient bucket: packs cotangents, runs the VJP program
        self.pending: list[tuple[_Item, SolveFuture]] = []
        self.oldest: float | None = None  # enqueue time of the oldest pending


class SolveService:
    """Request-coalescing front end over ``CompiledSolver``.

    Example (serving loop)::

        svc = SolveService(max_batch=16, max_delay=2e-3, max_inflight=4)
        svc.prewarm(SolveRequest(f, y0_example, 0.0, 1.0))   # AOT, optional
        futs = [svc.submit(SolveRequest(f, y0, t0, t1)) for ...]
        svc.poll()     # harvest completed launches + deadline-flush
        svc.flush()    # launch whatever is still queued (non-blocking)
        sols = [f.result() for f in futs]  # blocks per in-flight batch

    Parameters: ``max_batch`` (power of two; flush-on-size threshold and
    padded-batch ceiling), ``max_delay`` (seconds a request may wait before
    its bucket is flushed on the next ``submit``/``poll``; ``None`` disables
    deadline flushing), ``max_queue`` (total backlog bound; exceeding it
    drains every bucket), ``max_inflight`` (launched-but-unharvested batch
    window; a launch past it first blocks on the oldest in-flight batch --
    backpressure -- and ``0`` makes every execution synchronous, the
    pre-async blocking service), ``devices`` (the devices batches round-robin
    over; default every ``jax.devices()`` -- one process drives the mesh),
    ``default_method`` (for requests without one), ``default_grad_method``
    (for *gradient* requests without one; defaults to a ``ScanAdjoint`` over
    the stepper, the reverse-differentiable driver), ``donate``/``cache_size``
    (forwarded to each ``CompiledSolver``) and ``clock`` (injectable
    monotonic clock, for deterministic deadline tests).

    Memory: compiled programs are LRU-bounded per driver config
    (``cache_size``); bucket/driver/solver bookkeeping grows with the number
    of *distinct configurations served* (shape classes x methods), which a
    deployment bounds by construction -- the per-submit hot path only ever
    touches the buckets that currently have work waiting.  Device memory is
    bounded by ``max_inflight`` batches of packed inputs + results.
    """

    def __init__(
        self,
        *,
        max_batch: int = 16,
        max_delay: float | None = 0.01,
        max_queue: int = 4096,
        max_inflight: int = 4,
        devices=None,
        default_method: Any = None,
        default_grad_method: Any = None,
        donate: bool | str = "auto",
        cache_size: int = 128,
        clock: Callable[[], float] = time.monotonic,
    ):
        if max_batch < 1 or (max_batch & (max_batch - 1)) != 0:
            raise ValueError(f"max_batch must be a power of two, got {max_batch}")
        if max_queue < max_batch:
            raise ValueError("max_queue must be at least max_batch")
        if max_inflight < 0:
            raise ValueError(f"max_inflight must be >= 0, got {max_inflight}")
        self.max_batch = max_batch
        self.max_delay = max_delay
        self.max_queue = max_queue
        self.max_inflight = max_inflight
        self.devices = tuple(jax.devices() if devices is None else devices)
        if not self.devices:
            raise ValueError("need at least one device to serve on")
        self.default_method = default_method
        self.default_grad_method = default_grad_method
        self.donate = donate
        self.cache_size = cache_size
        self.clock = clock
        self._buckets: OrderedDict[tuple, _Bucket] = OrderedDict()
        # Buckets with pending requests, in first-enqueue order: the deadline
        # sweep runs on every submit, so it must scan the (few) waiting
        # buckets, not every shape class the service has ever seen.
        self._waiting: OrderedDict[tuple, _Bucket] = OrderedDict()
        self._solvers: dict[Any, CompiledSolver] = {}
        # Per-submit memos (the submit path is the serving hot loop: a fresh
        # driver construction or pytree flatten per request would rival the
        # amortized solve cost).  Entries keep their driver alive, so an id
        # can never be recycled while its memo exists.
        self._driver_memo: dict[Any, _Driver] = {}
        self._driver_keys: dict[int, tuple] = {}
        self._queue_depth = 0
        self._inflight: deque[_Inflight] = deque()
        self._rr = 0  # round-robin cursor over self.devices
        self._counters = {
            "n_requests": 0,
            "n_completed": 0,
            "n_batches": 0,
            "n_rows": 0,
            "n_pad_rows": 0,
            "n_deadline_flushes": 0,
            "n_size_flushes": 0,
            "n_failed_batches": 0,
            "n_backpressure_waits": 0,
            "peak_inflight": 0,
            "n_grad_solves": 0,
        }
        self._solver_totals: dict[str, float] = {}
        self._queue_s = 0.0
        self._pack_s = 0.0
        self._device_s = 0.0
        self._grad_device_s = 0.0

    # ------------------------------------------------------------------
    # request normalization and bucketing

    def _coerce_driver(self, method, grad: bool = False):
        if method is None:
            method = self.default_grad_method if grad else self.default_method
        if isinstance(method, (_Driver, BacksolveAdjoint)):
            return method
        memo_key = (grad,
                    method if isinstance(method, (str, type(None))) else id(method))
        driver = self._driver_memo.get(memo_key)
        if driver is None:
            stepper = AbstractStepper.coerce(method)
            # Gradient programs need a reverse-differentiable driver: the
            # default forward driver's while_loop has no reverse rule.
            driver = ScanAdjoint(stepper) if grad else AutoDiffAdjoint(stepper)
            self._driver_memo[memo_key] = driver
        return driver

    def _driver_key_of(self, driver: _Driver):
        entry = self._driver_keys.get(id(driver))
        if entry is None:
            entry = (driver, tree_key(driver))
            self._driver_keys[id(driver)] = entry
        return entry[1]

    @staticmethod
    def _as_array(x):
        # jax arrays pass through untouched: jnp.asarray on an existing
        # committed array still pays dtype canonicalization (~half the
        # submit cost at serving rates).  Everything else becomes a NumPy
        # array with its dtype pre-canonicalized (float64 -> float32 under
        # default x64-off), so bucket keys and prewarm specs match what
        # ``_pack``'s device transfer will actually produce -- a NumPy
        # float64 request must share its bucket (and prewarmed program)
        # with the float32 jnp request of the same logical shape.
        if isinstance(x, jax.Array):
            return x
        x = np.asarray(x)
        canonical = jax.dtypes.canonicalize_dtype(x.dtype)
        return x if x.dtype == canonical else x.astype(canonical)

    def _normalize(self, req: SolveRequest) -> tuple[_Item, Any]:
        grad = bool(req.grad) or req.cotangent is not None
        driver = self._coerce_driver(req.method, grad)
        if grad and isinstance(driver, AutoDiffAdjoint):
            raise TypeError(
                "gradient requests need a reverse-differentiable driver "
                "(ScanAdjoint or BacksolveAdjoint); AutoDiffAdjoint's "
                "while_loop has no reverse rule.  Pass method=ScanAdjoint(...) "
                "or set the service's default_grad_method."
            )
        if grad and req.t_eval is not None:
            raise ValueError(
                "gradient requests track only the final state: the cotangent "
                "pulls back through y(t1), so t_eval must be None"
            )
        if isinstance(driver, BacksolveAdjoint) and (
                req.t_eval is not None or req.dt0 is not None):
            raise TypeError(
                "BacksolveAdjoint serves final-state solves only: requests "
                "routed to it cannot carry t_eval or dt0"
            )
        if grad and isinstance(driver, BacksolveAdjoint) and \
                driver.mode == "joint":
            raise TypeError(
                "coalesced gradient serving needs row-independent backward "
                "solves: BacksolveAdjoint(mode='joint') stacks the whole "
                "batch into one adjoint instance with a batch-shared time "
                "range, which a bucket of independent requests cannot "
                "guarantee.  Use mode='per_instance' (or ScanAdjoint)."
            )
        y0 = (req.y0 if isinstance(req.y0, jax.Array)
              else jax.tree_util.tree_map(self._as_array, req.y0))
        flat = isinstance(y0, (jax.Array, np.ndarray))
        if flat and y0.ndim != 1:
            raise ValueError(
                f"request y0 must be an unbatched 1-D state or a PyTree, got "
                f"a bare array of shape {y0.shape}; reshape to 1-D or nest it"
            )
        leaves = jax.tree_util.tree_leaves(y0)
        if not leaves:
            raise ValueError("request y0 has no array leaves")
        f = req.f
        args = None
        if req.args is not None:
            args = (req.args if isinstance(req.args, jax.Array)
                    else jax.tree_util.tree_map(self._as_array, req.args))
            # Per-request args always batch like y0: each leaf is stacked
            # along a new leading axis at pack time.  Per-instance dynamics
            # (PyTree states through the ravel boundary, or explicit
            # batched=False terms) would see the whole stack shared, so mark
            # the term batched_args: the vmap then hands each instance its
            # own args row.  ODETerm hashes by value, so equal wrappers of
            # one vector field still share a bucket and a compiled program.
            backsolve_grad = grad and isinstance(driver, BacksolveAdjoint)
            if isinstance(f, ODETerm):
                if not flat or not f.batched or backsolve_grad:
                    f = dataclasses.replace(f, batched_args=True)
            elif not flat:
                f = ODETerm(f, batched=False, with_args=True,
                            batched_args=True)
            elif backsolve_grad:
                # The per-instance backward solve re-closes the dynamics over
                # the parameters one instance at a time; without the flag it
                # would hand every instance the WHOLE stacked-args batch (and
                # row-0 values after broadcasting) -- silently wrong gradients
                # for every row but the first.  Mark the rows so the adjoint
                # threads each instance's own row through the ravel boundary.
                f = ODETerm(f, batched=True, with_args=True,
                            batched_args=True)
        rtol = req.rtol if req.rtol is not None else driver.rtol
        atol = req.atol if req.atol is not None else driver.atol
        for name, tol in (("rtol", rtol), ("atol", atol)):
            if jnp.ndim(tol) != 0:
                raise ValueError(
                    f"per-request {name} must be scalar (got shape "
                    f"{jnp.shape(tol)}); per-feature tolerances do not fit "
                    "the (b,)-vector packing"
                )
        t_eval, n_eval = None, None
        if req.t_eval is not None:
            t_eval = np.asarray(req.t_eval, dtype=np.float64)
            if t_eval.ndim != 1 or t_eval.shape[0] < 1:
                raise ValueError(
                    f"request t_eval must be a non-empty 1-D grid, got shape "
                    f"{t_eval.shape}"
                )
            n_eval = int(t_eval.shape[0])
        cotangent = None
        if grad:
            if req.cotangent is None:
                # Default pullback: sum the gradient over state features.
                cotangent = jax.tree_util.tree_map(
                    lambda y: np.ones(np.shape(y), dtype=y.dtype), y0)
            else:
                cot = jax.tree_util.tree_map(self._as_array, req.cotangent)
                if (jax.tree_util.tree_structure(cot)
                        != jax.tree_util.tree_structure(y0)):
                    raise ValueError(
                        "cotangent must mirror y0's PyTree structure "
                        f"(got {jax.tree_util.tree_structure(cot)}, "
                        f"expected {jax.tree_util.tree_structure(y0)})"
                    )
                for cl, yl in zip(jax.tree_util.tree_leaves(cot), leaves):
                    if np.shape(cl) != np.shape(yl):
                        raise ValueError(
                            f"cotangent leaf shape {np.shape(cl)} does not "
                            f"match the y0 leaf shape {np.shape(yl)}"
                        )
                # The VJP's output aval is ys (dtype of y0): cast rather than
                # letting a float64 host cotangent split or break the program.
                cotangent = jax.tree_util.tree_map(
                    lambda c, y: np.asarray(c, dtype=y.dtype), cot, y0)
        item = _Item(f, y0, float(req.t0), float(req.t1), t_eval, n_eval,
                     args, float(rtol), float(atol),
                     None if req.dt0 is None else float(req.dt0),
                     grad, cotangent)
        return item, driver

    def _bucket_for(self, item: _Item, driver: _Driver) -> _Bucket:
        driver_key = self._driver_key_of(driver)
        n_eval_class = None if item.n_eval is None else next_pow2(item.n_eval)
        key = (
            driver_key,
            _f_key(item.f),
            tree_key(item.y0),
            n_eval_class,
            tree_key(item.args),
            item.dt0 is None,
            # Forward and gradient requests never share a bucket: they
            # dispatch to different compiled programs (the driver_key above
            # already separates adjoint configs -- driver class,
            # checkpoint_every, backsolve mode -- since it hashes the full
            # static config).  The cotangent's shape class is y0's by
            # validation, so the flag alone completes the program identity.
            item.grad,
        )
        bucket = self._buckets.get(key)
        if bucket is None:
            solver = self._solvers.get(driver_key)
            if solver is None:
                solver = CompiledSolver(driver, donate=self.donate,
                                        cache_size=self.cache_size)
                self._solvers[driver_key] = solver
            time_dtype = jnp.result_type(*[leaf.dtype for leaf in
                                           jax.tree_util.tree_leaves(item.y0)])
            bucket = _Bucket(key, driver, solver, item.f, time_dtype,
                             n_eval_class, item.args is not None,
                             item.dt0 is not None, item.grad)
            self._buckets[key] = bucket
        return bucket

    # ------------------------------------------------------------------
    # queueing policies

    def submit(self, req: SolveRequest) -> SolveFuture:
        """Queue one request; returns its future.  May launch batches: the
        request's own bucket on flush-on-size, expired buckets on
        flush-on-deadline, everything on backlog overflow.  Launches are
        non-blocking (unless ``max_inflight`` forces a backpressure wait);
        completed earlier launches are harvested on the way in."""
        self.poll()
        if self._queue_depth >= self.max_queue:
            self.flush()
        item, driver = self._normalize(req)
        bucket = self._bucket_for(item, driver)
        fut = SolveFuture(self, bucket, grad=item.grad)
        item.t_enq = self.clock()
        if not bucket.pending:
            bucket.oldest = item.t_enq
            self._waiting[bucket.key] = bucket
        bucket.pending.append((item, fut))
        self._queue_depth += 1
        self._counters["n_requests"] += 1
        if len(bucket.pending) >= self.max_batch:
            self._counters["n_size_flushes"] += 1
            self._execute(bucket)
        return fut

    def poll(self) -> int:
        """One cooperative tick of the serving engine: harvest every
        in-flight batch whose device work has finished (non-blocking), then
        launch every bucket that is due -- full ones always, waiting ones
        when their oldest request has aged past ``max_delay``.  Runs the
        harvest and the size sweep even with ``max_delay=None`` (deadline
        flushing disabled), so a ``poll()``-driven event loop always makes
        progress.  Returns the number of batches launched."""
        self._harvest_ready()
        if not self._waiting:
            return 0
        now = self.clock() if self.max_delay is not None else None
        n = 0
        for bucket in list(self._waiting.values()):
            if not bucket.pending:
                continue
            if len(bucket.pending) >= self.max_batch:
                self._counters["n_size_flushes"] += 1
                self._execute(bucket)
                n += 1
            elif now is not None and now - bucket.oldest >= self.max_delay:
                self._counters["n_deadline_flushes"] += 1
                self._execute(bucket)
                n += 1
        return n

    def flush(self) -> int:
        """Launch every non-empty bucket (non-blocking; harvest with
        ``drain()``/``poll()``/``result()``).  Returns the number of
        batches launched."""
        n = 0
        for bucket in list(self._waiting.values()):
            if bucket.pending:
                self._execute(bucket)
                n += 1
        return n

    def drain(self, n: int | None = None) -> int:
        """Blocking harvest of up to ``n`` in-flight batches (oldest first;
        all of them when ``n`` is None).  Does not launch queued buckets --
        pair with ``flush()`` for a full barrier.  Returns the number of
        batches harvested."""
        harvested = 0
        while self._inflight and (n is None or harvested < n):
            self._harvest(self._inflight[0], block=True)
            harvested += 1
        return harvested

    # ------------------------------------------------------------------
    # packing and execution

    def _pack(self, bucket: _Bucket, items: list[_Item], device) -> dict:
        """Stack per-request rows into the bucket's padded batch arguments,
        landed directly on ``device``.

        Stacking happens host-side (one NumPy stack + one transfer per
        field) rather than per-row on the device: at serving batch sizes the
        per-op dispatch of b x ``jnp.stack`` costs several times the solve
        itself."""
        b = min(next_pow2(len(items)), self.max_batch)
        rows = items + [items[0]] * (b - len(items))
        td = bucket.time_dtype
        put = lambda x: jax.device_put(x, device)
        host_stack = lambda *xs: put(np.stack([np.asarray(x) for x in xs]))
        vec = lambda vals: put(np.array(vals, dtype=td))
        kw = dict(
            y0=jax.tree_util.tree_map(host_stack, *[r.y0 for r in rows]),
            t_eval=None,
            t_start=vec([r.t0 for r in rows]),
            t_end=vec([r.t1 for r in rows]),
            dt0=None,
            args=None,
            rtol=vec([r.rtol for r in rows]),
            atol=vec([r.atol for r in rows]),
        )
        if bucket.n_eval_class is not None:
            n_class = bucket.n_eval_class
            grids = [np.concatenate([r.t_eval,
                                     np.full(n_class - r.n_eval, r.t_eval[-1])])
                     for r in rows]
            kw["t_eval"] = put(np.stack(grids).astype(td))
        if bucket.has_args:
            kw["args"] = jax.tree_util.tree_map(host_stack, *[r.args for r in rows])
        if bucket.has_dt0:
            kw["dt0"] = vec([r.dt0 for r in rows])
        if bucket.grad:
            # Per-request cotangents row through the batch exactly like y0;
            # pad rows reuse request 0's cotangent (their gradients are
            # sliced off with the rest of the padding).
            kw["cotangent"] = jax.tree_util.tree_map(
                host_stack, *[r.cotangent for r in rows])
        return kw

    def _execute(self, bucket: _Bucket) -> None:
        """Pack and *launch* a bucket's pending batch on the next device in
        round-robin order.  Non-blocking: the batch joins the in-flight
        window and its futures resolve at harvest.  A launch that would
        exceed ``max_inflight`` first blocks on the oldest in-flight batch
        (backpressure); ``max_inflight=0`` harvests inline (the blocking
        service)."""
        if not bucket.pending:
            return
        batch = bucket.pending
        bucket.pending = []
        bucket.oldest = None
        self._waiting.pop(bucket.key, None)
        self._queue_depth -= len(batch)
        while self._inflight and len(self._inflight) >= max(1, self.max_inflight):
            self._counters["n_backpressure_waits"] += 1
            self._harvest(self._inflight[0], block=True)
        device = self.devices[self._rr % len(self.devices)]
        self._rr += 1
        items = [item for item, _ in batch]
        now = self.clock()
        t0 = time.perf_counter()
        try:
            kw = self._pack(bucket, items, device)
            sol = bucket.solver.solve(bucket.f, device=device, **kw)
        except Exception as e:  # deliver to the owners, keep the service up
            self._counters["n_failed_batches"] += 1
            for _, fut in batch:
                fut._error = e
            return
        launch_pc = time.perf_counter()
        self._pack_s += launch_pc - t0
        self._queue_s += sum(now - item.t_enq for item in items)
        b = jax.tree_util.tree_leaves(kw["y0"])[0].shape[0]
        self._counters["n_batches"] += 1
        self._counters["n_rows"] += b
        self._counters["n_pad_rows"] += b - len(batch)
        rec = _Inflight(batch, bucket, sol, b, launch_pc, device)
        self._inflight.append(rec)
        self._counters["peak_inflight"] = max(self._counters["peak_inflight"],
                                              len(self._inflight))
        for _, fut in batch:
            fut._inflight = rec
        if self.max_inflight == 0:
            self._harvest(rec, block=True)

    def _harvest_ready(self) -> int:
        """Harvest every in-flight batch whose device work has finished,
        without blocking on the ones still running.  Returns the number of
        batches delivered.

        Probes at most one unfinished record per device: a device executes
        its launches in order, so once its oldest record is unready, every
        younger record on it is too -- this runs on every submit, and probing
        the whole window would rival the pack cost it exists to hide."""
        n = 0
        stalled: set[Any] = set()
        for rec in list(self._inflight):
            if rec.device in stalled:
                continue
            if self._harvest(rec, block=False):
                n += 1
            else:
                stalled.add(rec.device)
        return n

    def _harvest(self, rec: _Inflight, *, block: bool) -> bool:
        """Deliver one launched batch: wait for (or probe) the device
        buffers, transfer the batched ``Solution`` to host in one pass,
        slice per-request views and resolve the futures."""
        if not block and not rec.sol.is_ready():
            return False
        try:
            self._inflight.remove(rec)
        except ValueError:  # already harvested through another entry point
            return True
        bucket, batch = rec.bucket, rec.batch
        try:
            # One device->host transfer per field; the per-request views are
            # then zero-copy NumPy slices (device-side slicing would pay b
            # dispatches per field and dominate the batch -- results are
            # host-delivered by design).
            sol = rec.sol.block_until_ready().to_host()
        except Exception as e:  # deferred device failure surfaces here
            self._counters["n_failed_batches"] += 1
            for _, fut in batch:
                fut._error = e
                fut._inflight = None
            return True
        elapsed = time.perf_counter() - rec.launch_pc
        self._device_s += elapsed
        self._counters["n_completed"] += len(batch)
        if bucket.grad:
            self._grad_device_s += elapsed
            self._counters["n_grad_solves"] += len(batch)
        for name, acc in sol.stats.items():
            self._solver_totals[name] = (
                self._solver_totals.get(name, 0.0) + float(acc[: len(batch)].sum())
            )
        for i, (item, fut) in enumerate(batch):
            view = sol.slice_batch(slice(i, i + 1))
            if item.n_eval is not None and item.n_eval < bucket.n_eval_class:
                view = view.truncate_eval(item.n_eval)
            fut._solution = view
            fut._inflight = None
        return True

    # ------------------------------------------------------------------
    # prewarming and stats

    def prewarm(self, example: SolveRequest, batch_classes=None) -> int:
        """AOT-compile the programs ``example``-shaped requests will hit, one
        per power-of-two batch-size class (default: every class up to
        ``max_batch``) *per serving device* -- round-robin placement means
        any bucket can land anywhere on the mesh, so every device needs its
        own pinned executable.  Returns the number of programs newly
        compiled; warm classes are skipped, so prewarming is idempotent.
        Uses ``CompiledSolver.prewarm`` under the hood -- a subsequent flush
        of a matching bucket is a pure cache hit and never traces."""
        item, driver = self._normalize(example)
        bucket = self._bucket_for(item, driver)
        if batch_classes is None:
            batch_classes = [1 << i for i in range(self.max_batch.bit_length())]
        td = bucket.time_dtype
        specs = []
        for b in batch_classes:
            if b < 1 or b > self.max_batch or (b & (b - 1)) != 0:
                raise ValueError(
                    f"batch class {b} is not a power of two within max_batch="
                    f"{self.max_batch}"
                )
            vec = jax.ShapeDtypeStruct((b,), td)
            spec = dict(
                y0=jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct((b,) + x.shape, x.dtype),
                    item.y0,
                ),
                t_start=vec, t_end=vec, rtol=vec, atol=vec,
            )
            if bucket.n_eval_class is not None:
                spec["t_eval"] = jax.ShapeDtypeStruct((b, bucket.n_eval_class), td)
            if bucket.has_args:
                spec["args"] = jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct((b,) + x.shape, x.dtype),
                    item.args,
                )
            if bucket.has_dt0:
                spec["dt0"] = vec
            if bucket.grad:
                # The gradient program's extra operand: cotangent rows shaped
                # like y0 (validated at submit), selecting the VJP-wrapped
                # build in CompiledSolver.
                spec["cotangent"] = jax.tree_util.tree_map(
                    lambda x: jax.ShapeDtypeStruct((b,) + x.shape, x.dtype),
                    item.cotangent,
                )
            for device in self.devices:
                specs.append(dict(spec, device=device))
        return bucket.solver.prewarm(bucket.f, specs)

    def stats(self) -> dict[str, Any]:
        """Snapshot of the serving surface: queue/bucket/in-flight state,
        padding waste, the async time split -- ``queue_s`` (submit to
        launch), ``pack_s`` (host stacking + dispatch), ``device_s``
        (launch to observed harvest; overlapped launches double-count wall
        time, which is the point) -- realized solves/sec (completed requests
        over ``busy_s = pack_s + device_s``, the blocking service's old
        busy-time definition), compiled-program cache counters summed over
        the per-config ``CompiledSolver`` instances, and the aggregated
        solver statistics registry under ``solver/<name>``."""
        hits = misses = programs = 0
        for solver in self._solvers.values():
            info = solver.cache_info()
            hits += info.hits
            misses += info.misses
            programs += info.currsize
        c = self._counters
        busy_s = self._pack_s + self._device_s
        out: dict[str, Any] = {
            "queue_depth": self._queue_depth,
            "n_buckets": len(self._buckets),
            "n_inflight": len(self._inflight),
            "n_devices": len(self.devices),
            **c,
            "pad_waste": (c["n_pad_rows"] / c["n_rows"]) if c["n_rows"] else 0.0,
            "solves_per_sec": (c["n_completed"] / busy_s) if busy_s > 0 else 0.0,
            "queue_s": self._queue_s,
            "pack_s": self._pack_s,
            "device_s": self._device_s,
            "grad_device_s": self._grad_device_s,
            "busy_s": busy_s,
            "cache_hits": hits,
            "cache_misses": misses,
            "n_programs": programs,
        }
        for name, total in sorted(self._solver_totals.items()):
            out[f"solver/{name}"] = total
        return out
