"""Solve drivers: how the step function is iterated and how gradients flow.

The three drivers share the ``StepFunction`` ``init/step/finish`` interface
and differ only in the loop construct + gradient strategy (the paper's
Sec. 2.2 / Table 5 axis):

``AutoDiffAdjoint``
    ``jax.lax.while_loop`` -- the fastest forward pass (no wasted masked
    iterations); differentiable in forward mode only, since JAX's while_loop
    has no reverse rule.
``ScanAdjoint``
    bounded ``jax.lax.scan`` with masked no-op steps after termination
    (discretize-then-optimize); fully reverse-mode differentiable, with
    optional ``jax.checkpoint``-ed blocks trading recompute for memory.
``BacksolveAdjoint``
    optimize-then-discretize: the O(1)-memory adjoint-ODE backward pass,
    wrapping ``core/adjoint.py``'s ``jax.custom_vjp`` machinery.

All drivers accept arbitrary PyTree initial states.  Ravel/unravel happens at
the term boundary (``terms.ravel_state`` / ``terms.ravel_term``), so the hot
loop and the Pallas kernels keep operating on flat (b, f) buffers; the
returned ``Solution.ys`` is unravelled back to the caller's PyTree structure.
For PyTree states the vector field is interpreted *per instance*:
``f(t, y_tree, args)`` with scalar ``t`` and unbatched leaves, vmapped over
the batch.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .events import Event, normalize_events
from .solution import Solution
from .static import freeze, frozen_setattr, register_config_pytree, tree_key
from .step import StepFunction
from .stepper import AbstractStepper
from .terms import ODETerm, as_term, ravel_state, ravel_term


class _Driver:
    """Shared construction + PyTree plumbing for the loop-based drivers.

    Drivers follow the same static/dynamic split as ``StepFunction``: frozen
    after construction, pytree-registered with the tolerances as the only
    leaves and the rest as hashable aux data.  A driver is therefore a valid
    ``jax.jit`` argument, and value-equal drivers (same stepper, controller,
    layout flags) key to the same compiled program -- the contract
    ``CompiledSolver`` builds its zero-retrace cache on.
    """

    __setattr__ = frozen_setattr

    def __init__(
        self,
        stepper: AbstractStepper | str | None = None,
        controller=None,
        *,
        rtol=1e-3,
        atol=1e-6,
        max_steps: int = 10_000,
        dense: bool = True,
        dense_window: int = 0,
        batched_term: bool = True,
        events=None,
        event_bisect_iters: int = 30,
        extra_stats: tuple = (),
        fused: bool = False,
    ):
        self.stepper = AbstractStepper.coerce(stepper)
        self.controller = controller
        self.rtol = rtol
        self.atol = atol
        self.max_steps = max_steps
        self.dense = dense
        self.dense_window = dense_window
        self.batched_term = batched_term
        self.events = normalize_events(events)
        self.event_bisect_iters = event_bisect_iters
        self.extra_stats = tuple(extra_stats)
        self.fused = bool(fused)
        freeze(self)

    def _events_for(self, raveled) -> tuple[Event, ...]:
        """Events see the caller's state: for PyTree solves each per-instance
        condition receives the unravelled PyTree, not the flat buffer."""
        if raveled is None or not self.events:
            return self.events
        wrapped = []
        for e in self.events:
            if e.batched:
                raise ValueError(
                    "batched event conditions are not supported for PyTree "
                    "states; use per-instance cond_fn (batched=False)"
                )
            if e.with_args:
                cond = lambda t, y, args, _f=e.cond_fn: _f(t, raveled.unravel_one(y), args)
            else:
                cond = lambda t, y, _f=e.cond_fn: _f(t, raveled.unravel_one(y))
            wrapped.append(dataclasses.replace(e, cond_fn=cond))
        return tuple(wrapped)

    def _prepare(self, f, y0):
        """Normalize (f, y0) onto the flat convention.  Returns
        ``(step_fn, y0_flat, raveled)``; ``raveled`` is None for flat input."""
        y0_flat, raveled = ravel_state(y0)
        if raveled is None:
            term = as_term(f, batched=self.batched_term)
        else:
            term = ravel_term(f, raveled)
        step_fn = StepFunction(
            term,
            self.stepper,
            self.controller,
            rtol=self.rtol,
            atol=self.atol,
            dense=self.dense,
            dense_window=self.dense_window,
            events=self._events_for(raveled),
            event_bisect_iters=self.event_bisect_iters,
            extra_stats=self.extra_stats,
            fused=self.fused,
        )
        return step_fn, y0_flat, raveled

    @staticmethod
    def _finalize(sol: Solution, raveled) -> Solution:
        if raveled is None:
            return sol
        updates = dict(ys=raveled.unravel(sol.ys))
        if sol.event_y is not None:
            updates["event_y"] = raveled.unravel(sol.event_y)
        return dataclasses.replace(sol, **updates)


class AutoDiffAdjoint(_Driver):
    """``while_loop`` driver -- the paper's default forward solver.

    Example::

        solver = AutoDiffAdjoint(Stepper("tsit5"), pid_controller())
        sol = solver.solve(f, y0, t_eval, args=args)
    """

    def solve(
        self,
        f,
        y0,
        t_eval=None,
        *,
        t_start=None,
        t_end=None,
        dt0=None,
        args: Any = None,
    ) -> Solution:
        step_fn, y0_flat, raveled = self._prepare(f, y0)
        state, consts = step_fn.init(y0_flat, t_eval, t_start, t_end, dt0, args)
        state = jax.lax.while_loop(
            lambda s: jnp.any(s.running) & (s.it < self.max_steps),
            lambda s: step_fn.step(s, consts, args),
            state,
        )
        return self._finalize(step_fn.finish(state, consts), raveled)


class ScanAdjoint(_Driver):
    """Bounded-``scan`` driver: reverse-mode differentiable
    (discretize-then-optimize), with optional checkpointed blocks."""

    def __init__(self, stepper=None, controller=None, *, max_steps: int = 256,
                 checkpoint_every: int = 0, **kw):
        self.checkpoint_every = checkpoint_every  # before super() freezes
        super().__init__(stepper, controller, max_steps=max_steps, **kw)

    def solve(
        self,
        f,
        y0,
        t_eval=None,
        *,
        t_start=None,
        t_end=None,
        dt0=None,
        args: Any = None,
    ) -> Solution:
        step_fn, y0_flat, raveled = self._prepare(f, y0)
        state, consts = step_fn.init(y0_flat, t_eval, t_start, t_end, dt0, args)

        def scan_body(s, _):
            return step_fn.step(s, consts, args), None

        if self.checkpoint_every and self.checkpoint_every > 0:
            blocks, rem = divmod(self.max_steps, self.checkpoint_every)

            def block_body(s, _):
                s, _ = jax.lax.scan(scan_body, s, None, length=self.checkpoint_every)
                return s, None

            state, _ = jax.lax.scan(jax.checkpoint(block_body), state, None, length=blocks)
            if rem:
                # The remainder block honours the same checkpoint contract as
                # the full blocks: without the wrap, the tail's `rem` steps of
                # activations would be stored for the backward pass, silently
                # breaking the O(max_steps/checkpoint_every) memory bound
                # whenever max_steps % checkpoint_every != 0.
                def tail_body(s, _):
                    s, _ = jax.lax.scan(scan_body, s, None, length=rem)
                    return s, None

                state, _ = jax.checkpoint(tail_body)(state, None)
        else:
            state, _ = jax.lax.scan(scan_body, state, None, length=self.max_steps)
        return self._finalize(step_fn.finish(state, consts), raveled)


register_config_pytree(AutoDiffAdjoint, ("rtol", "atol"))
register_config_pytree(ScanAdjoint, ("rtol", "atol"))


class BacksolveAdjoint:
    """Adjoint-equation driver (optimize-then-discretize, O(1) memory).
    Frozen and pytree-registered like the loop drivers (tolerances dynamic,
    the rest static).

    Tracks only the final state; its VJP solves the augmented adjoint ODE
    backwards in time via ``core/adjoint.py``.

    **Return contract:** ``solve`` returns the final state ``y(t_end)`` -- an
    array of the same shape as ``y0`` for flat input, the caller's PyTree
    structure otherwise -- NOT a ``Solution``: the custom-VJP forward can only
    expose the differentiable output, so per-instance status/stats are
    unavailable here.  Use ``adjoint_backsolve_problem`` to instrument the
    backward pass, or let ``CompiledSolver`` synthesize a final-state
    ``Solution`` around this driver.

    **Memoization:** the ``custom_vjp`` closure built by ``make_adjoint_solve``
    is memoized per (vector-field identity, state structure) on the driver
    instance and wrapped in ``jax.jit``, so repeated ``solve`` calls with the
    same term reuse one traced program instead of rebuilding (and re-tracing)
    the closure on every call.  Reuse the same driver + term objects across
    solves to hit the cache; the memo is a derived cache excluded from the
    pytree aux data (an unflattened copy starts empty).

    ``ODETerm.batched_args`` terms thread each instance's own parameter row
    through the backward pass (per-request rows stay per-request in the
    returned cotangent).
    """

    __setattr__ = frozen_setattr

    def __init__(
        self,
        stepper: AbstractStepper | str | None = None,
        controller=None,
        *,
        rtol=1e-3,
        atol=1e-6,
        max_steps: int = 10_000,
        mode: str = "joint",
        events=None,
    ):
        if normalize_events(events):
            # Gradients through an event time need the implicit function
            # theorem on the adjoint boundary condition, which the backsolve's
            # custom_vjp does not implement.  Refuse loudly rather than
            # silently ignoring the events.
            raise ValueError(
                "BacksolveAdjoint does not support events: its O(1)-memory "
                "custom_vjp integrates the adjoint ODE from a fixed t_end and "
                "cannot differentiate through per-instance stopping times. "
                "Use AutoDiffAdjoint (forward mode) or ScanAdjoint "
                "(discretize-then-optimize) for event-terminated solves."
            )
        self.stepper = AbstractStepper.coerce(stepper)
        self.controller = controller
        self.rtol = rtol
        self.atol = atol
        self.max_steps = max_steps
        self.mode = mode
        self._solve_memo = {}
        freeze(self)

    def _rebuild_derived(self):
        # Pytree unflatten bypasses __init__; start with a fresh (empty) memo.
        object.__setattr__(self, "_solve_memo", {})

    def _adjoint_solve(self, f, state_key, raveled):
        """The memoized ``make_adjoint_solve`` closure for ``(f, state
        structure)``: rebuilding the ``custom_vjp`` wrapper per call would
        re-trace under ``jit`` on every solve and defeat ``CompiledSolver``
        caching, so the closure (jit-wrapped) is cached on the instance."""
        from .adjoint import make_adjoint_solve  # deferred: adjoint imports loop

        fkey = f if isinstance(f, ODETerm) else (type(f), id(f))
        key = (fkey, state_key)
        solve_fn = self._solve_memo.get(key)
        if solve_fn is None:
            if raveled is None:
                flat_f = f.vf if isinstance(f, ODETerm) else f
            else:
                flat_f = ravel_term(f, raveled).vf
            solve_fn = make_adjoint_solve(
                flat_f,
                method=self.stepper,
                rtol=self.rtol,
                atol=self.atol,
                max_steps=self.max_steps,
                mode=self.mode,
                controller=self.controller,
                batched_args=isinstance(f, ODETerm) and f.batched_args,
            )
            # Eager drivers (concrete tolerances) get a jit wrapper so repeated
            # solves dispatch through jit's C++ fast path.  A driver that was
            # unflattened *inside* another trace has tracer tolerances: the
            # closure must stay un-jitted there (an inner pjit would capture
            # the outer trace's tracers as constants and fail at lowering),
            # and the surrounding trace compiles it anyway.
            if not any(
                isinstance(x, jax.core.Tracer) for x in (self.rtol, self.atol)
            ):
                solve_fn = jax.jit(solve_fn)
            self._solve_memo[key] = solve_fn
        return solve_fn

    def solve(self, f, y0, *, t_start, t_end, args: Any = None):
        y0_flat, raveled = ravel_state(y0)
        # None for flat states; (treedef, per-leaf shape/dtype) for PyTrees --
        # the unravel closure is structure-specific, so the memo must be too.
        state_key = None if raveled is None else tree_key(y0)
        solve_fn = self._adjoint_solve(f, state_key, raveled)
        ys = solve_fn(y0_flat, t_start, t_end, args)
        return raveled.unravel(ys) if raveled is not None else ys


register_config_pytree(BacksolveAdjoint, ("rtol", "atol"), derived_fields=("_solve_memo",))
