"""Butcher tableaus for the explicit Runge-Kutta steppers.

Conventions:
  - ``a`` is the full (s, s) lower-triangular stage matrix.
  - ``b_sol`` are the solution weights, ``b_err = b_sol - b_hat`` are the weights
    of the embedded error estimate (``None`` for fixed-step methods).
  - ``fsal``: the last stage equals f(t + dt, y1), so an accepted step seeds the
    next step's first stage for free (First Same As Last).
  - ``ssal``: the solution is available before the last stage (Solution Same As
    Last) -- dopri5/tsit5's last stage is evaluated *at* the solution, which also
    makes f1 for dense output free.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class ButcherTableau:
    name: str
    order: int  # order of the solution advance
    error_order: int  # order of the embedded (lower-order) estimate + 1 == controller k
    a: np.ndarray  # (s, s)
    b_sol: np.ndarray  # (s,)
    b_err: np.ndarray | None  # (s,)
    c: np.ndarray  # (s,)
    fsal: bool
    ssal: bool

    @property
    def stages(self) -> int:
        return len(self.c)


def _tri(rows, s):
    a = np.zeros((s, s), dtype=np.float64)
    for i, row in enumerate(rows):
        a[i + 1, : len(row)] = row
    return a


EULER = ButcherTableau(
    name="euler",
    order=1,
    error_order=2,
    a=np.zeros((1, 1)),
    b_sol=np.array([1.0]),
    b_err=None,
    c=np.array([0.0]),
    fsal=False,
    ssal=False,
)

MIDPOINT = ButcherTableau(
    name="midpoint",
    order=2,
    error_order=2,
    a=_tri([[0.5]], 2),
    b_sol=np.array([0.0, 1.0]),
    b_err=None,
    c=np.array([0.0, 0.5]),
    fsal=False,
    ssal=False,
)

# The classic fixed-step RK4.
RK4 = ButcherTableau(
    name="rk4",
    order=4,
    error_order=4,
    a=_tri([[0.5], [0.0, 0.5], [0.0, 0.0, 1.0]], 4),
    b_sol=np.array([1 / 6, 1 / 3, 1 / 3, 1 / 6]),
    b_err=None,
    c=np.array([0.0, 0.5, 0.5, 1.0]),
    fsal=False,
    ssal=False,
)

# Heun-Euler 2(1) embedded pair.
HEUN = ButcherTableau(
    name="heun",
    order=2,
    error_order=2,
    a=_tri([[1.0]], 2),
    b_sol=np.array([0.5, 0.5]),
    b_err=np.array([0.5, 0.5]) - np.array([1.0, 0.0]),
    c=np.array([0.0, 1.0]),
    fsal=False,
    ssal=False,
)

# Bogacki--Shampine 3(2).
BOSH3 = ButcherTableau(
    name="bosh3",
    order=3,
    error_order=3,
    a=_tri([[1 / 2], [0.0, 3 / 4], [2 / 9, 1 / 3, 4 / 9]], 4),
    b_sol=np.array([2 / 9, 1 / 3, 4 / 9, 0.0]),
    b_err=np.array([2 / 9, 1 / 3, 4 / 9, 0.0]) - np.array([7 / 24, 1 / 4, 1 / 3, 1 / 8]),
    c=np.array([0.0, 1 / 2, 3 / 4, 1.0]),
    fsal=True,
    ssal=True,
)

# Dormand--Prince 5(4), the paper's benchmark method ("dopri5").
_DOPRI5_B = np.array([35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0])
_DOPRI5_BHAT = np.array(
    [5179 / 57600, 0.0, 7571 / 16695, 393 / 640, -92097 / 339200, 187 / 2100, 1 / 40]
)
DOPRI5 = ButcherTableau(
    name="dopri5",
    order=5,
    error_order=5,
    a=_tri(
        [
            [1 / 5],
            [3 / 40, 9 / 40],
            [44 / 45, -56 / 15, 32 / 9],
            [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729],
            [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656],
            list(_DOPRI5_B[:6]),
        ],
        7,
    ),
    b_sol=_DOPRI5_B,
    b_err=_DOPRI5_B - _DOPRI5_BHAT,
    c=np.array([0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0]),
    fsal=True,
    ssal=True,
)

# Tsitouras 5(4) ("tsit5"), torchode's other recommended method.
_TSIT5_B = np.array(
    [
        0.09646076681806523,
        0.01,
        0.4798896504144996,
        1.379008574103742,
        -3.290069515436081,
        2.324710524099774,
        0.0,
    ]
)
_TSIT5_BERR = np.array(
    [
        -0.00178001105222577714,
        -0.0008164344596567469,
        0.007880878010261995,
        -0.1447110071732629,
        0.5823571654525552,
        -0.45808210592918697,
        1 / 66,
    ]
)
TSIT5 = ButcherTableau(
    name="tsit5",
    order=5,
    error_order=5,
    a=_tri(
        [
            [0.161],
            [-0.008480655492356989, 0.335480655492357],
            [2.8971530571054935, -6.359448489975075, 4.3622954328695815],
            [
                5.325864828439257,
                -11.748883564062828,
                7.4955393428898365,
                -0.09249506636175525,
            ],
            [
                5.86145544294642,
                -12.92096931784711,
                8.159367898576159,
                -0.071584973281401,
                -0.028269050394068383,
            ],
            list(_TSIT5_B[:6]),
        ],
        7,
    ),
    b_sol=_TSIT5_B,
    b_err=_TSIT5_BERR,
    c=np.array([0.0, 0.161, 0.327, 0.9, 0.9800255409045097, 1.0, 1.0]),
    fsal=True,
    ssal=True,
)

TABLEAUS = {t.name: t for t in (EULER, MIDPOINT, RK4, HEUN, BOSH3, DOPRI5, TSIT5)}


def get_tableau(name: str) -> ButcherTableau:
    try:
        return TABLEAUS[name]
    except KeyError:
        raise ValueError(f"unknown method {name!r}; available: {sorted(TABLEAUS)}") from None
