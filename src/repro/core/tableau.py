"""Butcher tableaus for the explicit and diagonally implicit RK steppers.

Conventions:
  - ``a`` is the full (s, s) lower-triangular stage matrix.  Explicit methods
    have a zero diagonal; SDIRK/ESDIRK methods carry the implicit coefficient
    ``gamma`` on the diagonal of their implicit stages.
  - ``b_sol`` are the solution weights, ``b_err = b_sol - b_hat`` are the weights
    of the embedded error estimate (``None`` for fixed-step methods).
  - ``fsal``: the last stage equals f(t + dt, y1), so an accepted step seeds the
    next step's first stage for free (First Same As Last).  For the stiffly
    accurate implicit tableaus below (b_sol == last row of ``a``, c_s == 1) the
    same property holds: the last stage derivative IS f(t + dt, y1).
  - ``ssal``: the solution is available before the last stage (Solution Same As
    Last) -- dopri5/tsit5's last stage is evaluated *at* the solution, which also
    makes f1 for dense output free.
  - ``implicit``: at least one diagonal entry of ``a`` is nonzero; the tableau
    must be driven by ``DiagonallyImplicitRK`` (stage equations solved by the
    batched masked-Newton layer), never by the explicit stage recursion.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .static import register_static


def _readonly(arr: np.ndarray | None) -> np.ndarray | None:
    if arr is None:
        return None
    arr = np.array(arr, copy=True)
    arr.setflags(write=False)
    return arr


def _key(arr: np.ndarray | None):
    return None if arr is None else (arr.shape, arr.dtype.str, arr.tobytes())


@register_static
@dataclasses.dataclass(frozen=True, eq=False)
class ButcherTableau:
    """A tableau is *static solver config*: its coefficients are host-side
    numpy constants that the kernels unroll at compile time, never runtime
    arrays.  It is hashable by value (so equal tableaus key to the same
    compiled program), its arrays are frozen read-only copies, and it is
    pytree-registered with zero leaves so it can cross ``jax.jit`` boundaries
    as an ordinary argument."""

    name: str
    order: int  # order of the solution advance
    error_order: int  # order of the embedded (lower-order) estimate + 1 == controller k
    a: np.ndarray  # (s, s)
    b_sol: np.ndarray  # (s,)
    b_err: np.ndarray | None  # (s,)
    c: np.ndarray  # (s,)
    fsal: bool
    ssal: bool
    implicit: bool = False

    def __post_init__(self):
        for f in ("a", "b_sol", "b_err", "c"):
            object.__setattr__(self, f, _readonly(getattr(self, f)))

    def _identity(self) -> tuple:
        return (
            self.name, self.order, self.error_order,
            _key(self.a), _key(self.b_sol), _key(self.b_err), _key(self.c),
            self.fsal, self.ssal, self.implicit,
        )

    def __eq__(self, other):
        if not isinstance(other, ButcherTableau):
            return NotImplemented
        return self._identity() == other._identity()

    def __hash__(self):
        return hash(self._identity())

    @property
    def stages(self) -> int:
        return len(self.c)

    @property
    def stiffly_accurate(self) -> bool:
        """b_sol equals the last row of ``a``: y1 is the last stage value, so
        (with c_s == 1) the last stage derivative is f(t + dt, y1) for free."""
        return bool(np.allclose(self.a[-1], self.b_sol))

    @property
    def diagonal(self) -> float:
        """The shared implicit coefficient gamma of an SDIRK/ESDIRK tableau
        (every implicit stage carries the same diagonal entry, so one
        I - dt*gamma*J matrix serves all stages of a step)."""
        diag = np.diag(self.a)
        nz = diag[diag != 0.0]
        if nz.size == 0:
            return 0.0
        if not np.allclose(nz, nz[0]):
            raise ValueError(
                f"tableau {self.name!r} has non-constant implicit diagonal {diag}"
            )
        return float(nz[0])


def _tri(rows, s):
    a = np.zeros((s, s), dtype=np.float64)
    for i, row in enumerate(rows):
        a[i + 1, : len(row)] = row
    return a


EULER = ButcherTableau(
    name="euler",
    order=1,
    error_order=2,
    a=np.zeros((1, 1)),
    b_sol=np.array([1.0]),
    b_err=None,
    c=np.array([0.0]),
    fsal=False,
    ssal=False,
)

MIDPOINT = ButcherTableau(
    name="midpoint",
    order=2,
    error_order=2,
    a=_tri([[0.5]], 2),
    b_sol=np.array([0.0, 1.0]),
    b_err=None,
    c=np.array([0.0, 0.5]),
    fsal=False,
    ssal=False,
)

# The classic fixed-step RK4.
RK4 = ButcherTableau(
    name="rk4",
    order=4,
    error_order=4,
    a=_tri([[0.5], [0.0, 0.5], [0.0, 0.0, 1.0]], 4),
    b_sol=np.array([1 / 6, 1 / 3, 1 / 3, 1 / 6]),
    b_err=None,
    c=np.array([0.0, 0.5, 0.5, 1.0]),
    fsal=False,
    ssal=False,
)

# Heun-Euler 2(1) embedded pair.
HEUN = ButcherTableau(
    name="heun",
    order=2,
    error_order=2,
    a=_tri([[1.0]], 2),
    b_sol=np.array([0.5, 0.5]),
    b_err=np.array([0.5, 0.5]) - np.array([1.0, 0.0]),
    c=np.array([0.0, 1.0]),
    fsal=False,
    ssal=False,
)

# Bogacki--Shampine 3(2).
BOSH3 = ButcherTableau(
    name="bosh3",
    order=3,
    error_order=3,
    a=_tri([[1 / 2], [0.0, 3 / 4], [2 / 9, 1 / 3, 4 / 9]], 4),
    b_sol=np.array([2 / 9, 1 / 3, 4 / 9, 0.0]),
    b_err=np.array([2 / 9, 1 / 3, 4 / 9, 0.0]) - np.array([7 / 24, 1 / 4, 1 / 3, 1 / 8]),
    c=np.array([0.0, 1 / 2, 3 / 4, 1.0]),
    fsal=True,
    ssal=True,
)

# Dormand--Prince 5(4), the paper's benchmark method ("dopri5").
_DOPRI5_B = np.array([35 / 384, 0.0, 500 / 1113, 125 / 192, -2187 / 6784, 11 / 84, 0.0])
_DOPRI5_BHAT = np.array(
    [5179 / 57600, 0.0, 7571 / 16695, 393 / 640, -92097 / 339200, 187 / 2100, 1 / 40]
)
DOPRI5 = ButcherTableau(
    name="dopri5",
    order=5,
    error_order=5,
    a=_tri(
        [
            [1 / 5],
            [3 / 40, 9 / 40],
            [44 / 45, -56 / 15, 32 / 9],
            [19372 / 6561, -25360 / 2187, 64448 / 6561, -212 / 729],
            [9017 / 3168, -355 / 33, 46732 / 5247, 49 / 176, -5103 / 18656],
            list(_DOPRI5_B[:6]),
        ],
        7,
    ),
    b_sol=_DOPRI5_B,
    b_err=_DOPRI5_B - _DOPRI5_BHAT,
    c=np.array([0.0, 1 / 5, 3 / 10, 4 / 5, 8 / 9, 1.0, 1.0]),
    fsal=True,
    ssal=True,
)

# Tsitouras 5(4) ("tsit5"), torchode's other recommended method.
_TSIT5_B = np.array(
    [
        0.09646076681806523,
        0.01,
        0.4798896504144996,
        1.379008574103742,
        -3.290069515436081,
        2.324710524099774,
        0.0,
    ]
)
_TSIT5_BERR = np.array(
    [
        -0.00178001105222577714,
        -0.0008164344596567469,
        0.007880878010261995,
        -0.1447110071732629,
        0.5823571654525552,
        -0.45808210592918697,
        1 / 66,
    ]
)
TSIT5 = ButcherTableau(
    name="tsit5",
    order=5,
    error_order=5,
    a=_tri(
        [
            [0.161],
            [-0.008480655492356989, 0.335480655492357],
            [2.8971530571054935, -6.359448489975075, 4.3622954328695815],
            [
                5.325864828439257,
                -11.748883564062828,
                7.4955393428898365,
                -0.09249506636175525,
            ],
            [
                5.86145544294642,
                -12.92096931784711,
                8.159367898576159,
                -0.071584973281401,
                -0.028269050394068383,
            ],
            list(_TSIT5_B[:6]),
        ],
        7,
    ),
    b_sol=_TSIT5_B,
    b_err=_TSIT5_BERR,
    c=np.array([0.0, 0.161, 0.327, 0.9, 0.9800255409045097, 1.0, 1.0]),
    fsal=True,
    ssal=True,
)

# --------------------------------------------------------------------------
# Diagonally implicit (SDIRK/ESDIRK) tableaus for stiff problems.  All four
# are stiffly accurate (b_sol == last row of a, c_s == 1), so the last stage
# derivative doubles as the FSAL cache, and all share a single diagonal
# coefficient gamma, so one I - dt*gamma*J matrix serves every stage.

# Backward Euler: L-stable, order 1, no embedded estimate (fixed-step).
IMPLICIT_EULER = ButcherTableau(
    name="implicit_euler",
    order=1,
    error_order=2,
    a=np.array([[1.0]]),
    b_sol=np.array([1.0]),
    b_err=None,
    c=np.array([1.0]),
    fsal=True,
    ssal=True,
    implicit=True,
)

# TR-BDF2 as an ESDIRK 2(3) pair (Hosea & Shampine 1996): one trapezoidal
# substage + one BDF2 substage, L-stable, with a 3rd-order embedded estimate.
_TRBDF2_G = 2.0 - np.sqrt(2.0)  # gamma: the intermediate abscissa
_TRBDF2_D = _TRBDF2_G / 2.0  # the shared implicit diagonal
_TRBDF2_W = np.sqrt(2.0) / 4.0
TRBDF2 = ButcherTableau(
    name="trbdf2",
    order=2,
    error_order=3,
    a=np.array(
        [
            [0.0, 0.0, 0.0],
            [_TRBDF2_D, _TRBDF2_D, 0.0],
            [_TRBDF2_W, _TRBDF2_W, _TRBDF2_D],
        ]
    ),
    b_sol=np.array([_TRBDF2_W, _TRBDF2_W, _TRBDF2_D]),
    b_err=np.array([_TRBDF2_W, _TRBDF2_W, _TRBDF2_D])
    - np.array([(1.0 - _TRBDF2_W) / 3.0, (3.0 * _TRBDF2_W + 1.0) / 3.0, _TRBDF2_D / 3.0]),
    c=np.array([0.0, _TRBDF2_G, 1.0]),
    fsal=True,
    ssal=True,
    implicit=True,
)

# Kvaerno (2004) ESDIRK 3(2): A-L stable, explicit first stage.
_KV3_G = 0.43586652150845899941601945
_KV3_A31 = (-4.0 * _KV3_G**2 + 6.0 * _KV3_G - 1.0) / (4.0 * _KV3_G)
_KV3_A32 = (-2.0 * _KV3_G + 1.0) / (4.0 * _KV3_G)
_KV3_A41 = (6.0 * _KV3_G - 1.0) / (12.0 * _KV3_G)
_KV3_A42 = -1.0 / ((24.0 * _KV3_G - 12.0) * _KV3_G)
_KV3_A43 = (-6.0 * _KV3_G**2 + 6.0 * _KV3_G - 1.0) / (6.0 * _KV3_G - 3.0)
_KV3_B = np.array([_KV3_A41, _KV3_A42, _KV3_A43, _KV3_G])
KVAERNO3 = ButcherTableau(
    name="kvaerno3",
    order=3,
    error_order=3,
    a=np.array(
        [
            [0.0, 0.0, 0.0, 0.0],
            [_KV3_G, _KV3_G, 0.0, 0.0],
            [_KV3_A31, _KV3_A32, _KV3_G, 0.0],
            [_KV3_A41, _KV3_A42, _KV3_A43, _KV3_G],
        ]
    ),
    b_sol=_KV3_B,
    b_err=_KV3_B - np.array([_KV3_A31, _KV3_A32, _KV3_G, 0.0]),
    c=np.array([0.0, 2.0 * _KV3_G, 1.0, 1.0]),
    fsal=True,
    ssal=True,
    implicit=True,
)

# Kvaerno (2004) ESDIRK 5(4): the workhorse stiff method (diffrax's kvaerno5).
_KV5_G = 0.26
_KV5_A = np.zeros((7, 7))
_KV5_A[1, :2] = [0.26, 0.26]
_KV5_A[2, :3] = [0.13, 0.84033320996790809, 0.26]
_KV5_A[3, :4] = [0.22371961478320505, 0.47675532319799699, -0.06470895363112615, 0.26]
_KV5_A[4, :5] = [
    0.16648564323248321,
    0.10450018841591720,
    0.03631482272098715,
    -0.13090704451073998,
    0.26,
]
_KV5_A[5, :6] = [
    0.13855640231268224,
    0.0,
    -0.04245337201752043,
    0.02446657898003141,
    0.61943039072480676,
    0.26,
]
_KV5_A[6, :7] = [
    0.13659751177640291,
    0.0,
    -0.05496908796538376,
    -0.04118626728321046,
    0.62993304899016403,
    0.06962479448202728,
    0.26,
]
_KV5_B = _KV5_A[6].copy()
_KV5_BHAT = np.append(_KV5_A[5, :5], [0.26, 0.0])
KVAERNO5 = ButcherTableau(
    name="kvaerno5",
    order=5,
    error_order=5,
    a=_KV5_A,
    b_sol=_KV5_B,
    b_err=_KV5_B - _KV5_BHAT,
    c=np.array([0.0, 0.52, 1.230333209967908, 0.895765984350076, 0.436393609858648, 1.0, 1.0]),
    fsal=True,
    ssal=True,
    implicit=True,
)

TABLEAUS = {
    t.name: t
    for t in (
        EULER,
        MIDPOINT,
        RK4,
        HEUN,
        BOSH3,
        DOPRI5,
        TSIT5,
        IMPLICIT_EULER,
        TRBDF2,
        KVAERNO3,
        KVAERNO5,
    )
}


def get_tableau(name: str) -> ButcherTableau:
    try:
        return TABLEAUS[name]
    except KeyError:
        raise ValueError(f"unknown method {name!r}; available: {sorted(TABLEAUS)}") from None
