"""Per-instance event handling: detection and localization on dense output.

An ``Event`` observes the solution through a scalar condition function
``cond_fn(t, y, args)`` and *fires* when that condition crosses zero between
two accepted solver states.  Detection is a per-instance sign test on every
accepted step; localization refines the crossing time by masked bisection on
the stepper's dense-output interpolant (the cubic Hermite the solver already
builds for ``t_eval``), so pinning down the event time costs ZERO extra
vector-field evaluations -- each bisection iteration evaluates only the
interpolant polynomial (the ``masked_bisect_refine`` kernel op) and the
condition function on the interpolated state.

Everything is batched with per-instance masks, the same discipline as the
outer loop and the Newton layer: each instance in the batch detects, localizes
and (for ``terminal`` events) terminates independently, and instances whose
events already fired ride along frozen.  ``StepFunction`` threads an
``EventState`` through the loop and turns a fired terminal event into a
per-instance stop with ``Status.EVENT``, truncating dense output past the
event time.

Semantics (matching ``scipy.integrate.solve_ivp`` events):

direction
    ``0`` fires on any zero crossing, ``> 0`` only when the condition goes
    from negative to positive (rising), ``< 0`` only falling.  A condition
    that is zero at both endpoints of a step does not fire (an identically
    zero condition never fires).
terminal
    ``True`` stops the instance at the event time: its committed state
    becomes the interpolated ``(event_t, event_y)`` and its status
    ``Status.EVENT``.  ``False`` records the FIRST crossing per (instance,
    event) and keeps integrating (fixed-shape buffers cannot hold an
    unbounded crossing list; re-arm by solving again from the event time).

A crossing that enters and leaves zero within a single accepted step (an even
number of crossings) is invisible to the endpoint sign test -- the standard
limitation of sampled event detection; tighten tolerances to shrink steps
near an expected event.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from ..kernels import ops
from .static import register_static


@register_static
@dataclasses.dataclass(frozen=True)
class Event:
    """A scalar zero-crossing condition on the solution.

    An ``Event`` spec is static solver config: frozen, hashable (the
    condition callable hashes by identity) and pytree-registered with zero
    leaves so it crosses ``jax.jit`` boundaries unchanged.  Data the
    condition needs at runtime flows through ``args``.

    ``batched=False`` (default): ``cond_fn(t, y, args) -> scalar`` is written
    for a single instance (scalar ``t``, ``(f,)`` -- or the user's PyTree --
    state) and is vmapped over the batch, mirroring scipy's event signature.
    ``batched=True``: ``cond_fn`` handles ``(b,)`` times and ``(b, f)`` states
    directly and returns ``(b,)`` values (not supported for PyTree states,
    whose per-instance structure only exists inside the vmap).
    """

    cond_fn: Callable[..., Any]
    terminal: bool = True
    direction: float = 0.0
    batched: bool = False
    with_args: bool = True

    def value(self, t: jax.Array, y: jax.Array, args: Any) -> jax.Array:
        """Batched condition values: ((b,), (b, f)) -> (b,)."""
        if self.batched:
            out = self.cond_fn(t, y, args) if self.with_args else self.cond_fn(t, y)
        else:
            if self.with_args:
                out = jax.vmap(lambda ti, yi: self.cond_fn(ti, yi, args))(t, y)
            else:
                out = jax.vmap(self.cond_fn)(t, y)
        return jnp.asarray(out, dtype=y.dtype).reshape(t.shape)


def normalize_events(events) -> tuple[Event, ...]:
    """Accept None, a single Event or a sequence; return a tuple of Events."""
    if events is None:
        return ()
    if isinstance(events, Event):
        return (events,)
    events = tuple(events)
    for e in events:
        if not isinstance(e, Event):
            raise TypeError(f"expected Event, got {type(e).__name__}; wrap cond_fn in Event(...)")
    return events


class EventState(NamedTuple):
    """Loop-carried per-instance event bookkeeping (all (b, E)-shaped, E = #events)."""

    value: jax.Array  # (b, E) condition values at the current accepted state
    fired: jax.Array  # (b, E) bool: first crossing already recorded
    t: jax.Array  # (b, E) localized first-crossing times (NaN until fired)
    y: jax.Array  # (b, E, f) interpolated states at the crossings


def init_event_state(
    events: Sequence[Event], t0: jax.Array, y0: jax.Array, args: Any
) -> EventState:
    b, f = y0.shape
    E = len(events)
    value = jnp.stack([e.value(t0, y0, args) for e in events], axis=1)
    return EventState(
        value=value,
        fired=jnp.zeros((b, E), dtype=bool),
        t=jnp.full((b, E), jnp.nan, dtype=t0.dtype),
        y=jnp.zeros((b, E, f), dtype=y0.dtype),
    )


def _localize(
    event: Event,
    coeffs,
    t0: jax.Array,  # (b,) step start times
    dt: jax.Array,  # (b,) signed step sizes actually taken
    v0: jax.Array,  # (b,) condition values at x = 0
    active: jax.Array,  # (b,) bool: instances whose crossing to localize
    args: Any,
    iters: int,
) -> tuple[jax.Array, jax.Array]:
    """Bisect the crossing of ``event`` on the interpolant, masked by ``active``.

    The bracket lives in interpolant coordinates x = (t - t0)/dt in [0, 1]
    (monotone along the trajectory for either time direction).  Returns
    ``(x, y)``: the bracket midpoint after ``iters`` halvings and the
    interpolated state there; garbage where ``~active`` (callers mask).
    """
    lo = jnp.zeros_like(t0)
    hi = jnp.ones_like(t0)
    none = jnp.zeros(t0.shape, dtype=bool)
    # Priming call with an all-False mask: leaves the bracket at [0, 1] and
    # evaluates the interpolant at its midpoint, seeding the loop carry.
    carry = ops.masked_bisect_refine(coeffs, lo, hi, v0, v0, none)

    def body(_, carry):
        lo, hi, v_lo, mid, y_mid = carry
        v_mid = event.value(t0 + mid * dt, y_mid, args)
        return ops.masked_bisect_refine(coeffs, lo, hi, v_lo, v_mid, active)

    lo, hi, v_lo, mid, y_mid = jax.lax.fori_loop(0, iters, body, carry)
    return mid, y_mid


class EventAdvance(NamedTuple):
    """What one step's event processing hands back to ``StepFunction.step``."""

    estate: EventState
    stop: jax.Array  # (b,) bool: a terminal event fired this step
    t_stop: jax.Array  # (b,) earliest terminal event time (valid where stop)
    y_stop: jax.Array  # (b, f) interpolated state there (valid where stop)
    n_new: jax.Array  # (b,) int32: events recorded this step


def advance(
    events: Sequence[Event],
    estate: EventState,
    coeffs,  # dense-output interpolant coefficients of this step
    t0: jax.Array,  # (b,) step start times
    dt: jax.Array,  # (b,) signed step sizes actually taken
    t_new: jax.Array,  # (b,) step end times
    y_new: jax.Array,  # (b, f) accepted candidate states
    accept: jax.Array,  # (b,) bool (already masked by running)
    args: Any,
    iters: int,
) -> EventAdvance:
    """Detect, localize and record this step's crossings, per instance.

    Each event's bisection (the only nontrivial cost) runs under a
    ``lax.cond`` on "any instance fired THIS event", so steps without
    crossings pay E condition evaluations and nothing else.

    Gradients: the bisection returns bracket midpoints that are dyadic
    constants in x, so differentiating ``event_t = t0 + x*dt`` carries only
    the firing step's endpoint sensitivities -- NOT the implicit-function
    event derivative -(dg/dtheta)/(dg/dt).  Treat event-time gradients (in
    either AD mode) as approximate; apply the IFT correction outside the
    solver when exact sensitivities are needed.
    """
    # Condition evaluation is user code and cannot fuse; the sign tests and
    # the value carry are ONE registry op (in-kernel on the Pallas backends).
    v_new = jnp.stack([e.value(t_new, y_new, args) for e in events], axis=1)
    newly, v_keep = ops.fused_event_detect(
        estate.value, v_new, estate.fired, accept,
        directions=tuple(e.direction for e in events),
    )  # (b, E) each

    # Each event's bisection runs under its OWN cond: a step where only one
    # of E events fires pays one localizer, not E.
    xs, ys = [], []
    for i, e in enumerate(events):
        x_i, y_i = jax.lax.cond(
            jnp.any(newly[:, i]),
            lambda i=i, e=e: _localize(
                e, coeffs, t0, dt, estate.value[:, i], newly[:, i], args, iters
            ),
            lambda: (jnp.zeros_like(t0), jnp.zeros_like(y_new)),
        )
        xs.append(x_i)
        ys.append(y_i)
    x, y_ev = jnp.stack(xs, axis=1), jnp.stack(ys, axis=1)  # (b, E), (b, E, f)

    # Terminal resolution (the instance stops at its EARLIEST terminal
    # crossing; crossings localized after that point happened beyond the end
    # of this instance's trajectory and are discarded -- not recorded, so a
    # re-solve from the event time can still observe them), bookkeeping
    # update and stop outputs: ONE registry op over the localizer's outputs.
    fired, ev_t, ev_y, stop, t_stop, y_stop, n_new = ops.fused_event_commit(
        x, y_ev, newly, y_new, t0, dt, estate.fired, estate.t, estate.y,
        terminal=tuple(e.terminal for e in events),
    )
    return EventAdvance(
        estate=EventState(value=v_keep, fired=fired, t=ev_t, y=ev_y),
        stop=stop,
        t_stop=t_stop,
        y_stop=y_stop,
        n_new=n_new,
    )
