"""Explicit Runge-Kutta stepping: the swappable "step method" component.

``Stepper`` owns the Butcher tableau, the fused RK step (FSAL/SSAL reuse) and
the dense-output interpolant.  One ``step`` computes all stage derivatives,
the 5th/embedded-order update and the error estimate.  The per-stage
accumulation and the final (update, error) pair go through
``repro.kernels.ops`` so the hot loops run as single fused kernels (Pallas on
TPU, XLA-fused jnp on CPU).

The module-level ``rk_step`` / ``initial_step_size`` functions remain the
underlying primitives; ``Stepper`` is the object the drivers compose with a
term and a controller (``AutoDiffAdjoint(Stepper("tsit5"), pid_controller())``).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .tableau import ButcherTableau, get_tableau
from .terms import ODETerm


class StepResult(NamedTuple):
    y1: jax.Array  # (b, f) candidate next state
    err: jax.Array  # (b, f) embedded error estimate (zeros for fixed-step)
    f1: jax.Array  # (b, f) f(t + dt, y1) -- exact for FSAL/SSAL tableaus
    n_f_evals: int  # static count of dynamics evaluations in this step


def rk_step(
    term: ODETerm,
    tab: ButcherTableau,
    t: jax.Array,  # (b,)
    dt: jax.Array,  # (b,)
    y: jax.Array,  # (b, f)
    f0: jax.Array,  # (b, f) derivative at (t, y); FSAL cache
    args: Any,
) -> StepResult:
    import numpy as np

    s = tab.stages
    dtype = y.dtype
    # Tableau coefficients stay as host-side numpy: they are compile-time
    # constants, which lets the Pallas kernels unroll them into the VPU
    # instruction stream (no coefficient loads at runtime).
    a = np.asarray(tab.a, dtype=dtype)
    c = np.asarray(tab.c, dtype=dtype)
    b_sol = np.asarray(tab.b_sol, dtype=dtype)
    b_err = (
        np.asarray(tab.b_err, dtype=dtype)
        if tab.b_err is not None
        else np.zeros((s,), dtype=dtype)
    )

    ks = [f0]  # stage 0 is always f(t, y) == the FSAL cache
    n_evals = 0
    for i in range(1, s):
        K = jnp.stack(ks)
        yi = ops.stage_accum(y, dt, K, a[i, :i])
        ti = t + c[i] * dt
        ks.append(term.vf(ti, yi, args))
        n_evals += 1

    K = jnp.stack(ks)
    y1, err = ops.fused_update(y, K, dt, b_sol, b_err)

    if tab.fsal:
        f1 = ks[-1]
    else:
        f1 = term.vf(t + dt, y1, args)
        n_evals += 1
    return StepResult(y1=y1, err=err, f1=f1, n_f_evals=n_evals)


def initial_step_size(
    term: ODETerm,
    t0: jax.Array,  # (b,)
    y0: jax.Array,  # (b, f)
    f0: jax.Array,  # (b, f)
    direction: jax.Array,  # (b,) +-1
    order: int,
    atol,
    rtol,
    args: Any = None,
    *,
    dt_min: float = 0.0,
    dt_max: float = float("inf"),
) -> jax.Array:
    """Hairer/Noersett/Wanner automatic initial step selection, vectorized.

    The proposal magnitude is clamped to ``[dt_min, dt_max]`` so an over-eager
    first step can never exceed the controller's step bounds (on smooth
    problems the heuristic happily proposes steps 100x larger than ``h0``).
    """
    dtype = y0.dtype
    atol = jnp.asarray(atol, dtype=dtype)
    rtol = jnp.asarray(rtol, dtype=dtype)
    if atol.ndim == 1:
        atol = atol[:, None]
    if rtol.ndim == 1:
        rtol = rtol[:, None]
    scale = atol + jnp.abs(y0) * rtol

    def rms(x):
        return ops.rms_norm(x, scale)

    d0 = rms(y0)
    d1 = rms(f0)
    h0 = jnp.where((d0 < 1e-5) | (d1 < 1e-5), 1e-6, 0.01 * d0 / jnp.maximum(d1, 1e-30))

    y1 = y0 + (h0 * direction)[:, None] * f0
    f1 = term.vf(t0 + h0 * direction, y1, args)
    d2 = rms(f1 - f0) / jnp.maximum(h0, 1e-30)

    dmax = jnp.maximum(d1, d2)
    h1 = jnp.where(
        dmax <= 1e-15,
        jnp.maximum(1e-6, h0 * 1e-3),
        (0.01 / jnp.maximum(dmax, 1e-30)) ** (1.0 / order),
    )
    h = jnp.clip(jnp.minimum(100.0 * h0, h1), dt_min, dt_max)
    return h * direction


class Stepper:
    """Owns tableau + RK step + interpolant; stateless across steps.

    Construct from a method name or an explicit tableau::

        Stepper("tsit5")
        Stepper(my_tableau)

    Contributes ``n_f_evals`` to the solver's statistics registry (the static
    per-step evaluation count, shared across the batch because the dynamics
    run on the full batch while any instance is running -- torchode's
    "overhanging evaluations").
    """

    def __init__(self, method: str | ButcherTableau = "dopri5"):
        self.tableau = get_tableau(method) if isinstance(method, str) else method

    @classmethod
    def coerce(cls, value: "Stepper | str | ButcherTableau | None") -> "Stepper":
        """Normalize the stepper argument accepted by drivers/StepFunction."""
        if value is None:
            return cls()
        if isinstance(value, Stepper):
            return value
        return cls(value)

    @property
    def order(self) -> int:
        return self.tableau.order

    @property
    def error_order(self) -> int:
        return self.tableau.error_order

    @property
    def is_adaptive(self) -> bool:
        return self.tableau.b_err is not None

    def init(self, term: ODETerm, t0: jax.Array, y0: jax.Array, args: Any) -> jax.Array:
        """Seed the FSAL derivative cache: f(t0, y0)."""
        return term.vf(t0, y0, args)

    def step(
        self,
        term: ODETerm,
        t: jax.Array,
        dt: jax.Array,
        y: jax.Array,
        f0: jax.Array,
        args: Any,
    ) -> StepResult:
        return rk_step(term, self.tableau, t, dt, y, f0, args)

    def interp_coeffs(self, y0, y1, f0, f1, dt):
        """Dense-output interpolant coefficients (cubic Hermite, Horner form)."""
        return ops.hermite_coeffs(y0, y1, f0, f1, dt)

    def initial_step_size(
        self,
        term: ODETerm,
        t0,
        y0,
        f0,
        direction,
        atol,
        rtol,
        args: Any = None,
        *,
        dt_min: float = 0.0,
        dt_max: float = float("inf"),
    ) -> jax.Array:
        return initial_step_size(
            term, t0, y0, f0, direction, self.tableau.order, atol, rtol, args,
            dt_min=dt_min, dt_max=dt_max,
        )

    # --- statistics registry contribution ---
    def init_stats(self, batch: int) -> dict[str, jax.Array]:
        return {"n_f_evals": jnp.zeros((batch,), dtype=jnp.int32)}

    def update_stats(self, stats: dict, ctx) -> dict:
        return {
            **stats,
            "n_f_evals": stats["n_f_evals"] + ctx.step_active * ctx.n_f_evals,
        }

    def __repr__(self) -> str:
        return f"Stepper({self.tableau.name!r})"
