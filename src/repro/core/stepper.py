"""Explicit Runge-Kutta stepper with FSAL/SSAL reuse and fused stage math.

One ``step`` computes all stage derivatives, the 5th/embedded-order update and
the error estimate.  The per-stage accumulation and the final (update, error)
pair go through ``repro.kernels.ops`` so the hot loops run as single fused
kernels (Pallas on TPU, XLA-fused jnp on CPU).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .tableau import ButcherTableau
from .terms import ODETerm


class StepResult(NamedTuple):
    y1: jax.Array  # (b, f) candidate next state
    err: jax.Array  # (b, f) embedded error estimate (zeros for fixed-step)
    f1: jax.Array  # (b, f) f(t + dt, y1) -- exact for FSAL/SSAL tableaus
    n_f_evals: int  # static count of dynamics evaluations in this step


def rk_step(
    term: ODETerm,
    tab: ButcherTableau,
    t: jax.Array,  # (b,)
    dt: jax.Array,  # (b,)
    y: jax.Array,  # (b, f)
    f0: jax.Array,  # (b, f) derivative at (t, y); FSAL cache
    args: Any,
) -> StepResult:
    import numpy as np

    s = tab.stages
    dtype = y.dtype
    # Tableau coefficients stay as host-side numpy: they are compile-time
    # constants, which lets the Pallas kernels unroll them into the VPU
    # instruction stream (no coefficient loads at runtime).
    a = np.asarray(tab.a, dtype=dtype)
    c = np.asarray(tab.c, dtype=dtype)
    b_sol = np.asarray(tab.b_sol, dtype=dtype)
    b_err = (
        np.asarray(tab.b_err, dtype=dtype)
        if tab.b_err is not None
        else np.zeros((s,), dtype=dtype)
    )

    ks = [f0]  # stage 0 is always f(t, y) == the FSAL cache
    n_evals = 0
    for i in range(1, s):
        K = jnp.stack(ks)
        yi = ops.stage_accum(y, dt, K, a[i, :i])
        ti = t + c[i] * dt
        ks.append(term.vf(ti, yi, args))
        n_evals += 1

    K = jnp.stack(ks)
    y1, err = ops.fused_update(y, K, dt, b_sol, b_err)

    if tab.fsal:
        f1 = ks[-1]
    else:
        f1 = term.vf(t + dt, y1, args)
        n_evals += 1
    return StepResult(y1=y1, err=err, f1=f1, n_f_evals=n_evals)


def initial_step_size(
    term: ODETerm,
    t0: jax.Array,  # (b,)
    y0: jax.Array,  # (b, f)
    f0: jax.Array,  # (b, f)
    direction: jax.Array,  # (b,) +-1
    order: int,
    atol,
    rtol,
    args: Any = None,
) -> jax.Array:
    """Hairer/Noersett/Wanner automatic initial step selection, vectorized."""
    dtype = y0.dtype
    atol = jnp.asarray(atol, dtype=dtype)
    rtol = jnp.asarray(rtol, dtype=dtype)
    if atol.ndim == 1:
        atol = atol[:, None]
    if rtol.ndim == 1:
        rtol = rtol[:, None]
    scale = atol + jnp.abs(y0) * rtol

    def rms(x):
        return jnp.sqrt(jnp.mean(jnp.square(x / scale), axis=-1))

    d0 = rms(y0)
    d1 = rms(f0)
    h0 = jnp.where((d0 < 1e-5) | (d1 < 1e-5), 1e-6, 0.01 * d0 / jnp.maximum(d1, 1e-30))

    y1 = y0 + (h0 * direction)[:, None] * f0
    f1 = term.vf(t0 + h0 * direction, y1, args)
    d2 = rms(f1 - f0) / jnp.maximum(h0, 1e-30)

    dmax = jnp.maximum(d1, d2)
    h1 = jnp.where(
        dmax <= 1e-15,
        jnp.maximum(1e-6, h0 * 1e-3),
        (0.01 / jnp.maximum(dmax, 1e-30)) ** (1.0 / order),
    )
    return jnp.minimum(100.0 * h0, h1) * direction
