"""Runge-Kutta stepping: the swappable "step method" component hierarchy.

``AbstractStepper`` is the protocol every step method implements -- construct
(``init``/``init_carry``), advance (``step``), interpolate (``interp_coeffs``),
propose a first step (``initial_step_size``) and contribute to the statistics
registry (``init_stats``/``update_stats``).  Two implementations:

``ExplicitRK``
    The tableau + FSAL explicit path (``Stepper`` is kept as a compatibility
    alias).  One ``step`` computes all stage derivatives, the solution update
    and the embedded error estimate through the fused kernels in
    ``repro.kernels.ops``.
``DiagonallyImplicitRK``
    SDIRK/ESDIRK methods for stiff problems (implicit_euler, trbdf2,
    kvaerno3, kvaerno5).  Each implicit stage equation is solved by the
    batched masked-Newton layer in ``core/newton.py`` -- per-instance
    convergence masks, Jacobians from ``ODETerm.vf_jac`` (autodiff default,
    user-overridable) and chord-style Jacobian reuse across stages AND steps
    with a per-instance refresh mask carried in the loop state.

The module-level ``rk_step`` / ``initial_step_size`` functions remain the
underlying primitives; steppers are the objects the drivers compose with a
term and a controller (``AutoDiffAdjoint(ExplicitRK("tsit5"),
pid_controller())`` or ``AutoDiffAdjoint("kvaerno5")``).
"""

from __future__ import annotations

import warnings
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from .newton import NewtonConfig, newton_solve
from .static import freeze, frozen_setattr, register_static, value_eq
from .tableau import ButcherTableau, get_tableau
from .terms import ODETerm


class StepResult(NamedTuple):
    y1: jax.Array  # (b, f) candidate next state
    err: jax.Array  # (b, f) embedded error estimate (zeros for fixed-step)
    f1: jax.Array  # (b, f) f(t + dt, y1) -- exact for FSAL/SSAL tableaus
    n_f_evals: Any  # dynamics evaluations in this step (int or () int32)
    carry: Any = ()  # stepper-private cross-step state proposal (e.g. Jacobian)
    solver_failed: jax.Array | None = None  # (b,) bool: nonlinear solve failed
    stats_aux: dict | None = None  # extra per-step stats (n_newton_iters, ...)


def _tableau_arrays(tab: ButcherTableau, dtype):
    """Tableau coefficients as host-side numpy (a, c, b_sol, b_err): they are
    compile-time constants, which lets the Pallas kernels unroll them into the
    VPU instruction stream (no coefficient loads at runtime).  Fixed-step
    tableaus (b_err is None) get zero error weights."""
    a = np.asarray(tab.a, dtype=dtype)
    c = np.asarray(tab.c, dtype=dtype)
    b_sol = np.asarray(tab.b_sol, dtype=dtype)
    b_err = (
        np.asarray(tab.b_err, dtype=dtype)
        if tab.b_err is not None
        else np.zeros((tab.stages,), dtype=dtype)
    )
    return a, c, b_sol, b_err


def rk_step(
    term: ODETerm,
    tab: ButcherTableau,
    t: jax.Array,  # (b,)
    dt: jax.Array,  # (b,)
    y: jax.Array,  # (b, f)
    f0: jax.Array,  # (b, f) derivative at (t, y); FSAL cache
    args: Any,
) -> StepResult:
    s = tab.stages
    a, c, b_sol, b_err = _tableau_arrays(tab, y.dtype)

    ks = [f0]  # stage 0 is always f(t, y) == the FSAL cache
    n_evals = 0
    for i in range(1, s):
        K = jnp.stack(ks)
        yi = ops.stage_accum(y, dt, K, a[i, :i])
        ti = t + c[i] * dt
        ks.append(term.vf(ti, yi, args))
        n_evals += 1

    K = jnp.stack(ks)
    y1, err = ops.fused_update(y, K, dt, b_sol, b_err)

    if tab.fsal:
        f1 = ks[-1]
    else:
        f1 = term.vf(t + dt, y1, args)
        n_evals += 1
    return StepResult(y1=y1, err=err, f1=f1, n_f_evals=n_evals)


def initial_step_size(
    term: ODETerm,
    t0: jax.Array,  # (b,)
    y0: jax.Array,  # (b, f)
    f0: jax.Array,  # (b, f)
    direction: jax.Array,  # (b,) +-1
    order: int,
    atol,
    rtol,
    args: Any = None,
    *,
    dt_min: float = 0.0,
    dt_max: float = float("inf"),
) -> jax.Array:
    """Hairer/Noersett/Wanner automatic initial step selection, vectorized.

    The proposal magnitude is clamped to ``[dt_min, dt_max]`` so an over-eager
    first step can never exceed the controller's step bounds (on smooth
    problems the heuristic happily proposes steps 100x larger than ``h0``).
    """
    atol, rtol = ops.broadcast_tolerances(atol, rtol, y0.dtype)
    scale = atol + jnp.abs(y0) * rtol

    def rms(x):
        return ops.rms_norm(x, scale)

    d0 = rms(y0)
    d1 = rms(f0)
    h0 = jnp.where((d0 < 1e-5) | (d1 < 1e-5), 1e-6, 0.01 * d0 / jnp.maximum(d1, 1e-30))

    y1 = y0 + (h0 * direction)[:, None] * f0
    f1 = term.vf(t0 + h0 * direction, y1, args)
    d2 = rms(f1 - f0) / jnp.maximum(h0, 1e-30)

    dmax = jnp.maximum(d1, d2)
    h1 = jnp.where(
        dmax <= 1e-15,
        jnp.maximum(1e-6, h0 * 1e-3),
        (0.01 / jnp.maximum(dmax, 1e-30)) ** (1.0 / order),
    )
    h = jnp.clip(jnp.minimum(100.0 * h0, h1), dt_min, dt_max)
    return h * direction


class AbstractStepper:
    """The step-method protocol the drivers and ``StepFunction`` compose.

    A stepper owns a tableau, is stateless across *construction* (all
    cross-step state lives in the loop-carried ``carry`` it proposes), and
    contributes named per-instance accumulators to the statistics registry.

    Steppers are *static solver config*: frozen after ``__init__`` (the
    tableau and every knob may be baked into a cached compiled program),
    hashable by value (equal configs key to the same executable) and --
    for the concrete subclasses below -- pytree-registered with zero leaves
    so they cross ``jax.jit``/``vmap``/``shard_map`` boundaries as ordinary
    arguments.  Subclasses must call ``freeze(self)`` at the end of their
    ``__init__``.
    """

    tableau: ButcherTableau

    __setattr__ = frozen_setattr

    @staticmethod
    def coerce(value: "AbstractStepper | str | ButcherTableau | None") -> "AbstractStepper":
        """Normalize the stepper argument accepted by drivers/StepFunction:
        explicit tableaus get an ``ExplicitRK``, implicit ones a
        ``DiagonallyImplicitRK``."""
        if value is None:
            return ExplicitRK()
        if isinstance(value, AbstractStepper):
            return value
        tab = get_tableau(value) if isinstance(value, str) else value
        return DiagonallyImplicitRK(tab) if tab.implicit else ExplicitRK(tab)

    @property
    def order(self) -> int:
        return self.tableau.order

    @property
    def error_order(self) -> int:
        return self.tableau.error_order

    @property
    def is_adaptive(self) -> bool:
        return self.tableau.b_err is not None

    def init(self, term: ODETerm, t0: jax.Array, y0: jax.Array, args: Any) -> jax.Array:
        """Seed the derivative cache: f(t0, y0) (the FSAL seed)."""
        return term.vf(t0, y0, args)

    def init_carry(self, term: ODETerm, t0, y0, f0, args) -> Any:
        """Build the stepper's cross-step carry (lives in ``LoopState``).
        Explicit methods carry nothing; implicit ones carry the Jacobian and
        its per-instance refresh mask."""
        return ()

    def step(
        self,
        term: ODETerm,
        t: jax.Array,
        dt: jax.Array,
        y: jax.Array,
        f0: jax.Array,
        args: Any,
        carry: Any = (),
        scale: jax.Array | None = None,
    ) -> StepResult:
        raise NotImplementedError

    def commit_carry(self, old: Any, new: Any, accept: jax.Array, running: jax.Array) -> Any:
        """Merge the step's proposed carry into the loop state.  Default:
        advance the carry for running instances, freeze it for finished ones
        (the carry is valid for accepted AND rejected attempts -- e.g. a
        Jacobian evaluated at (t, y) stays correct when the step is retried
        with a smaller dt)."""

        def mask(n, o):
            if n.ndim == 0:  # batch-shared scalar leaves advance as proposed
                return n
            r = running.reshape(running.shape + (1,) * (n.ndim - 1))
            return jnp.where(r, n, o)

        return jax.tree_util.tree_map(mask, new, old)

    def interp_coeffs(self, y0, y1, f0, f1, dt):
        """Dense-output interpolant coefficients (cubic Hermite, Horner form)."""
        return ops.hermite_coeffs(y0, y1, f0, f1, dt)

    def initial_step_size(
        self,
        term: ODETerm,
        t0,
        y0,
        f0,
        direction,
        atol,
        rtol,
        args: Any = None,
        *,
        dt_min: float = 0.0,
        dt_max: float = float("inf"),
    ) -> jax.Array:
        return initial_step_size(
            term, t0, y0, f0, direction, self.tableau.order, atol, rtol, args,
            dt_min=dt_min, dt_max=dt_max,
        )

    # --- statistics registry contribution ---
    def init_stats(self, batch: int) -> dict[str, jax.Array]:
        return {"n_f_evals": jnp.zeros((batch,), dtype=jnp.int32)}

    def update_stats(self, stats: dict, ctx) -> dict:
        return {
            **stats,
            "n_f_evals": stats["n_f_evals"] + ctx.step_active * ctx.n_f_evals,
        }

    def __repr__(self) -> str:
        return f"{type(self).__name__}({self.tableau.name!r})"


@register_static
@value_eq
class ExplicitRK(AbstractStepper):
    """Tableau + explicit RK step + interpolant; stateless across steps.

    Construct from a method name or an explicit tableau::

        ExplicitRK("tsit5")
        ExplicitRK(my_tableau)

    Contributes ``n_f_evals`` to the solver's statistics registry (the static
    per-step evaluation count, shared across the batch because the dynamics
    run on the full batch while any instance is running -- torchode's
    "overhanging evaluations").
    """

    def __init__(self, method: str | ButcherTableau = "dopri5"):
        self.tableau = get_tableau(method) if isinstance(method, str) else method
        if self.tableau.implicit:
            raise ValueError(
                f"tableau {self.tableau.name!r} has implicit stages; "
                "use DiagonallyImplicitRK"
            )
        freeze(self)

    def step(self, term, t, dt, y, f0, args, carry=(), scale=None):
        return rk_step(term, self.tableau, t, dt, y, f0, args)

    def stage_derivatives(self, term, t, dt, y, f0, args):
        """The stacked stage slopes K (s, b, f) WITHOUT the b_sol/b_err
        combination -- the fused-step fast path hands K to the megakernel,
        which does the combine/norm/controller/commit in one launch.
        Bitwise-identical stage recursion to ``rk_step`` (same ops, same
        order).  Returns ``(K, n_f_evals)``."""
        tab = self.tableau
        a, c, _, _ = _tableau_arrays(tab, y.dtype)
        ks = [f0]
        n_evals = 0
        for i in range(1, tab.stages):
            yi = ops.stage_accum(y, dt, jnp.stack(ks), a[i, :i])
            ks.append(term.vf(t + c[i] * dt, yi, args))
            n_evals += 1
        return jnp.stack(ks), n_evals

    def trailing_derivative(self, term, t, dt, y, K, args):
        """The non-FSAL trailing evaluation f(t + dt, y1) the fused fast path
        feeds to the megakernel as ``f1``.  y1 is rebuilt through the same
        ``fused_update`` program the kernel applies internally (on the ref
        backend XLA CSEs the two), and -- like ``rk_step`` -- the evaluation
        happens on every attempt, accepted or rejected, so ``n_f_evals`` and
        the committed derivative cache stay bitwise-identical to the unfused
        path.  Returns ``(f1, n_f_evals_delta)``."""
        tab = self.tableau
        _, _, b_sol, b_err = _tableau_arrays(tab, y.dtype)
        y1, _ = ops.fused_update(y, K, dt, b_sol, b_err)
        return term.vf(t + dt, y1, args), 1


# Compatibility alias: the pre-hierarchy name of the explicit stepper.
Stepper = ExplicitRK


class DIRKCarry(NamedTuple):
    """Cross-step state of ``DiagonallyImplicitRK``: the chord Jacobian and
    the per-instance mask asking for it to be re-evaluated next step."""

    jac: jax.Array  # (b, f, f) df/dy from a previous step (possibly stale)
    refresh: jax.Array  # (b,) bool


@register_static
@value_eq
class DiagonallyImplicitRK(AbstractStepper):
    """SDIRK/ESDIRK stepper for stiff problems, batched-Newton inside.

    Every implicit stage shares the tableau's single diagonal coefficient
    ``gamma``, so one chord matrix ``M = I - dt*gamma*J`` (per instance)
    serves all stages of a step.  ``J`` comes from ``ODETerm.vf_jac`` and is
    reused across stages *and* steps; an instance re-evaluates it only when
    its ``refresh`` flag is set (Newton failed or converged slowly), so
    well-behaved instances amortize one Jacobian over many steps.

    All inner-solver knobs live on ONE object: pass
    ``newton=NewtonConfig(tol=..., max_iters=..., divergence_rate=...,
    slow_iters=...)``.  The legacy loose kwargs (``newton_tol``,
    ``max_newton_iters``, ``slow_iters``) are deprecated aliases that emit a
    ``DeprecationWarning`` and cannot be combined with ``newton=``.

    Statistics: ``n_f_evals`` (batched Newton evaluations, overhanging),
    ``n_newton_iters`` (per-instance inner iterations while running) and
    ``n_jac_evals`` (per-instance Jacobian evaluations).
    """

    def __init__(
        self,
        method: str | ButcherTableau = "kvaerno5",
        *,
        newton: NewtonConfig | None = None,
        newton_tol: float | None = None,
        max_newton_iters: int | None = None,
        slow_iters: int | None = None,
    ):
        self.tableau = get_tableau(method) if isinstance(method, str) else method
        if not self.tableau.implicit:
            raise ValueError(
                f"tableau {self.tableau.name!r} is explicit; use ExplicitRK"
            )
        self.gamma = self.tableau.diagonal  # validates the constant diagonal
        legacy = {
            "newton_tol": newton_tol,
            "max_newton_iters": max_newton_iters,
            "slow_iters": slow_iters,
        }
        used = [name for name, v in legacy.items() if v is not None]
        if used:
            if newton is not None:
                raise TypeError(
                    f"cannot combine newton= with legacy kwarg(s) {used}; "
                    "put every knob on the NewtonConfig"
                )
            warnings.warn(
                f"DiagonallyImplicitRK kwarg(s) {used} are deprecated; pass "
                "newton=NewtonConfig(tol=..., max_iters=..., slow_iters=...) "
                "instead",
                DeprecationWarning,
                stacklevel=2,
            )
            newton = NewtonConfig(
                tol=newton_tol if newton_tol is not None else 1e-2,
                max_iters=max_newton_iters if max_newton_iters is not None else 8,
                slow_iters=slow_iters,
            )
        self.newton = newton if newton is not None else NewtonConfig()
        freeze(self)

    # The pre-NewtonConfig knob names, kept readable for callers/tests.
    @property
    def newton_tol(self) -> float:
        return self.newton.tol

    @property
    def max_newton_iters(self) -> int:
        return self.newton.max_iters

    @property
    def slow_iters(self) -> int:
        return self.newton.effective_slow_iters

    def init_carry(self, term, t0, y0, f0, args) -> DIRKCarry:
        b, f = y0.shape
        return DIRKCarry(
            jac=jnp.zeros((b, f, f), dtype=y0.dtype),
            refresh=jnp.ones((b,), dtype=bool),
        )

    def _stage_sweep(self, term, t, dt, y, f0, args, carry, scale, *, factor_once):
        """The shared stage recursion of the unfused and fused DIRK paths:
        per-instance Jacobian refresh, chord-matrix build, and one masked
        Newton solve per implicit stage.  ``factor_once=False`` is the
        classic path (each iteration re-solves against ``M`` through
        ``batched_linsolve``); ``factor_once=True`` factors ``M`` ONCE via
        ``ops.batched_lu_factor`` and runs every Newton iteration as one
        ``ops.fused_newton_iter`` launch against the prefactored LU.  On the
        ref backend the two produce bitwise-identical iterates (the LU
        composition is exactly what ``jnp.linalg.solve`` lowers to), so the
        fused and unfused DIRK solves stay bitwise-equal there.

        Returns ``(K, carry_out, failed, n_static_evals, n_evals, stats_aux)``.
        """
        tab = self.tableau
        dtype = y.dtype
        a, c, _, _ = _tableau_arrays(tab, dtype)
        if not isinstance(carry, DIRKCarry):
            carry = self.init_carry(term, t, y, f0, args)
        if scale is None:
            # Direct-call default: the solver's default tolerances.
            scale = 1e-6 + 1e-3 * jnp.abs(y)

        # --- per-instance Jacobian refresh (skipped entirely when nobody asks) ---
        J = jax.lax.cond(
            jnp.any(carry.refresh),
            lambda: jnp.where(carry.refresh[:, None, None], term.vf_jac(t, y, args), carry.jac),
            lambda: carry.jac,
        )
        n_jac_evals = carry.refresh.astype(jnp.int32)
        eye = jnp.eye(y.shape[1], dtype=dtype)
        M = eye - (dt * self.gamma)[:, None, None] * J
        operator = ops.batched_lu_factor(M) if factor_once else None

        ks: list[jax.Array] = []
        failed = jnp.zeros(dt.shape, dtype=bool)
        slow = jnp.zeros(dt.shape, dtype=bool)
        n_newton_iters = jnp.zeros(dt.shape, dtype=jnp.int32)
        n_evals = jnp.zeros((), dtype=jnp.int32)
        n_static_evals = 0
        slow_iters = self.newton.effective_slow_iters
        for i in range(tab.stages):
            ti = t + c[i] * dt
            y_pred = y if i == 0 else ops.stage_accum(y, dt, jnp.stack(ks), a[i, :i])
            if a[i, i] == 0.0:  # explicit stage (the E in ESDIRK)
                if i == 0:
                    ks.append(f0)
                else:
                    ks.append(term.vf(ti, y_pred, args))
                    n_static_evals += 1
            else:
                dtg = (dt * a[i, i])[:, None]

                def eval_fn(k, ti=ti, y_pred=y_pred, dtg=dtg):
                    return term.vf(ti, y_pred + dtg * k, args)

                # Convergence is measured on the stage VALUE increment
                # dt*a_ii*delta_k (state units), not the raw slope update,
                # so the test matches the atol/rtol error scale.
                stage_scale = scale / jnp.maximum(jnp.abs(dtg), jnp.finfo(dtype).tiny)
                pred = ks[-1] if ks else f0  # predictor: the previous stage slope
                if factor_once:
                    res = newton_solve(
                        eval_fn, pred, scale=stage_scale,
                        operator=operator, config=self.newton,
                    )
                else:
                    res = newton_solve(
                        eval_fn, pred, M, stage_scale, config=self.newton,
                    )
                ks.append(res.k)
                failed = failed | ~res.converged
                slow = slow | (res.n_iters >= slow_iters)
                n_newton_iters = n_newton_iters + res.n_iters
                n_evals = n_evals + res.n_evals

        stats_aux = {"n_newton_iters": n_newton_iters, "n_jac_evals": n_jac_evals}
        carry_out = DIRKCarry(jac=J, refresh=failed | slow)
        return jnp.stack(ks), carry_out, failed, n_static_evals, n_evals, stats_aux

    def step(self, term, t, dt, y, f0, args, carry=(), scale=None):
        tab = self.tableau
        _, _, b_sol, b_err = _tableau_arrays(tab, y.dtype)
        K, carry_out, failed, n_static_evals, n_evals, stats_aux = self._stage_sweep(
            term, t, dt, y, f0, args, carry, scale, factor_once=False
        )
        y1, err = ops.fused_update(y, K, dt, b_sol, b_err)
        if tab.stiffly_accurate and tab.c[-1] == 1.0:
            f1 = K[-1]  # the last stage derivative IS f(t + dt, y1)
        else:
            f1 = term.vf(t + dt, y1, args)
            n_static_evals += 1

        return StepResult(
            y1=y1,
            err=err,
            f1=f1,
            n_f_evals=n_evals + n_static_evals,
            carry=carry_out,
            solver_failed=failed,
            stats_aux=stats_aux,
        )

    def fused_stage_parts(self, term, t, dt, y, f0, args, carry, scale):
        """The DIRK half of the fused fast path: the stage sweep with the
        factor-once Newton strategy (one ``batched_lu_factor`` per step, one
        ``fused_newton_iter`` launch per Newton iteration), plus the trailing
        derivative -- everything the ``fused_step`` megakernel needs as
        inputs.  The combine/norm/controller/commit happen in-kernel, with
        the per-instance ``solver_failed`` mask threaded through its
        ``failed=`` input so divergence still lands as a controller reject.

        Returns ``(K, f1, n_f_evals, carry, solver_failed, stats_aux)``.
        """
        tab = self.tableau
        _, _, b_sol, b_err = _tableau_arrays(tab, y.dtype)
        K, carry_out, failed, n_static_evals, n_evals, stats_aux = self._stage_sweep(
            term, t, dt, y, f0, args, carry, scale, factor_once=True
        )
        if tab.stiffly_accurate and tab.c[-1] == 1.0:
            f1 = K[-1]
        else:
            # Rebuild y1 through the same fused_update program the megakernel
            # applies internally (XLA CSEs the two on the ref backend), then
            # one trailing vf launch -- exactly like ``step``.
            y1, _ = ops.fused_update(y, K, dt, b_sol, b_err)
            f1 = term.vf(t + dt, y1, args)
            n_static_evals += 1
        return K, f1, n_evals + n_static_evals, carry_out, failed, stats_aux

    def commit_carry(self, old, new, accept, running):
        """Advance the Jacobian for running instances.  Two refresh-flag
        refinements: a rejected step that already ran on a FRESH Jacobian
        (old.refresh was set) retries at the same (t, y), where re-evaluating
        would reproduce J bit-identically -- suppress the flag and let the dt
        shrink do the work; and finished instances drop their flag so a frozen
        instance can never keep triggering whole-batch re-evaluation."""
        wasteful = old.refresh & ~accept
        return DIRKCarry(
            jac=jnp.where(running[:, None, None], new.jac, old.jac),
            refresh=new.refresh & ~wasteful & running,
        )

    # --- statistics registry contribution ---
    def init_stats(self, batch: int) -> dict[str, jax.Array]:
        zeros = jnp.zeros((batch,), dtype=jnp.int32)
        return {"n_f_evals": zeros, "n_newton_iters": zeros, "n_jac_evals": zeros}

    def update_stats(self, stats: dict, ctx) -> dict:
        aux = ctx.aux or {}
        running = ctx.running.astype(jnp.int32)
        out = {
            **stats,
            "n_f_evals": stats["n_f_evals"] + ctx.step_active * ctx.n_f_evals,
        }
        if "n_newton_iters" in aux:
            out["n_newton_iters"] = (
                stats["n_newton_iters"] + ctx.step_active * running * aux["n_newton_iters"]
            )
        if "n_jac_evals" in aux:
            out["n_jac_evals"] = (
                stats["n_jac_evals"] + ctx.step_active * running * aux["n_jac_evals"]
            )
        return out
