"""The static/dynamic split: solver components as hashable compile-time config.

JAX separates every traced program into *static* structure (Python objects
that select which program gets built; changing them retraces) and *dynamic*
data (arrays that flow through a fixed program; changing them re-runs it).
The solver stack draws that line explicitly:

static
    ``ODETerm`` (the vector-field callable), steppers and their tableaus
    (coefficients are compile-time constants the kernels unroll), controllers
    (filter coefficients select the step-factor program), ``Event`` specs and
    layout choices (``dense``, ``dense_window``, ``max_steps``).
dynamic
    everything with a batch axis -- ``y0``, ``t_eval``/``t_start``/``t_end``,
    ``args`` leaves, and the tolerances ``rtol``/``atol`` (scalars or
    per-instance vectors; a tolerance change must never retrace).

``register_static`` registers a class as a pytree with **zero leaves**: the
object itself rides in the treedef as auxiliary data, so it can cross
``jax.jit`` boundaries as an ordinary argument without ``static_argnums``
bookkeeping -- JAX's tracing machinery hashes it into the compilation-cache
key automatically.  That requires value-based ``__hash__``/``__eq__`` (two
equal configs must hit the same executable) and immutability after
construction (mutating an object that is already baked into a cached program
would silently desynchronize config and executable) -- ``frozen_setattr``/
``freeze`` enforce the latter.

Containers with a dynamic tail (``StepFunction``, the drivers) register
through ``register_config_pytree`` instead: their tolerance fields flatten to
leaves, everything else to hashable aux data (derived caches excluded and
rebuilt on unflatten).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np


def frozen_setattr(self, name: str, value: Any) -> None:
    """``__setattr__`` for frozen-after-init classes (see ``freeze``)."""
    if getattr(self, "_frozen", False):
        raise AttributeError(
            f"{type(self).__name__} is frozen: it is static solver config that "
            "may already be baked into a compiled program. Construct a new "
            "instance instead of mutating."
        )
    object.__setattr__(self, name, value)


def freeze(obj: Any) -> None:
    """Seal ``obj`` against further attribute assignment.  Call at the end of
    ``__init__`` in classes whose ``__setattr__`` is ``frozen_setattr``."""
    object.__setattr__(obj, "_frozen", True)


def register_static(cls: type) -> type:
    """Register ``cls`` as an all-static pytree: no leaves, the instance is
    the aux data.  Usable as a decorator.  Instances must be hashable by
    value and immutable."""

    jax.tree_util.register_pytree_node(
        cls,
        lambda obj: ((), obj),
        lambda obj, _children: obj,
    )
    return cls


def static_items(obj: Any, exclude: tuple[str, ...] = ()) -> tuple:
    """The instance's attributes as a sorted name/value tuple, skipping
    ``exclude`` and the freeze marker -- the value identity used by the
    ``__eq__``/``__hash__`` of static components and by pytree aux data."""
    skip = set(exclude) | {"_frozen"}
    return tuple(
        (name, value) for name, value in sorted(vars(obj).items()) if name not in skip
    )


def value_eq(cls: type, exclude: tuple[str, ...] = ()) -> type:
    """Give ``cls`` value-based ``__eq__``/``__hash__`` over its attributes
    (minus ``exclude``), so equal configs key to the same compiled program."""

    def __eq__(self, other):
        if type(other) is not type(self):
            return NotImplemented
        return static_items(self, exclude) == static_items(other, exclude)

    def __hash__(self):
        return hash((cls.__name__, static_items(self, exclude)))

    cls.__eq__ = __eq__
    cls.__hash__ = __hash__
    return cls


def leaf_key(x) -> Any:
    """Hashable shape/dtype fingerprint of one dynamic leaf.

    This is the per-call hot path of the compiled front end and the serving
    batcher, so it avoids ``jnp.asarray``/tree machinery for the common
    cases.  Host scalars key by Python type -- jit assigns them weak dtypes,
    so they must not share an entry with committed arrays."""
    if x is None:
        return None
    if isinstance(x, (jax.Array, jax.ShapeDtypeStruct, np.ndarray, np.generic)):
        # np.dtype objects hash/compare by value and avoid the str() cost
        # (this runs per leaf per request at serving rates).
        return (tuple(x.shape), np.dtype(x.dtype), bool(getattr(x, "weak_type", False)))
    if isinstance(x, (bool, int, float, complex)):
        return type(x).__name__
    return None  # pytree container: caller flattens


def tree_key(tree) -> Any:
    """Hashable (structure, avals) fingerprint of a dynamic argument pytree.

    Two trees share a key exactly when they compile to the same program
    point: same treedef (which hashes any static config riding in aux data,
    e.g. a driver's stepper/controller/layout) and same per-leaf
    shape/dtype/weak-type.  This is the identity ``CompiledSolver`` keys its
    executable cache on and the serving layer keys request buckets on -- a
    request maps to a bucket iff it would hit the same compiled program.
    """
    k = leaf_key(tree)
    if k is not None or tree is None:
        return k
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (treedef, tuple(leaf_key(x) for x in leaves))


def register_config_pytree(
    cls: type,
    dynamic_fields: tuple[str, ...],
    derived_fields: tuple[str, ...] = (),
) -> type:
    """Register ``cls`` as a pytree whose ``dynamic_fields`` attributes are
    leaves and whose remaining attributes are (hashable) aux data.

    ``derived_fields`` are caches computed from the rest (they may hold
    back-references to the instance itself); they are excluded from the aux
    data and rebuilt on unflatten via the class's ``_rebuild_derived`` hook.
    Unflattening bypasses ``__init__`` -- the aux carries already-normalized
    attributes -- so flatten/unflatten round-trips are cheap enough for the
    trace-time hot path and reconstruction cannot re-run validation on
    tracers.
    """

    skip = tuple(dynamic_fields) + tuple(derived_fields)

    def flatten_with_keys(obj):
        children = tuple(
            (jax.tree_util.GetAttrKey(name), getattr(obj, name))
            for name in dynamic_fields
        )
        return children, static_items(obj, skip)

    def flatten(obj):
        children, aux = flatten_with_keys(obj)
        return tuple(c for _, c in children), aux

    def unflatten(aux, children):
        obj = object.__new__(cls)
        for name, value in aux:
            object.__setattr__(obj, name, value)
        for name, value in zip(dynamic_fields, children):
            object.__setattr__(obj, name, value)
        rebuild = getattr(obj, "_rebuild_derived", None)
        if rebuild is not None:
            rebuild()
        object.__setattr__(obj, "_frozen", True)
        return obj

    jax.tree_util.register_pytree_with_keys(cls, flatten_with_keys, unflatten, flatten)
    return cls
