"""ODE terms: the dynamics wrapper the solver integrates.

The solver's calling convention is batched: ``f(t, y, args)`` with ``t`` of
shape (batch,) and ``y`` of shape (batch, features).  ``ODETerm`` adapts
common user signatures onto that convention.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ODETerm:
    """Wraps a vector field ``f(t, y, args) -> dy/dt``.

    ``batched=True`` (default): f already handles (b,) times and (b, f) states.
    ``batched=False``: f is written for a single instance (scalar t, (f,) y)
    and is vmapped over the batch.
    """

    f: Callable[..., Any]
    batched: bool = True
    with_args: bool = True

    def vf(self, t: jax.Array, y: jax.Array, args: Any) -> jax.Array:
        if self.batched:
            out = self.f(t, y, args) if self.with_args else self.f(t, y)
        else:
            if self.with_args:
                out = jax.vmap(lambda ti, yi: self.f(ti, yi, args))(t, y)
            else:
                out = jax.vmap(self.f)(t, y)
        return jnp.asarray(out, dtype=y.dtype)


def as_term(f: Callable | ODETerm, *, batched: bool = True, with_args: bool | None = None) -> ODETerm:
    if isinstance(f, ODETerm):
        return f
    if with_args is None:
        with_args = True
    return ODETerm(f, batched=batched, with_args=with_args)
