"""ODE terms: the dynamics wrapper the solver integrates.

The solver's hot loop is strictly batched-flat: ``f(t, y, args)`` with ``t``
of shape (batch,) and ``y`` of shape (batch, features).  ``ODETerm`` adapts
common user signatures onto that convention.

Arbitrary PyTree-structured states (nested dicts/tuples of arrays, the latent
states of latent ODEs and CNFs) are supported by ravelling at the *term
boundary* via ``jax.flatten_util``: the loop, the controllers and the Pallas
kernels only ever see flat ``(b, f)`` buffers, and the user's vector field
only ever sees its own PyTree.  ``ravel_state`` builds the round-trip,
``ravel_term`` adapts the per-instance PyTree dynamics onto the flat batched
convention.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from ..kernels import ops
from .static import register_static


@register_static
@dataclasses.dataclass(frozen=True)
class ODETerm:
    """Wraps a vector field ``f(t, y, args) -> dy/dt``.

    An ``ODETerm`` is *static solver config*: frozen, hashable (callables
    hash by identity -- reuse the same function object across solves, or the
    compilation cache retraces) and pytree-registered with zero leaves, so it
    crosses ``jax.jit`` boundaries without ``static_argnums`` bookkeeping.
    Anything the dynamics should read at runtime belongs in ``args`` (a
    dynamic pytree), never closed over.

    ``batched=True`` (default): f already handles (b,) times and (b, f) states.
    ``batched=False``: f is written for a single instance (scalar t, (f,) y)
    and is vmapped over the batch.

    ``batched_args=True`` declares that every ``args`` leaf carries the batch
    as its *leading axis* and must be mapped per instance alongside ``t`` and
    ``y`` (each instance sees its own unbatched args row).  Only meaningful
    for per-instance dynamics -- ``batched=False`` terms and PyTree-state
    solves through ``ravel_term`` -- where args would otherwise be passed
    through shared.  This is how the serving layer batches requests with
    different parameter values into one bucket.

    ``f_jac`` optionally supplies the state Jacobian df/dy for implicit
    steppers.  It follows the same batching convention as ``f``: per instance
    it maps ((), (f,)) -> (f, f); batched it maps ((b,), (b, f)) -> (b, f, f).
    When omitted, ``vf_jac`` falls back to forward-mode autodiff
    (``jax.jacfwd``) of the vector field, vmapped over the batch.
    """

    f: Callable[..., Any]
    batched: bool = True
    with_args: bool = True
    f_jac: Callable[..., Any] | None = None
    batched_args: bool = False

    def vf(self, t: jax.Array, y: jax.Array, args: Any) -> jax.Array:
        if self.batched:
            out = self.f(t, y, args) if self.with_args else self.f(t, y)
        else:
            if self.with_args:
                if self.batched_args and args is not None:
                    out = jax.vmap(lambda ti, yi, ai: self.f(ti, yi, ai))(t, y, args)
                else:
                    out = jax.vmap(lambda ti, yi: self.f(ti, yi, args))(t, y)
            else:
                out = jax.vmap(self.f)(t, y)
        return jnp.asarray(out, dtype=y.dtype)

    def vf_jac(self, t: jax.Array, y: jax.Array, args: Any) -> jax.Array:
        """Batched state Jacobian df/dy at (t, y): (b, f, f).

        Used by the implicit steppers to build the Newton matrix
        I - dt*gamma*J.  The default is forward-mode autodiff: one batched JVP
        per feature-basis vector.  Because batch instances are independent by
        the solver's convention (f never mixes instances), a tangent shared
        across the batch recovers every instance's Jacobian column in a single
        pass -- and per-instance ``args`` flow through untouched.  Supply
        ``f_jac`` for an analytic or structured Jacobian.
        """
        if self.f_jac is not None:
            if self.batched:
                out = self.f_jac(t, y, args) if self.with_args else self.f_jac(t, y)
            else:
                if self.with_args:
                    if self.batched_args and args is not None:
                        out = jax.vmap(
                            lambda ti, yi, ai: self.f_jac(ti, yi, ai)
                        )(t, y, args)
                    else:
                        out = jax.vmap(lambda ti, yi: self.f_jac(ti, yi, args))(t, y)
                else:
                    out = jax.vmap(self.f_jac)(t, y)
            return jnp.asarray(out, dtype=y.dtype)

        def column(e):  # e: (f,) basis vector -> (b, f) = J @ e per instance
            return jax.jvp(
                lambda yy: self.vf(t, yy, args), (y,), (jnp.broadcast_to(e, y.shape),)
            )[1]

        cols = jax.vmap(column)(jnp.eye(y.shape[1], dtype=y.dtype))  # (f_in, b, f_out)
        return jnp.moveaxis(cols, 0, -1)


@register_static
@dataclasses.dataclass(frozen=True)
class PolynomialTerm(ODETerm):
    """An ``ODETerm`` whose vector field is a closed-form elementwise
    polynomial ``dy_i/dt = sum_d poly_coeffs[d] * y_i**d``.

    The coefficients are *static config* (a tuple of floats, or of length-f
    float tuples for per-feature coefficients), which is what lets the fused
    step megakernel inline the stage evaluations: an entire explicit-RK step
    attempt becomes ONE kernel launch with zero vector-field dispatches (the
    torchode regime's launch-bound limit).  Covers linear/affine dynamics
    (exponential decay, relaxation), logistic growth, and any scalar
    polynomial reaction term.  Construct via ``polynomial_term``.
    """

    poly_coeffs: tuple = ()


def polynomial_term(*coeffs) -> PolynomialTerm:
    """Build a ``PolynomialTerm`` for ``dy/dt = sum_d coeffs[d] * y**d``.

    Each positional coefficient is scalar (shared across features) or a
    length-f sequence (per-feature), low -> high degree::

        polynomial_term(0.0, -1.0)        # dy/dt = -y        (exp decay)
        polynomial_term(0.0, 1.0, -1.0)   # dy/dt = y - y**2  (logistic)

    The term solves identically through every code path; with the fused step
    fast path enabled it additionally lowers the stage evaluations into the
    megakernel (see ``StepFunction``).
    """
    if not coeffs:
        raise ValueError("polynomial_term needs at least one coefficient")
    norm = tuple(
        float(c)
        if np.ndim(c) == 0
        else tuple(float(x) for x in np.asarray(c).reshape(-1))
        for c in coeffs
    )

    def f(t, y, args):
        del t, args  # autonomous by construction
        return ops.poly_eval(y, norm)

    return PolynomialTerm(f=f, batched=True, with_args=True, poly_coeffs=norm)


def as_term(f: Callable | ODETerm, *, batched: bool = True, with_args: bool | None = None) -> ODETerm:
    if isinstance(f, ODETerm):
        return f
    if with_args is None:
        with_args = True
    return ODETerm(f, batched=batched, with_args=with_args)


class RaveledState(NamedTuple):
    """Round-trip between a batched PyTree state and the flat (b, f) buffer
    the solver loop operates on.

    ``unravel_one`` maps a single (f,) vector back to one instance's PyTree
    (the closure produced by ``jax.flatten_util.ravel_pytree``).
    """

    unravel_one: Callable[[jax.Array], Any]
    num_features: int

    def ravel(self, y: Any) -> jax.Array:
        """Batched PyTree (leaves (b, ...)) -> flat (b, f)."""
        return jax.vmap(lambda inst: ravel_pytree(inst)[0])(y)

    def unravel(self, ys: jax.Array) -> Any:
        """(b, f) -> batched PyTree; (b, n, f) -> PyTree with (b, n, ...) leaves."""
        if ys.ndim == 3:
            return jax.vmap(jax.vmap(self.unravel_one))(ys)
        return jax.vmap(self.unravel_one)(ys)


def ravel_state(y0: Any) -> tuple[jax.Array, RaveledState | None]:
    """Normalize a user initial state onto the flat (b, f) convention.

    Returns ``(y0_flat, raveled)``.  ``raveled`` is ``None`` when ``y0`` is
    already a flat (b, f) array (or nested numeric lists, the historical
    convenience), otherwise a ``RaveledState`` describing the round-trip.
    Every leaf of a PyTree state must carry the batch as its leading axis.
    """
    if isinstance(y0, (jax.Array, np.ndarray)):
        return jnp.asarray(y0), None
    if isinstance(y0, (list, tuple)):
        # Nested *numeric* lists are the historical flat-array convenience.  A
        # list/tuple with array leaves is a genuine PyTree (e.g. a pair of
        # (b,)-shaped states) and must NOT be stacked into a (b, f) buffer.
        leaves = jax.tree_util.tree_leaves(y0)
        all_scalars = all(
            isinstance(leaf, (int, float, complex, bool))
            or getattr(leaf, "ndim", None) == 0
            for leaf in leaves
        )
        if all_scalars:
            arr = jnp.asarray(y0)
            if arr.ndim == 2:
                return arr, None
    y0 = jax.tree_util.tree_map(jnp.asarray, y0)
    one = jax.tree_util.tree_map(lambda x: x[0], y0)
    flat0, unravel_one = ravel_pytree(one)
    raveled = RaveledState(unravel_one=unravel_one, num_features=flat0.shape[0])
    return raveled.ravel(y0), raveled


def ravel_term(
    f: Callable | ODETerm, raveled: RaveledState, *, with_args: bool = True,
    batched_args: bool = False,
) -> ODETerm:
    """Adapt a *per-instance* PyTree vector field ``f(t, y_tree, args) ->
    dy_tree`` onto the flat batched convention.

    Ravel/unravel happens only at this boundary; the step math, controllers
    and kernels all stay on (b, f) buffers.  With ``batched_args`` (taken
    from the term when an ``ODETerm`` is passed), every args leaf carries a
    leading batch axis and is vmapped per instance -- the serving layer's
    per-request parameter rows for PyTree states.
    """
    if isinstance(f, ODETerm):
        with_args = f.with_args
        batched_args = f.batched_args
        f = f.f

    def flat_f(t, y, args):
        if with_args and batched_args and args is not None:
            def one_with_args(ti, yi, ai):
                dy = f(ti, raveled.unravel_one(yi), ai)
                return ravel_pytree(dy)[0]

            return jax.vmap(one_with_args)(t, y, args)

        def one(ti, yi):
            yt = raveled.unravel_one(yi)
            dy = f(ti, yt, args) if with_args else f(ti, yt)
            return ravel_pytree(dy)[0]

        return jax.vmap(one)(t, y)

    return ODETerm(flat_f, batched=True, with_args=True)
