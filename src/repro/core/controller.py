"""Adaptive step-size controllers with fully batched per-instance state.

Implements the Soederlind (2002, 2003) digital-filter family: the next step
factor is

    factor = safety * e_n^{-b1/k} * e_{n-1}^{-b2/k} * e_{n-2}^{-b3/k}

where ``e`` are weighted-RMS error ratios (accept iff e <= 1) and ``k`` is the
error-estimator order + 1.  b = (1, 0, 0) is the integral (I) controller used by
torchdiffeq/TorchDyn; torchode additionally ships PI/PID coefficient sets.

Every quantity -- error history, proposed dt, accept decision -- is a (batch,)
vector, which is the paper's core contribution: instances never share a step
size.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .static import register_static


class ControllerState(NamedTuple):
    # inverse error ratios of the previous two accepted steps (init 1.0)
    prev_inv_ratio: jax.Array  # (b,)
    prev2_inv_ratio: jax.Array  # (b,)


class _ControllerStats:
    """Statistics-registry contribution shared by all controllers: the
    controller owns the accept/reject decision, so it records ``n_accepted``."""

    def init_stats(self, batch: int) -> dict[str, jax.Array]:
        return {"n_accepted": jnp.zeros((batch,), dtype=jnp.int32)}

    def update_stats(self, stats: dict, ctx) -> dict:
        return {**stats, "n_accepted": stats["n_accepted"] + ctx.accept.astype(jnp.int32)}


@register_static
@dataclasses.dataclass(frozen=True)
class PIDController(_ControllerStats):
    """General PID step controller; I/PI controllers are coefficient choices.

    Coefficients follow the convention of torchode / diffrax docs: they are
    divided by the controller order ``k`` internally.

    A controller is static solver config -- a frozen, hashable coefficient
    set, pytree-registered with zero leaves: its floats select the step-factor
    *program*, so changing them retraces (per-instance tolerances are the
    dynamic knob; see ``rtol``/``atol`` on the drivers).
    """

    pcoeff: float = 0.0
    icoeff: float = 1.0
    dcoeff: float = 0.0
    safety: float = 0.9
    factor_min: float = 0.2
    factor_max: float = 10.0
    dt_min: float = 0.0
    dt_max: float = float("inf")

    def init(self, batch: int, dtype) -> ControllerState:
        one = jnp.ones((batch,), dtype=dtype)
        return ControllerState(one, one)

    def betas(self, k: int) -> tuple[float, float, float]:
        # Soederlind exponents for (e_n, e_{n-1}, e_{n-2}) given PID coefficients.
        b1 = (self.pcoeff + self.icoeff + self.dcoeff) / k
        b2 = -(self.pcoeff + 2.0 * self.dcoeff) / k
        b3 = self.dcoeff / k
        return b1, b2, b3

    def filter_params(self, k: int) -> tuple[float, ...]:
        """The controller's static coefficient tuple ``(b1, b2, b3, safety,
        factor_min, factor_max, dt_min, dt_max)`` -- the compile-time constants
        the fused step megakernel unrolls into its accept/next-dt tail."""
        return (*self.betas(k), self.safety, self.factor_min, self.factor_max,
                self.dt_min, self.dt_max)

    def __call__(
        self,
        err_ratio: jax.Array,  # (b,) weighted RMS error ratio of this step
        dt: jax.Array,  # (b,) step size just attempted (signed)
        state: ControllerState,
        k: int,  # error-estimator order + 1
    ) -> tuple[jax.Array, jax.Array, ControllerState]:
        """Returns (accept (b,) bool, dt_next (b,) signed, new state).

        Delegates to ``ops.pid_update``: the SAME expression sequence the
        fused step megakernel bakes in, so fused and unfused solves make
        bitwise-identical accept/next-dt decisions.
        """
        b1, b2, b3 = self.betas(k)
        accept, dt_next, new_inv, new_inv2 = ops.pid_update(
            err_ratio, dt, state.prev_inv_ratio, state.prev2_inv_ratio,
            b1=b1, b2=b2, b3=b3, safety=self.safety,
            factor_min=self.factor_min, factor_max=self.factor_max,
            dt_min=self.dt_min, dt_max=self.dt_max,
        )
        return accept, dt_next, ControllerState(new_inv, new_inv2)


def integral_controller(**kw) -> PIDController:
    """The I controller of torchdiffeq/TorchDyn (b = (1, 0, 0))."""
    return PIDController(pcoeff=0.0, icoeff=1.0, dcoeff=0.0, **kw)


def pi_controller(**kw) -> PIDController:
    """A common PI coefficient choice (0.3/0.4 rule)."""
    return PIDController(pcoeff=0.3, icoeff=0.4, dcoeff=0.0, **kw)


def pid_controller(**kw) -> PIDController:
    """PID coefficients from diffrax's documentation (as used in the paper's App. C)."""
    return PIDController(pcoeff=0.2, icoeff=0.3, dcoeff=0.1, **kw)


@register_static
@dataclasses.dataclass(frozen=True)
class FixedController(_ControllerStats):
    """Fixed-step 'controller': always accept, keep dt (euler/rk4 style).
    Frozen/hashable/static like ``PIDController`` (value-equal instances key
    to the same compiled program)."""

    dt_min: float = 0.0
    dt_max: float = float("inf")

    def init(self, batch: int, dtype) -> ControllerState:
        one = jnp.ones((batch,), dtype=dtype)
        return ControllerState(one, one)

    def filter_params(self, k: int) -> tuple[float, ...]:
        """The fixed-mode kernel contract: there are no filter coefficients.
        The fused megakernel runs with ``ctrl_mode="fixed"`` instead --
        accept everything that is running, keep the standing dt proposal and
        pass the controller history through untouched, exactly what
        ``__call__`` + the loop's masked commit compute unfused."""
        return ()

    def __call__(self, err_ratio, dt, state, k):
        accept = jnp.ones(dt.shape, dtype=bool)
        return accept, dt, state
