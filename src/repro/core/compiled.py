"""Zero-retrace compiled solving: the AOT front end and multi-device sharding.

Every ``AutoDiffAdjoint.solve`` call traces the full ``lax.while_loop``
program from scratch unless the caller wraps it in ``jax.jit`` themselves --
and even then, Python-side dispatch re-validates the closure every call.  In
the small-model serving regime the paper's per-step numbers target (Sec. 4),
that dispatch overhead dominates the actual integration.  This module fixes
it with the static/dynamic split the component stack now guarantees:

``CompiledSolver``
    Wraps a driver.  ``solve(...)`` looks up an LRU cache keyed on the
    driver's *static config* (hashable treedef aux) plus the shapes/dtypes of
    every dynamic argument; on a miss it AOT-compiles the solve program once
    (``jax.jit(...).lower(...).compile()`` with ``donate_argnums`` on ``y0``)
    and thereafter dispatches straight to the cached executable -- repeated
    same-shaped solves perform **zero retraces** and zero Python tracing work.
    ``compile(...)`` exposes the same machinery ahead of time: pass
    ``jax.ShapeDtypeStruct`` specs and get a callable handle back before the
    first request arrives.

``sharded_solve``
    The paper's batch parallelism extended across chips: instances are
    independent, so the batch axis shards embarrassingly across a device mesh
    via ``shard_map`` -- each device runs the full per-instance adaptive loop
    on its shard, with its own termination reduction (no cross-device sync
    inside the loop, the multi-device analogue of torchode's no-host-sync
    rule).  Results match the single-device compiled program exactly.

What is static vs dynamic (the retrace contract):

* static -- retrace on change: the vector field (by ``is`` identity: reuse
  the function object), stepper/tableau, controller coefficients, event
  specs, ``dense``/``dense_window``/``max_steps``, and every *shape/dtype*.
* dynamic -- free to vary per call: ``y0`` values, ``t_eval``/``t_start``/
  ``t_end`` values, ``dt0``, ``args`` leaves, and the tolerances
  ``rtol``/``atol`` (including per-instance vectors).

Donation caveat: XLA can only reuse a donated buffer when some *output* has
the same shape/dtype, which for a solve means the final-state regime
(``t_eval=None``: ``ys`` is ``(b, f)`` like ``y0``).  The default
``donate="auto"`` therefore donates ``y0`` exactly when ``t_eval is None``
and keeps it alive otherwise (avoiding XLA's "donated buffers were not
usable" warning on dense-output solves, where donation buys nothing).  When
donation is active the executable *consumes* the ``y0`` buffers -- reusing
the same array for a later call raises "buffer has been deleted or donated".
Serving loops that construct a fresh ``y0`` per request (the intended
pattern) never notice; set ``donate=False`` to keep caller buffers alive
unconditionally.
"""

from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from .drivers import AutoDiffAdjoint, BacksolveAdjoint, _Driver
from .solution import Grads, Solution
from .static import freeze, frozen_setattr
from .static import leaf_key as _leaf_key
from .static import tree_key as _tree_key
from .stepper import AbstractStepper
from .terms import ODETerm


def _spec(x) -> jax.ShapeDtypeStruct:
    """Normalize a concrete array (or an existing spec) to a ShapeDtypeStruct."""
    if isinstance(x, jax.ShapeDtypeStruct):
        return x
    x = jnp.asarray(x) if not hasattr(x, "shape") else x
    return jax.ShapeDtypeStruct(x.shape, x.dtype)


class CacheInfo(NamedTuple):
    hits: int
    misses: int
    currsize: int
    maxsize: int


def _f_key(f):
    """Cache identity of the dynamics: ODETerms by value, bare callables by
    object identity (cache entries close over ``f``, keeping it alive, so an
    id can never be recycled while its entry exists)."""
    return f if isinstance(f, ODETerm) else (type(f), id(f))


def _final_state_solution(ys, t_end) -> Solution:
    """Synthesize the final-state ``Solution`` for a driver that returns only
    ``y(t_end)`` (``BacksolveAdjoint``): per-instance status/stats do not
    cross its custom-VJP boundary, so status is all-SUCCESS and stats empty --
    documented on the driver, and exactly the regime the serving layer's grad
    path uses."""
    leaves = jax.tree_util.tree_leaves(ys)
    b = leaves[0].shape[0]
    ts = jnp.broadcast_to(jnp.asarray(t_end, leaves[0].dtype), (b,))
    return Solution(ts=ts, ys=ys, status=jnp.zeros((b,), jnp.int32), stats={})


class _KeyedLRU:
    """The one keyed-LRU implementation behind both front-end caches
    (``CompiledSolver`` and ``sharded_solve``): a fix to keying or eviction
    applies to both or neither."""

    def __init__(self, maxsize: int):
        self.maxsize = maxsize
        self.data: OrderedDict = OrderedDict()
        self.hits = 0
        self.misses = 0

    def get(self, key):
        entry = self.data.get(key)
        if entry is not None:
            self.hits += 1
            self.data.move_to_end(key)
        else:
            self.misses += 1
        return entry

    def put(self, key, entry) -> None:
        self.data[key] = entry
        while len(self.data) > self.maxsize:
            self.data.popitem(last=False)

    def __len__(self) -> int:
        return len(self.data)

    def clear(self) -> None:
        self.data.clear()


class _CacheEntry:
    """One (static config, shapes) point of the solve cache.

    ``jitted`` is the jit-wrapped solve program: it traces exactly once (on
    the first call or on ``lower``) and later calls dispatch through jit's
    C++ fast path -- measurably faster than the Python call path of an
    ``XlaExecutable``.  ``executable`` is the AOT-compiled artifact, built
    lazily by ``CompiledSolver.compile``; once it exists, ``solve`` routes
    through it so an AOT-then-solve sequence never traces a second time.

    The cache key includes the tolerance-override shape class (see
    ``CompiledSolver._key``), so every call routed to this entry carries
    tolerance leaves matching the avals the entry was built for -- the
    executable is always usable when present.
    """

    __slots__ = ("jitted", "executable", "driver_leaves", "grad")

    def __init__(self, jitted, driver_leaves, grad: bool = False):
        self.jitted = jitted
        self.executable = None
        self.driver_leaves = driver_leaves
        self.grad = grad

    def call(self, y0, t_eval, t_start, t_end, dt0, args, rtol, atol,
             cotangent=None) -> Solution:
        tol_leaves = self.driver_leaves
        fn = self.executable if self.executable is not None else self.jitted
        if rtol is not None or atol is not None:
            tol_leaves = list(tol_leaves)
            if rtol is not None:
                tol_leaves[0] = rtol
            if atol is not None:
                tol_leaves[1] = atol
        if self.grad:
            return fn(y0, tol_leaves, t_eval, t_start, t_end, dt0, args, cotangent)
        return fn(y0, tol_leaves, t_eval, t_start, t_end, dt0, args)


class CompiledSolve:
    """A fully AOT-compiled solve program for one (static config, shapes)
    point.  Calling it never traces: the arguments' shapes/dtypes must match
    the specs it was compiled for (a mismatch raises instead of silently
    recompiling -- that is the point)."""

    def __init__(self, entry: _CacheEntry):
        self._entry = entry

    def __call__(
        self,
        y0,
        t_eval=None,
        *,
        t_start=None,
        t_end=None,
        dt0=None,
        args: Any = None,
        rtol=None,
        atol=None,
        cotangent=None,
    ) -> Solution:
        return self._entry.call(y0, t_eval, t_start, t_end, dt0, args, rtol,
                                atol, cotangent)

    def as_text(self) -> str:
        """The compiled program's HLO (donation shows up as input/output
        aliasing on the ``y0`` parameter)."""
        return self._entry.executable.as_text()


class CompiledSolver:
    """Zero-retrace front end over a loop driver.

    Example (serving loop)::

        solver = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5")))
        for batch in requests:                       # same (b, f) shapes
            sol = solver.solve(f, batch.y0, t_eval)  # traces exactly once

    ``solve`` arguments and semantics match ``AutoDiffAdjoint.solve``; add
    per-call ``rtol``/``atol`` overrides (dynamic -- they never retrace when
    they keep the driver tolerances' shape/dtype; an override with a *new*
    shape, e.g. a per-instance vector over a scalar default, compiles one
    variant program on first use).  The cache key is ``(driver static config,
    f identity, shapes/dtypes of every dynamic argument)``; see the module
    docstring for the full static/dynamic contract and the ``donate`` caveat.
    """

    __setattr__ = frozen_setattr

    def __init__(
        self,
        solver: _Driver | AbstractStepper | str | None = None,
        *,
        donate: bool | str = "auto",
        cache_size: int = 128,
        **driver_kw,
    ):
        if donate not in (True, False, "auto"):
            raise ValueError(f"donate must be True, False or 'auto', got {donate!r}")
        if isinstance(solver, (_Driver, BacksolveAdjoint)):
            if driver_kw:
                raise TypeError("pass driver options to the driver, not CompiledSolver")
            driver = solver
        else:
            driver = AutoDiffAdjoint(AbstractStepper.coerce(solver), **driver_kw)
        self.driver = driver
        # BacksolveAdjoint is final-state-only (no t_eval/dt0); its forward
        # program wraps the returned y(t_end) in a synthesized Solution.
        self._backsolve = isinstance(driver, BacksolveAdjoint)
        self.donate = donate
        self.cache_size = cache_size
        self._cache = _KeyedLRU(cache_size)
        # The driver is frozen config: flatten it once and reuse on every call.
        leaves, treedef = jax.tree_util.tree_flatten(driver)
        self._driver_leaves = leaves
        self._driver_def = treedef
        self._driver_tol_keys = tuple(_leaf_key(x) for x in leaves)
        self._driver_key = (treedef, self._driver_tol_keys)
        freeze(self)

    def cache_info(self) -> CacheInfo:
        c = self._cache
        return CacheInfo(c.hits, c.misses, len(c), self.cache_size)

    def cache_clear(self) -> None:
        self._cache.clear()

    @staticmethod
    def _device_key(device):
        """Cache-key component of a placement request: ``None`` (default
        placement) and explicit devices key distinct entries, because an AOT
        executable is pinned to the device it lowered for -- one executable
        per device is exactly what lets a serving process round-robin
        concurrent buckets across the whole mesh."""
        return None if device is None else (device.platform, device.id)

    def _tol_key(self, x, i):
        """Shape class of a tolerance override: ``None`` when absent *or*
        when it matches the driver leaf's aval (same program either way --
        tolerances are dynamic leaves), a distinct key otherwise (e.g. a
        per-instance vector over a scalar default selects its own program
        point, which ``compile`` can AOT-build)."""
        if x is None:
            return None
        k = _leaf_key(x)
        return None if k == self._driver_tol_keys[i] else k

    def _validate(self, t_eval, dt0, cotangent) -> None:
        if self._backsolve and (t_eval is not None or dt0 is not None):
            raise TypeError(
                "BacksolveAdjoint tracks only the final state: pass "
                "t_start/t_end, not t_eval/dt0"
            )
        if cotangent is not None and isinstance(self.driver, AutoDiffAdjoint):
            raise TypeError(
                "AutoDiffAdjoint's while_loop has no reverse-mode rule: "
                "gradient programs (cotangent=...) need ScanAdjoint "
                "(discretize-then-optimize) or BacksolveAdjoint (adjoint ODE)"
            )

    def _key(self, f, y0, t_eval, t_start, t_end, dt0, args, rtol=None,
             atol=None, device=None, cotangent=None) -> tuple:
        return (
            self._driver_key,
            _f_key(f),
            _tree_key(y0),
            _tree_key(t_eval),
            _tree_key(t_start),
            _tree_key(t_end),
            _tree_key(dt0),
            _tree_key(args),
            self._tol_key(rtol, 0),
            self._tol_key(atol, 1),
            self._device_key(device),
            _tree_key(cotangent),
        )

    def cache_key(self, f, y0, t_eval=None, *, t_start=None, t_end=None,
                  dt0=None, args: Any = None, rtol=None, atol=None,
                  device=None, cotangent=None) -> tuple:
        """The hashable identity of the compiled program a ``solve`` with
        these arguments (or ``ShapeDtypeStruct`` specs) would dispatch to:
        (driver static config, dynamics identity, every dynamic argument's
        shape/dtype class, placement, cotangent class -- ``None`` for forward
        programs).  Two argument sets with equal keys share one executable.
        The serving layer buckets requests by exactly this key, so a bucket
        never straddles two programs (and forward and gradient requests never
        share a bucket)."""
        self._validate(t_eval, dt0, cotangent)
        return self._key(f, y0, t_eval, t_start, t_end, dt0, args, rtol, atol,
                         device, cotangent)

    def _donate(self, t_eval) -> bool:
        """Resolve the donation policy: 'auto' donates y0 exactly when the
        solve tracks only the final state, the one case where an output buffer
        (ys, shaped like y0) exists for XLA to alias into."""
        if self.donate == "auto":
            return t_eval is None
        return self.donate

    def _build(self, f, t_eval, grad: bool = False) -> _CacheEntry:
        """Build the jit-wrapped solve program for one cache point.

        Forward programs call the driver directly.  Gradient programs
        (``grad=True``) wrap the driver's solve in ``jax.vjp`` over
        ``(y0, args)``, pull the caller's cotangent through it, and deliver
        the result as a ``Solution`` whose ``grads`` field carries
        ``Grads(y0=..., args=...)`` -- one compiled artifact per (config,
        shapes, device) covering forward AND backward, which is what makes a
        served gradient request prewarmable exactly like inference.
        """
        driver_def = self._driver_def
        backsolve = self._backsolve

        def run(drv, y0, t_eval, t_start, t_end, dt0, args) -> Solution:
            if backsolve:
                ys = drv.solve(f, y0, t_start=t_start, t_end=t_end, args=args)
                return _final_state_solution(ys, t_end)
            return drv.solve(
                f, y0, t_eval, t_start=t_start, t_end=t_end, dt0=dt0, args=args
            )

        if not grad:
            def fn(y0, tol_leaves, t_eval, t_start, t_end, dt0, args):
                drv = jax.tree_util.tree_unflatten(driver_def, tol_leaves)
                return run(drv, y0, t_eval, t_start, t_end, dt0, args)

            donate = (0,) if self._donate(t_eval) else ()
            return _CacheEntry(jax.jit(fn, donate_argnums=donate),
                               self._driver_leaves)

        def fn(y0, tol_leaves, t_eval, t_start, t_end, dt0, args, cotangent):
            drv = jax.tree_util.tree_unflatten(driver_def, tol_leaves)

            def fwd(y0_, args_):
                sol = run(drv, y0_, t_eval, t_start, t_end, dt0, args_)
                return sol.ys, sol

            if args is None:
                # No args operand: keep the VJP arity minimal (and the
                # gradient None, distinguishable from a zero cotangent).
                ys, vjp_fn, sol = jax.vjp(lambda y_: fwd(y_, None), y0,
                                          has_aux=True)
                (gy0,) = vjp_fn(cotangent)
                gargs = None
            else:
                ys, vjp_fn, sol = jax.vjp(fwd, y0, args, has_aux=True)
                gy0, gargs = vjp_fn(cotangent)
            return dataclasses.replace(sol, grads=Grads(y0=gy0, args=gargs))

        # In the final-state regime the cotangent buffer (argnum 7) has the
        # same shape as ys and grads.y0, so XLA can alias it; y0 itself is a
        # VJP residual and must stay alive.
        donate = (7,) if self._donate(t_eval) else ()
        return _CacheEntry(jax.jit(fn, donate_argnums=donate),
                           self._driver_leaves, grad=True)

    def _lookup(self, f, y0, t_eval, t_start, t_end, dt0, args,
                rtol=None, atol=None, device=None, cotangent=None) -> _CacheEntry:
        self._validate(t_eval, dt0, cotangent)
        key = self._key(f, y0, t_eval, t_start, t_end, dt0, args, rtol, atol,
                        device, cotangent)
        entry = self._cache.get(key)
        if entry is None:
            entry = self._build(f, t_eval, grad=cotangent is not None)
            self._cache.put(key, entry)
        return entry

    def compile(
        self,
        f,
        y0,
        t_eval=None,
        *,
        t_start=None,
        t_end=None,
        dt0=None,
        args: Any = None,
        rtol=None,
        atol=None,
        device=None,
        cotangent=None,
    ) -> CompiledSolve:
        """AOT-compile for the given argument specs (``jax.ShapeDtypeStruct``
        or example arrays) and return the callable executable handle.  The
        entry is also installed in the cache, so a later ``solve`` with
        matching shapes dispatches to the same executable without ever
        tracing again.

        ``rtol``/``atol`` specs select the tolerance shape class to build:
        pass e.g. ``jax.ShapeDtypeStruct((b,), jnp.float32)`` to AOT-compile
        the per-instance-tolerance variant a serving bucket will call with
        (omitting them compiles the driver-default class).

        ``cotangent`` specs (matching the output ``ys``) AOT-build the
        *gradient* program for this point: the VJP-wrapped solve that
        ``solve(..., cotangent=...)`` dispatches to.  Gradient and forward
        programs are distinct cache entries.

        ``device`` pins the executable to one device of the mesh (every
        dynamic argument must then live there at call time -- ``solve`` with
        the same ``device`` places them).  Each device compiles its own
        entry; the serving layer prewarms one per device it round-robins
        over."""
        entry = self._lookup(f, y0, t_eval, t_start, t_end, dt0, args, rtol,
                             atol, device, cotangent)
        if entry.executable is None:
            tol_leaves = list(self._driver_leaves)
            if rtol is not None:
                tol_leaves[0] = rtol
            if atol is not None:
                tol_leaves[1] = atol
            spec_of = _spec
            if device is not None:
                from jax.sharding import SingleDeviceSharding

                sharding = SingleDeviceSharding(device)
                spec_of = lambda x: jax.ShapeDtypeStruct(
                    _spec(x).shape, _spec(x).dtype, sharding=sharding
                )
            operands = (y0, tol_leaves, t_eval, t_start, t_end, dt0, args)
            if entry.grad:
                operands = operands + (cotangent,)
            abstract = jax.tree_util.tree_map(spec_of, operands)
            entry.executable = entry.jitted.lower(*abstract).compile()
        return CompiledSolve(entry)

    def prewarm(self, f, specs: "list[dict] | tuple[dict, ...]") -> int:
        """AOT-compile a batch of program points before traffic arrives.

        Each element of ``specs`` is a kwargs mapping for :meth:`compile`
        minus ``f`` (so it must carry ``y0`` plus whichever of ``t_eval``/
        ``t_start``/``t_end``/``dt0``/``args``/``rtol``/``atol``/``device``
        the serving call will pass), with ``jax.ShapeDtypeStruct`` leaves
        standing in for the concrete arrays.  Returns the number of entries
        compiled for the first time (already-warm points are skipped for
        free, so prewarming is idempotent)."""
        n_new = 0
        for spec in specs:
            spec = dict(spec)
            kw = {k: spec.pop(k, None)
                  for k in ("t_eval", "t_start", "t_end", "dt0", "args",
                            "rtol", "atol", "device", "cotangent")}
            y0 = spec.pop("y0")
            if spec:
                raise TypeError(f"unknown prewarm spec keys: {sorted(spec)}")
            key = self._key(f, y0, kw["t_eval"], kw["t_start"], kw["t_end"],
                            kw["dt0"], kw["args"], kw["rtol"], kw["atol"],
                            kw["device"], kw["cotangent"])
            entry = self._cache.data.get(key)
            if entry is not None and entry.executable is not None:
                continue
            self.compile(f, y0, **kw)
            n_new += 1
        return n_new

    def solve(
        self,
        f,
        y0,
        t_eval=None,
        *,
        t_start=None,
        t_end=None,
        dt0=None,
        args: Any = None,
        rtol=None,
        atol=None,
        device=None,
        cotangent=None,
    ) -> Solution:
        """Dispatch a solve through the zero-retrace cache.  ``device``
        selects the per-device program variant (see :meth:`compile`) and
        commits every dynamic argument there first -- a no-op transfer for
        arguments the caller already placed, which is the serving fast path
        (the batch packer lands buffers on the target device directly).

        ``cotangent`` (matching the output ``ys``; usually ``ones_like`` of
        the final state, or the loss gradient w.r.t. it) routes through the
        *gradient* program: the returned ``Solution`` additionally carries
        ``grads = Grads(y0=dL/dy0, args=dL/dargs)``.  Requires a
        reverse-differentiable driver (``ScanAdjoint``/``BacksolveAdjoint``)."""
        if device is not None:
            (y0, t_eval, t_start, t_end, dt0, args, rtol, atol,
             cotangent) = jax.device_put(
                (y0, t_eval, t_start, t_end, dt0, args, rtol, atol, cotangent),
                device,
            )
        entry = self._lookup(f, y0, t_eval, t_start, t_end, dt0, args, rtol,
                             atol, device, cotangent)
        return entry.call(y0, t_eval, t_start, t_end, dt0, args, rtol, atol,
                          cotangent)


# --------------------------------------------------------------------------
# Multi-device sharding: the batch axis across a mesh.

_SHARDED_CACHE = _KeyedLRU(64)


def _batch_spec(x, batch: int, axis_name: str):
    """Shard any leaf whose leading dim is the batch axis; replicate the rest."""
    from jax.sharding import PartitionSpec as P

    s = _spec(x)
    if len(s.shape) >= 1 and s.shape[0] == batch:
        return P(axis_name)
    return P()


def sharded_solve(
    mesh,
    f,
    y0,
    t_eval=None,
    *,
    t_start=None,
    t_end=None,
    dt0=None,
    args: Any = None,
    solver: _Driver | None = None,
    method: AbstractStepper | str | None = None,
    rtol=None,
    atol=None,
    axis_name: str = "data",
    **solver_kw,
) -> Solution:
    """Solve a batch of IVPs with the batch axis sharded across ``mesh``.

    Instances are independent by the solver's core contract, so this is
    embarrassingly parallel: each device runs the complete adaptive loop on
    its ``b / n_devices`` shard, terminating on its *local* all-done
    reduction (a device whose shard finishes early goes idle instead of
    lock-stepping with the stragglers -- strictly less overhanging work than
    the single-device program).  For explicit steppers, per-instance results,
    statuses and stats are bitwise identical to the single-device ``jax.jit``
    program.  Two caveats: whole-batch overhang accounting (``n_f_evals``)
    can differ, because the dynamics stop being evaluated for a shard as soon
    as that shard drains; and the implicit steppers' batched linear algebra
    compiles to batch-size-dependent XLA fusions, so their agreement is at
    rounding level rather than bitwise.

    Sharding rule: ``y0`` leaves, ``(b,)``-shaped ``t_start``/``t_end``/
    ``dt0``/tolerances, 2-D ``(b, n)`` ``t_eval`` and any ``args`` leaf whose
    leading dim equals the batch size shard along ``axis_name``; everything
    else is replicated (1-D ``t_eval`` is always replicated -- it is a shared
    time grid, whatever its length).

    The batch does NOT have to divide the mesh: a ragged batch is padded up
    to the next multiple of the mesh axis with copies of instance 0 (the
    same trick the serving layer uses for bucket padding -- instances never
    interact, so pad rows only cost FLOPs), solved, and sliced back, so the
    returned ``Solution`` covers exactly the ``b`` requested instances and
    every real instance matches the unsharded program.  A serve-time hot
    bucket can therefore span the mesh whatever its size.

    Pass a configured driver via ``solver=`` or let ``method``/``rtol``/
    ``atol``/``solver_kw`` build an ``AutoDiffAdjoint``.  The shard-mapped
    program is jitted and cached, so repeated same-shape calls do not retrace.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if solver is None:
        solver = AutoDiffAdjoint(
            AbstractStepper.coerce(method),
            rtol=1e-3 if rtol is None else rtol,
            atol=1e-6 if atol is None else atol,
            **solver_kw,
        )
    elif method is not None or rtol is not None or atol is not None or solver_kw:
        raise TypeError(
            "pass solver options (method/rtol/atol/...) to the driver given "
            "via solver=, not to sharded_solve"
        )

    # Commit every leaf to a device array: the sharding specs below are
    # computed from concrete shapes, and host scalars must not split the key.
    y0, t_eval, t_start, t_end, dt0, args = jax.tree_util.tree_map(
        jnp.asarray, (y0, t_eval, t_start, t_end, dt0, args)
    )
    y0_leaves = jax.tree_util.tree_leaves(y0)
    if not y0_leaves:
        raise ValueError("y0 has no array leaves")
    requested = y0_leaves[0].shape[0]
    n_dev = mesh.shape[axis_name]
    n_pad = (-requested) % n_dev

    driver_leaves, driver_def = jax.tree_util.tree_flatten(solver)
    inputs = (driver_leaves, y0, t_eval, t_start, t_end, dt0, args)

    if n_pad:
        # Ragged batch: pad every batch-leading leaf (the same leaves the
        # sharding rule below would shard) to the next multiple of the mesh
        # axis by replicating instance 0, and slice the padding back off the
        # result.  The 1-D t_eval exception mirrors spec_for: a shared grid
        # is never a batch axis, whatever its length.
        def pad_tree(tree):
            if tree is t_eval and t_eval is not None and jnp.ndim(t_eval) == 1:
                return tree
            return jax.tree_util.tree_map(
                lambda x: jnp.concatenate(
                    [x, jnp.repeat(x[:1], n_pad, axis=0)], axis=0)
                if jnp.ndim(x) >= 1 and x.shape[0] == requested else x,
                tree,
            )

        driver_leaves, y0, t_eval, t_start, t_end, dt0, args = (
            pad_tree(tree) for tree in inputs
        )
        inputs = (driver_leaves, y0, t_eval, t_start, t_end, dt0, args)
    batch = requested + n_pad

    key = (
        mesh, axis_name, driver_def, _f_key(f),
        tuple(_tree_key(t) for t in inputs),
    )
    entry = _SHARDED_CACHE.get(key)
    if entry is None:
        def spec_for(tree):
            if tree is t_eval and t_eval is not None and jnp.ndim(t_eval) == 1:
                return P()  # shared time grid, even if its length equals the batch
            return jax.tree_util.tree_map(
                lambda x: _batch_spec(x, batch, axis_name), tree
            )

        in_specs = tuple(spec_for(tree) for tree in inputs)

        def local(driver_leaves, y0, t_eval, t_start, t_end, dt0, args):
            drv = jax.tree_util.tree_unflatten(driver_def, driver_leaves)
            return drv.solve(
                f, y0, t_eval, t_start=t_start, t_end=t_end, dt0=dt0, args=args
            )

        out_shape = jax.eval_shape(local, *inputs)
        out_specs = jax.tree_util.tree_map(lambda _: P(axis_name), out_shape)
        entry = jax.jit(
            shard_map(
                local, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=False,
            )
        )
        _SHARDED_CACHE.put(key, entry)
    sol = entry(*inputs)
    return sol.slice_batch(slice(0, requested)) if n_pad else sol
