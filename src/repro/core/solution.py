"""Solution container, per-instance status codes and solver statistics."""

from __future__ import annotations

import dataclasses
import enum
from typing import Any

import jax


class Status(enum.IntEnum):
    """Per-instance termination status (SUCCESS == 0, as in torchode)."""

    SUCCESS = 0
    REACHED_MAX_STEPS = 1
    INFINITE = 2
    REACHED_DT_MIN = 3
    EVENT = 4  # a terminal event fired; the instance stopped at event_t


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Solution:
    """Result of a batched IVP solve.

    ts:     (b, n) evaluation times (== the t_eval passed in), or (b,) the
            per-instance reached times when t_eval is None (t_end on SUCCESS,
            the event time on EVENT, the last accepted time otherwise)
    ys:     (b, n, f) solution values, or (b, f) final states when t_eval is None.
            For a PyTree initial state, ``ys`` is the same PyTree structure with
            (b, n, ...) / (b, ...) leaves (unravelled at the driver boundary).
            Dense output is truncated at a terminal event: eval points past the
            event time stay at their initial (zero) fill and are excluded from
            ``n_initialized``.
    status: (b,) int32, one of ``Status``
    stats:  the solver's statistics registry: a dict of named per-instance (b,)
            accumulators contributed by each component (stepper: n_f_evals,
            controller: n_accepted, step function: n_steps, n_initialized,
            and n_events when events are registered, plus any user-registered
            contributors)

    When events are registered (all None otherwise; E = number of events):

    event_t:    (b, E) localized first-crossing times (NaN where not fired)
    event_y:    (b, E, f) interpolated states at the crossings (PyTree states
                unravel to (b, E, ...) leaves)
    event_mask: (b, E) bool -- which (instance, event) cells fired
    """

    ts: jax.Array
    ys: jax.Array
    status: jax.Array
    stats: dict[str, Any]
    event_t: jax.Array | None = None
    event_y: Any = None
    event_mask: jax.Array | None = None

    @property
    def success(self) -> jax.Array:
        """True where integration ended as requested: reached t_end OR was
        stopped by a terminal event (scipy's solve_ivp convention -- an event
        termination is the *intended* outcome, not a failure)."""
        return (self.status == Status.SUCCESS.value) | (self.status == Status.EVENT.value)
