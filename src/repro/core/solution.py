"""Solution container, per-instance status codes and solver statistics."""

from __future__ import annotations

import dataclasses
import enum
from typing import Any

import jax


class Status(enum.IntEnum):
    """Per-instance termination status (SUCCESS == 0, as in torchode)."""

    SUCCESS = 0
    REACHED_MAX_STEPS = 1
    INFINITE = 2
    REACHED_DT_MIN = 3


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Solution:
    """Result of a batched IVP solve.

    ts:     (b, n) evaluation times (== the t_eval passed in), or (b,) final times
    ys:     (b, n, f) solution values, or (b, f) final states when t_eval is None.
            For a PyTree initial state, ``ys`` is the same PyTree structure with
            (b, n, ...) / (b, ...) leaves (unravelled at the driver boundary).
    status: (b,) int32, one of ``Status``
    stats:  the solver's statistics registry: a dict of named per-instance (b,)
            accumulators contributed by each component (stepper: n_f_evals,
            controller: n_accepted, step function: n_steps, n_initialized,
            plus any user-registered contributors)
    """

    ts: jax.Array
    ys: jax.Array
    status: jax.Array
    stats: dict[str, Any]

    @property
    def success(self) -> jax.Array:
        return self.status == Status.SUCCESS.value
