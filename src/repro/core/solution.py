"""Solution container, per-instance status codes and solver statistics."""

from __future__ import annotations

import dataclasses
import enum
from typing import Any, NamedTuple

import jax
import numpy as np


class Grads(NamedTuple):
    """Gradients delivered by a reverse-mode solve program.

    y0:   cotangent of the initial state -- same PyTree structure as the
          ``y0`` that was solved, every leaf with the batch as its leading
          axis (``(b, f)`` for flat states).
    args: cotangent of the dynamics arguments (same structure as ``args``),
          or ``None`` when the solve carried no args.  When the term batches
          its args (``ODETerm.batched_args`` / serving's per-request parameter
          rows), each leaf's leading axis is the batch and row ``i`` is
          request ``i``'s own parameter gradient.
    """

    y0: Any
    args: Any = None


class Status(enum.IntEnum):
    """Per-instance termination status (SUCCESS == 0, as in torchode)."""

    SUCCESS = 0
    REACHED_MAX_STEPS = 1
    INFINITE = 2
    REACHED_DT_MIN = 3
    EVENT = 4  # a terminal event fired; the instance stopped at event_t


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class Solution:
    """Result of a batched IVP solve.

    ts:     (b, n) evaluation times (== the t_eval passed in), or (b,) the
            per-instance reached times when t_eval is None (t_end on SUCCESS,
            the event time on EVENT, the last accepted time otherwise)
    ys:     (b, n, f) solution values, or (b, f) final states when t_eval is None.
            For a PyTree initial state, ``ys`` is the same PyTree structure with
            (b, n, ...) / (b, ...) leaves (unravelled at the driver boundary).
            Dense output is truncated at a terminal event: eval points past the
            event time stay at their initial (zero) fill and are excluded from
            ``n_initialized``.
    status: (b,) int32, one of ``Status``
    stats:  the solver's statistics registry: a dict of named per-instance (b,)
            accumulators contributed by each component (stepper: n_f_evals,
            controller: n_accepted, step function: n_steps, n_initialized,
            and n_events when events are registered, plus any user-registered
            contributors)

    When events are registered (all None otherwise; E = number of events):

    event_t:    (b, E) localized first-crossing times (NaN where not fired)
    event_y:    (b, E, f) interpolated states at the crossings (PyTree states
                unravel to (b, E, ...) leaves)
    event_mask: (b, E) bool -- which (instance, event) cells fired

    grads: a ``Grads(y0=..., args=...)`` record when the solution came out of
    a reverse-mode program (``CompiledSolver.solve(cotangent=...)`` / a
    served ``GradRequest``), ``None`` otherwise.  Every grads leaf carries
    the batch as its leading axis, so ``slice_batch`` views carve per-request
    gradients out of a coalesced backward solve exactly like ``ys``.
    """

    ts: jax.Array
    ys: jax.Array
    status: jax.Array
    stats: dict[str, Any]
    event_t: jax.Array | None = None
    event_y: Any = None
    event_mask: jax.Array | None = None
    grads: Any = None

    @property
    def success(self) -> jax.Array:
        """True where integration ended as requested: reached t_end OR was
        stopped by a terminal event (scipy's solve_ivp convention -- an event
        termination is the *intended* outcome, not a failure)."""
        return (self.status == Status.SUCCESS.value) | (self.status == Status.EVENT.value)

    def is_ready(self) -> bool:
        """True when every device buffer has finished computing.

        JAX dispatch is asynchronous: a solve returns immediately with
        futures for its output buffers.  The serving engine launches a batch,
        keeps packing the next one, and uses this probe to harvest completed
        solutions without ever blocking the host on an unfinished program
        (host arrays are trivially ready).
        """
        return all(
            x.is_ready() for x in jax.tree_util.tree_leaves(self)
            if isinstance(x, jax.Array)
        )

    def block_until_ready(self) -> "Solution":
        """Wait for every device buffer; returns self (chains like jax's)."""
        jax.block_until_ready(jax.tree_util.tree_leaves(self))
        return self

    def to_host(self) -> "Solution":
        """Deliver every field as a host NumPy array -- one device->host
        transfer per field (blocking if buffers are still computing).  The
        serving layer calls this exactly once per harvested batch, so the
        per-request ``slice_batch`` views that follow are zero-copy host
        slices instead of b device dispatches per field."""
        return jax.tree_util.tree_map(np.asarray, self)

    def slice_batch(self, index) -> "Solution":
        """View of a subset of instances: every field sliced along the batch
        axis by ``index`` (a ``slice``, int array or index list -- anything
        numpy-style that preserves the leading axis).

        This is the unpacking primitive of the serving layer: a padded bucket
        solve slices back into per-request solutions, and because instances
        never interact (the solver's core batch-invariance contract), a
        sliced view is exactly what solving those instances alone would have
        produced.  Works on PyTree ``ys``/``event_y`` (every leaf carries the
        batch as its leading axis) and slices each stats accumulator.
        """
        take = lambda x: x[index]
        if (isinstance(self.ys, (np.ndarray, jax.Array)) and self.event_t is None
                and self.grads is None):
            # Fast path for flat-state, event-free, forward-only solutions:
            # direct indexing, no tree machinery (the serving unpack hot loop).
            return Solution(
                ts=self.ts[index],
                ys=self.ys[index],
                status=self.status[index],
                stats={k: v[index] for k, v in self.stats.items()},
            )
        maybe = lambda x: None if x is None else jax.tree_util.tree_map(take, x)
        return dataclasses.replace(
            self,
            ts=take(self.ts),
            ys=jax.tree_util.tree_map(take, self.ys),
            status=take(self.status),
            stats={k: jax.tree_util.tree_map(take, v) for k, v in self.stats.items()},
            event_t=maybe(self.event_t),
            event_y=maybe(self.event_y),
            event_mask=maybe(self.event_mask),
            grads=maybe(self.grads),
        )

    def truncate_eval(self, n: int) -> "Solution":
        """Drop evaluation points past the first ``n``: ``ts`` becomes
        ``(b, n)`` and every ``ys`` leaf ``(b, n, ...)``.

        The unpad view for eval-grid padding: the serving layer pads each
        request's ``t_eval`` to a power-of-two length class by repeating the
        final time, and the repeated columns -- pure interpolant re-evaluations,
        never solver state -- are cut off here.  ``stats`` are left untouched
        and so count the padded grid (``n_initialized`` in particular).
        """
        if self.ts.ndim < 2:
            raise ValueError(
                "truncate_eval needs a dense-output solution (ts of shape "
                f"(b, n)); this one tracks only final states (ts {self.ts.shape})"
            )
        return dataclasses.replace(
            self,
            ts=self.ts[:, :n],
            ys=jax.tree_util.tree_map(lambda x: x[:, :n], self.ys),
        )
