"""repro.core -- batch-parallel adaptive ODE solving (the torchode technique in JAX).

Two API levels:

  - one-call wrappers: ``solve_ivp`` / ``solve_ivp_scan`` (flat arrays or
    PyTree states)
  - composable components: ``AutoDiffAdjoint(Stepper("tsit5"),
    pid_controller()).solve(f, y0, t_eval)`` -- term, stepper, controller and
    driver are each independently swappable, and every component can
    contribute per-instance accumulators to the solver's statistics registry.
"""

from .compiled import CompiledSolve, CompiledSolver, sharded_solve
from .controller import (
    FixedController,
    PIDController,
    integral_controller,
    pi_controller,
    pid_controller,
)
from .drivers import AutoDiffAdjoint, BacksolveAdjoint, ScanAdjoint
from .events import Event, EventState
from .loop import make_solver, solve_ivp, solve_ivp_scan
from .newton import NewtonConfig, NewtonResult, newton_solve
from .serving import GradRequest, SolveFuture, SolveRequest, SolveService
from .solution import Grads, Solution, Status
from .step import FusedFallbackReason, LoopState, StepContext, StepFunction
from .stepper import (
    AbstractStepper,
    DiagonallyImplicitRK,
    DIRKCarry,
    ExplicitRK,
    Stepper,
    StepResult,
    initial_step_size,
    rk_step,
)
from .tableau import TABLEAUS, ButcherTableau, get_tableau
from .terms import (
    ODETerm,
    PolynomialTerm,
    RaveledState,
    as_term,
    polynomial_term,
    ravel_state,
    ravel_term,
)

__all__ = [
    "AbstractStepper",
    "CompiledSolve",
    "CompiledSolver",
    "sharded_solve",
    "DiagonallyImplicitRK",
    "DIRKCarry",
    "ExplicitRK",
    "NewtonConfig",
    "NewtonResult",
    "newton_solve",
    "FixedController",
    "PIDController",
    "integral_controller",
    "pi_controller",
    "pid_controller",
    "AutoDiffAdjoint",
    "BacksolveAdjoint",
    "ScanAdjoint",
    "Event",
    "EventState",
    "make_solver",
    "solve_ivp",
    "solve_ivp_scan",
    "GradRequest",
    "SolveFuture",
    "SolveRequest",
    "SolveService",
    "Grads",
    "Solution",
    "Status",
    "LoopState",
    "StepContext",
    "StepFunction",
    "FusedFallbackReason",
    "Stepper",
    "StepResult",
    "initial_step_size",
    "rk_step",
    "TABLEAUS",
    "ButcherTableau",
    "get_tableau",
    "ODETerm",
    "PolynomialTerm",
    "RaveledState",
    "as_term",
    "polynomial_term",
    "ravel_state",
    "ravel_term",
]
