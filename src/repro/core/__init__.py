"""repro.core -- batch-parallel adaptive ODE solving (the torchode technique in JAX)."""

from .controller import (
    FixedController,
    PIDController,
    integral_controller,
    pi_controller,
    pid_controller,
)
from .loop import make_solver, solve_ivp, solve_ivp_scan
from .solution import Solution, Status
from .tableau import TABLEAUS, ButcherTableau, get_tableau
from .terms import ODETerm, as_term

__all__ = [
    "FixedController",
    "PIDController",
    "integral_controller",
    "pi_controller",
    "pid_controller",
    "make_solver",
    "solve_ivp",
    "solve_ivp_scan",
    "Solution",
    "Status",
    "TABLEAUS",
    "ButcherTableau",
    "get_tableau",
    "ODETerm",
    "as_term",
]
