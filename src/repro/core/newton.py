"""Batched masked Newton/chord iteration for the implicit stage equations.

This is the paper's per-instance principle pushed down into the *inner*
nonlinear solve: every ODE instance in the batch iterates its own Newton
sequence and terminates independently through a convergence mask, exactly the
way the outer loop freezes finished instances.  One global ``while_loop``
iteration performs one batched vector-field evaluation and one batched dense
linear solve -- instances that already converged (or failed) stop updating
but keep riding along (the inner-loop analogue of torchode's "overhanging
evaluations"), so there is never a host sync or a per-instance Python loop.

The iteration is a *chord* Newton: the matrix ``M = I - dt*gamma*J`` is built
once per solver step from a (possibly stale, per-instance refreshed) Jacobian
and reused across all stages and iterations.  Two linear-algebra strategies
share the loop:

``M`` path
    Each iteration runs a full batched dense solve against ``M``
    (``ops.batched_linsolve``) followed by the masked commit + convergence
    norm (``ops.masked_newton_update``).  This is the external-caller
    fallback: no precomputation required.

``operator`` path (factor once)
    The caller factors ``M`` once per step via ``ops.batched_lu_factor``
    (partial-pivoted LU) and every iteration runs ONE fused op,
    ``ops.fused_newton_iter``: residual, permutation scatter, the two
    triangular back-substitutions against the prefactored LU, masked commit,
    and the scaled-RMS norm in a single launch.  On the ref backend the LU
    composition reproduces ``jnp.linalg.solve`` bitwise (it is the same
    ``lax.linalg.lu`` + triangular-solve sequence ``solve`` lowers to), so
    both paths yield identical iterates.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .static import register_static


@register_static
@dataclasses.dataclass(frozen=True)
class NewtonConfig:
    """The inner nonlinear solver's knobs as one hashable static-config
    object: ``DiagonallyImplicitRK`` carries a ``NewtonConfig``, so the knobs
    participate in the stepper's value hash (equal configs -> the same
    compiled program) and cross ``jax.jit`` boundaries as compile-time
    constants, which is what lets the iteration caps unroll into the traced
    ``while_loop`` bound.

    tol
        Convergence threshold for the scaled RMS of the Newton update,
        measured in the step's atol/rtol error units.
    max_iters
        Per-stage iteration cap; exhausting it marks the instance failed.
    divergence_rate
        Growth factor of the update norm between iterations that counts as
        divergence.
    slow_iters
        Iteration count at or above which a *converged* instance is still
        considered slow, scheduling a Jacobian refresh for its next step.
        ``None`` (the default) derives ``max(2, max_iters // 2)``.
    """

    tol: float = 1e-2
    max_iters: int = 8
    divergence_rate: float = 2.0
    slow_iters: int | None = None

    @property
    def effective_slow_iters(self) -> int:
        """The refresh threshold with the ``None`` default resolved."""
        if self.slow_iters is not None:
            return self.slow_iters
        return max(2, self.max_iters // 2)


class NewtonResult(NamedTuple):
    k: jax.Array  # (b, f) solved stage derivative (where converged)
    converged: jax.Array  # (b,) bool: update norm fell below tol
    diverged: jax.Array  # (b,) bool: non-finite residual or growing iterates
    n_iters: jax.Array  # (b,) int32: iterations while this instance was active
    n_evals: jax.Array  # () int32: batched vf evaluations (overhanging count)


class _NewtonState(NamedTuple):
    k: jax.Array
    active: jax.Array
    converged: jax.Array
    diverged: jax.Array
    n_iters: jax.Array
    prev_norm: jax.Array
    it: jax.Array


def newton_solve(
    eval_fn: Callable[[jax.Array], jax.Array],
    k0: jax.Array,  # (b, f) initial iterate (predictor)
    M: jax.Array | None = None,  # (b, f, f) chord matrix I - dt*gamma*J
    scale: jax.Array | None = None,  # (b, f) error scale atol + rtol*|y|
    *,
    operator: tuple[jax.Array, jax.Array] | None = None,
    config: NewtonConfig | None = None,
) -> NewtonResult:
    """Solve ``k = eval_fn(k)`` per instance by masked chord-Newton iteration.

    ``eval_fn`` is the batched stage map ``k -> f(t_i, y_pred + dt*a_ii*k)``;
    the residual is ``g(k) = k - eval_fn(k)`` and each iteration applies
    ``k <- k - M^{-1} g(k)`` where an instance is still active.  Convergence is
    per instance: the scaled RMS of the update falls below ``config.tol``
    (measured in the same atol/rtol units as the step acceptance test, so
    ``tol`` is the fraction of the local error budget the inexact solve may
    consume).  Divergence -- non-finite values or the update norm growing by
    more than ``config.divergence_rate`` between iterations -- deactivates the
    instance with ``diverged`` set; the stepper reports that through the
    controller's reject path rather than poisoning the whole batch.

    The linear solve comes from exactly one of two sources:

    - ``M``: the chord matrix itself; each iteration runs a fresh batched
      dense solve (``ops.batched_linsolve``).
    - ``operator``: the ``(lu, permutation)`` pair from
      ``ops.batched_lu_factor(M)``; each iteration runs the single fused
      ``ops.fused_newton_iter`` launch against the prefactored LU.

    All numeric knobs live on ``config`` (a :class:`NewtonConfig`); ``None``
    means the defaults.
    """
    if (M is None) == (operator is None):
        raise TypeError("newton_solve needs exactly one of M= or operator=")
    if scale is None:
        raise TypeError("newton_solve requires scale")
    cfg = config if config is not None else NewtonConfig()
    tol, max_iters, divergence_rate = cfg.tol, cfg.max_iters, cfg.divergence_rate
    b = k0.shape[0]
    inf = jnp.asarray(jnp.inf, k0.dtype)

    def cond(s: _NewtonState):
        return jnp.any(s.active) & (s.it < max_iters)

    def body(s: _NewtonState):
        if operator is not None:
            lu, perm = operator
            k_new, res_norm = ops.fused_newton_iter(
                lu, perm, s.k, eval_fn(s.k), s.active, scale)
        else:
            g = s.k - eval_fn(s.k)
            delta = ops.batched_linsolve(M, g)
            k_new, res_norm = ops.masked_newton_update(s.k, delta, s.active, scale)
        finite = jnp.isfinite(res_norm)
        conv_now = s.active & finite & (res_norm <= tol)
        div_now = s.active & (~finite | ((s.it > 0) & (res_norm > divergence_rate * s.prev_norm)))
        return _NewtonState(
            k=k_new,
            active=s.active & ~conv_now & ~div_now,
            converged=s.converged | conv_now,
            diverged=s.diverged | div_now,
            n_iters=s.n_iters + s.active.astype(jnp.int32),
            prev_norm=jnp.where(s.active, res_norm, s.prev_norm),
            it=s.it + 1,
        )

    init = _NewtonState(
        k=k0,
        active=jnp.ones((b,), dtype=bool),
        converged=jnp.zeros((b,), dtype=bool),
        diverged=jnp.zeros((b,), dtype=bool),
        n_iters=jnp.zeros((b,), dtype=jnp.int32),
        prev_norm=jnp.full((b,), inf),
        it=jnp.zeros((), dtype=jnp.int32),
    )
    out = jax.lax.while_loop(cond, body, init)
    return NewtonResult(
        k=out.k,
        converged=out.converged,
        diverged=out.diverged | (out.active & ~out.converged),
        n_iters=out.n_iters,
        n_evals=out.it,
    )
