from .sharding import (
    batch_spec,
    cache_shardings,
    param_shardings,
    state_shardings,
)

__all__ = ["batch_spec", "cache_shardings", "param_shardings", "state_shardings"]
