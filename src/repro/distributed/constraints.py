"""Activation sharding constraints.

FSDP shards weights over the data axes; without anchors, GSPMD happily
propagates those weight shardings INTO the activations (batch becomes
replicated, d_model becomes data-sharded -- a 16x per-device compute blowup we
measured in the dry-run).  Anchoring the residual stream at period boundaries
forces the all-gathers onto the (small) weights instead, which is the whole
point of ZeRO-3.

The model code calls ``constrain(x, *spec)`` with LOGICAL axis names
("dp", "tp", None); launchers activate a mapping to mesh axes for the duration
of a trace.  When inactive (CPU unit tests), constrain is a no-op.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import PartitionSpec as P

_state = threading.local()


def _mapping():
    return getattr(_state, "mapping", None)


@contextlib.contextmanager
def activation_sharding(dp=("data",), tp="model", tp_size=None, mesh=None):
    """Enable logical->mesh axis mapping for constrain() inside jit traces.

    ``tp_size`` (the model-axis extent) lets layers pick divisibility-dependent
    strategies (e.g. head- vs sequence-sharded attention for GQA).  ``mesh``
    enables shard_map-based layers (expert-parallel MoE dispatch)."""
    prev = _mapping()
    _state.mapping = {
        "dp": tuple(dp), "tp": tp, None: None, "_tp_size": tp_size, "_mesh": mesh,
    }
    try:
        yield
    finally:
        _state.mapping = prev


def tp_size():
    """Model-axis size under the active mapping, or None when inactive."""
    m = _mapping()
    return m.get("_tp_size") if m else None


def current_mesh():
    """Mesh under the active mapping (for shard_map layers), or None."""
    m = _mapping()
    return m.get("_mesh") if m else None


def logical_axes():
    m = _mapping()
    if m is None:
        return None, None
    return m["dp"], m["tp"]


def constrain(x, *spec):
    """with_sharding_constraint using logical axes; no-op outside launchers."""
    m = _mapping()
    if m is None:
        return x
    resolved = tuple(m.get(s, None) for s in spec)
    return jax.lax.with_sharding_constraint(x, P(*resolved))
