"""GSPMD sharding rules for parameters, optimizer state, activations, caches.

Layout summary (mesh axes: optional "pod", "data", "model"):
  - batch dims           -> ("pod", "data")   [dp]
  - attention heads/ffn  -> "model"           [tensor parallelism]
  - MoE expert dim       -> "model"           [expert parallelism]
  - vocab (embed rows)   -> "model"
  - FSDP: the non-model weight dim additionally shards over dp (ZeRO-3);
    optimizer moments inherit their parameter's spec.
  - KV caches: flat head dim (KV*hd) -> "model"; batch -> dp.

Every rule is guarded by divisibility: a dim that does not divide evenly by
the axis size falls back to replication (recorded in the dry-run report).
"""

from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axis_size(mesh, axis):
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        s = 1
        for a in axis:
            s *= mesh.shape[a]
        return s
    return mesh.shape[axis]


def _guard(mesh, shape, spec):
    """Replace any axis assignment whose shard count does not divide the dim."""
    fixed = []
    for dim, axis in zip(shape, spec):
        fixed.append(axis if dim % _axis_size(mesh, axis) == 0 else None)
    return P(*fixed)


def _leaf_name(path):
    parts = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
    name = parts[-1]
    # quantized-optimizer leaves ("q" int8 payload / "s" blockwise scales)
    # inherit their parameter's rule; see optim/quantized.py
    if name in ("q", "s") and len(parts) >= 2:
        name = parts[-2]
    return name, parts


# trailing-dim specs by leaf name (after stripping any leading period axis)
def _weight_rule(name: str, parts: list[str], ndim: int, fsdp_ax):
    moe = "moe" in parts
    table = {
        "embed": ("model", fsdp_ax),
        "wq": (fsdp_ax, "model"),
        "wk": (fsdp_ax, "model"),
        "wv": (fsdp_ax, "model"),
        "wo": ("model", fsdp_ax),
        "bq": ("model",),
        "bk": ("model",),
        "bv": ("model",),
        "router": (fsdp_ax, None),
        "shared_in": (fsdp_ax, "model"),
        "shared_gate": (fsdp_ax, "model"),
        "shared_out": ("model", fsdp_ax),
        # mamba
        "in_proj": (fsdp_ax, "model"),
        "conv_w": (None, "model"),
        "conv_b": ("model",),
        "x_proj": ("model", None),
        "dt_proj": (None, "model"),
        "dt_bias": ("model",),
        "A_log": ("model", None),
        "D": ("model",),
        "out_proj": ("model", fsdp_ax),
        # xlstm
        "up": (fsdp_ax, "model"),
        "down": ("model", fsdp_ax),
        "wi": (None, None),
        "wf": (None, None),
        "out": (None, "model"),
    }
    if moe and name in ("w_in", "w_gate"):
        return ("model", fsdp_ax, None)  # (E, d, h): expert parallel + fsdp
    if moe and name == "w_out":
        return ("model", None, fsdp_ax)
    if name in ("w_in", "w_gate"):
        return (fsdp_ax, "model")
    if name == "w_out":
        return ("model", fsdp_ax)
    if name.startswith("r_") or name.startswith("w_"):  # slstm gates
        return (None, "model")
    if name.endswith("_scale") or name.endswith("_bias"):
        return (None,) * ndim
    if name in table:
        return table[name]
    return (None,) * ndim


def param_shardings(mesh, abstract_params, *, fsdp: bool = True):
    """PartitionSpec tree for a params (or adam moments) pytree."""
    fs = dp_axes(mesh) if fsdp else None
    if fs is not None and len(fs) == 1:
        fs = fs[0]

    def spec(path, leaf):
        name, parts = _leaf_name(path)
        in_blocks = any(p in ("blocks", "enc_blocks") for p in parts)
        ndim = leaf.ndim - (1 if in_blocks else 0)
        rule = _weight_rule(name, parts, ndim, fs)
        rule = (tuple(rule) + (None,) * ndim)[:ndim]
        full = ((None,) if in_blocks else ()) + tuple(rule)
        return NamedSharding(mesh, _guard(mesh, leaf.shape, full))

    return jax.tree_util.tree_map_with_path(spec, abstract_params)


def state_shardings(mesh, abstract_state, *, fsdp: bool = True):
    """Shardings for {params, opt{m, v, step}} train state."""
    return {
        "params": param_shardings(mesh, abstract_state["params"], fsdp=fsdp),
        "opt": {
            "m": param_shardings(mesh, abstract_state["opt"]["m"], fsdp=fsdp),
            "v": param_shardings(mesh, abstract_state["opt"]["v"], fsdp=fsdp),
            "step": NamedSharding(mesh, P()),
        },
    }


def batch_spec(mesh, x):
    """Batch-leading activation spec: batch -> dp, rest replicated.

    ``x`` may be an int (ndim; unguarded) or an abstract array, in which case
    the batch axis falls back to replication when not divisible (e.g. the
    long_500k cell's global_batch=1)."""
    dp = dp_axes(mesh)
    if isinstance(x, int):
        return NamedSharding(mesh, P(dp, *([None] * (x - 1))))
    spec = (dp,) + (None,) * (x.ndim - 1)
    return NamedSharding(mesh, _guard(mesh, x.shape, spec))


def cache_shardings(mesh, abstract_cache):
    """KV/SSM/xLSTM cache specs (leaves carry a leading period axis)."""
    dp = dp_axes(mesh)

    def spec(path, leaf):
        name, parts = _leaf_name(path)
        nd = leaf.ndim
        if name in ("k", "v", "xk", "xv"):  # (L, b, S, KV*hd)
            s = (None, dp, None, "model")
        elif name == "h" and nd == 4:  # mamba state (L, b, di, N)
            s = (None, dp, "model", None)
        elif name == "conv":  # (L, b, K-1, di)
            s = (None, dp, None, "model")
        elif name == "C":  # mlstm (L, b, H, hd, hd)
            s = (None, dp, None, "model", None)
        elif name == "n" and nd == 4:  # mlstm (L, b, H, hd)
            s = (None, dp, None, "model")
        else:  # slstm (L, b, d) / mlstm m (L, b, H)
            s = (None, dp, "model") if nd == 3 else (None, dp) + (None,) * (nd - 2)
        return NamedSharding(mesh, _guard(mesh, leaf.shape, s[:nd]))

    return jax.tree_util.tree_map_with_path(spec, abstract_cache)
