"""Gradient compression for the cross-pod (DCI) all-reduce.

At 2+ pods the "pod" axis crosses data-center interconnect, which is an order
of magnitude slower than intra-pod ICI -- the cross-pod gradient reduction is
the natural place for lossy compression.  We implement int8 block quantization
with ERROR FEEDBACK (the residual of this step's quantization is added to the
next step's gradient), which keeps SGD convergence (Karimireddy et al., 2019).

``compressed_psum_pod`` runs inside ``jax.shard_map`` over the "pod" axis with
the other mesh axes left automatic, so it composes with the jit train step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def quantize_int8(x):
    """Blockwise symmetric int8 quantization.  Returns (q, scales)."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale, shape):
    out = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return out[:n].reshape(shape)


def compress_roundtrip(x):
    q, s = quantize_int8(x)
    return dequantize_int8(q, s, x.shape)


def psum_compressed(x, axis_name: str):
    """int8-compressed psum along ``axis_name`` (inside shard_map): quantize,
    all-to-all-free ring reduce emulated by psum of dequantized int8 payload.

    The wire payload is q (1 byte/elt) + scales (4/BLOCK bytes/elt) ~ 4x less
    than f32.  We model it as psum over the dequantized tensor so XLA emits one
    collective; on real hardware this maps to a custom reduction kernel.
    """
    q, s = quantize_int8(x)
    deq = dequantize_int8(q, s, x.shape)
    return jax.lax.psum(deq, axis_name)


def grads_with_error_feedback(grads, ef_state, compress_fn=compress_roundtrip):
    """Apply compression with error feedback: g' = C(g + e); e' = (g + e) - g'."""
    corrected = jax.tree.map(lambda g, e: g + e, grads, ef_state)
    compressed = jax.tree.map(compress_fn, corrected)
    new_ef = jax.tree.map(lambda c, comp: c - comp, corrected, compressed)
    return compressed, new_ef


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
