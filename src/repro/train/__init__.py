from .steps import (
    cross_entropy_loss,
    init_train_state,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

__all__ = [
    "cross_entropy_loss",
    "init_train_state",
    "make_decode_step",
    "make_prefill_step",
    "make_train_step",
]
