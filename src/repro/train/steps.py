"""Jit-level step functions: train (fwd + bwd + AdamW) and serve (prefill/decode).

These are the exact programs the multi-pod dry-run lowers and compiles; they
are also what examples/train_lm.py executes on reduced configs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import decode_step as model_decode
from ..models import forward, init_params, prefill
from ..optim import adamw_init, adamw_update, clip_by_global_norm
from ..optim.adamw import AdamWConfig
from ..optim.quantized import qadamw_init, qadamw_update


def cross_entropy_loss(logits, labels, mask=None):
    """Stable CE over a (possibly vocab-sharded) logits tensor; f32 math."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()


def init_train_state(cfg, key, *, optimizer: str = "adamw"):
    params = init_params(cfg, key)
    init = qadamw_init if optimizer == "adamw8bit" else adamw_init
    return {"params": params, "opt": init(params)}


def make_train_step(cfg, opt_cfg: AdamWConfig | None = None, *, moe_aux_weight=0.01,
                    remat: bool = False, optimizer: str = "adamw"):
    opt_cfg = opt_cfg or AdamWConfig()
    opt_update = qadamw_update if optimizer == "adamw8bit" else adamw_update

    def loss_fn(params, batch):
        # remat is applied PER PERIOD inside the layer scan (see models/lm.py)
        logits, aux = forward(cfg, params, batch, remat=remat)
        mask = batch.get("loss_mask")
        loss = cross_entropy_loss(logits, batch["labels"], mask)
        metrics = {"ce_loss": loss}
        if "moe_balance" in aux:
            loss = loss + moe_aux_weight * aux["moe_balance"]
            metrics["moe_balance"] = aux["moe_balance"]
        return loss, metrics

    def train_step(state, batch):
        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state["params"], batch
        )
        grads, gn = clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt, extra = opt_update(opt_cfg, state["params"], grads, state["opt"])
        metrics = {**metrics, **extra, "loss": loss, "grad_norm": gn}
        return {"params": params, "opt": opt}, metrics

    return train_step


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        return prefill(cfg, params, batch)

    return prefill_step


def make_decode_step(cfg):
    def decode_fn(params, token, pos, cache):
        return model_decode(cfg, params, token, pos, cache)

    return decode_fn
