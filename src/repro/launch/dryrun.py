import os

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"
).strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell.

For each cell this prints/records:
  - compiled.memory_analysis()  (per-device bytes -- does it fit HBM?)
  - compiled.cost_analysis()    (HLO FLOPs / bytes for the roofline)
  - collective bytes parsed from the post-SPMD HLO text
  - the three roofline terms (compute / memory / collective, seconds)

Usage:
  python -m repro.launch.dryrun --arch starcoder2-15b --shape train_4k
  python -m repro.launch.dryrun --all --out dryrun_results.json
  python -m repro.launch.dryrun --all --multi-pod
"""

import argparse
import json
import sys
import time

import jax
import numpy as np

from ..configs import all_archs, get_config
from ..distributed.sharding import (
    batch_spec,
    cache_shardings,
    param_shardings,
    state_shardings,
)
from ..models.config import SHAPES
from ..train.steps import make_decode_step, make_prefill_step, make_train_step
from . import specs as S
from .mesh import make_production_mesh
from jax.sharding import NamedSharding, PartitionSpec as P

from . import hlocost

# --- TPU v5e hardware constants (per chip) ---
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s/link


def roofline(per_dev_flops, per_dev_bytes, per_dev_coll_bytes):
    """Three roofline terms in seconds.  Inputs are PER-DEVICE quantities taken
    from the post-SPMD (per-device) HLO module, so each term divides by one
    chip's peak; this equals global/(chips*peak) for an even sharding."""
    terms = {
        "compute_s": per_dev_flops / PEAK_FLOPS,
        "memory_s": per_dev_bytes / HBM_BW,
        "collective_s": per_dev_coll_bytes / ICI_BW,
    }
    terms["bottleneck"] = max(
        ("compute_s", "memory_s", "collective_s"), key=lambda k: terms[k]
    )
    return terms


def model_flops(cfg, abstract_params, shape_name: str) -> float:
    """6*N*D (train) / 2*N*D (inference), N_active for MoE -- the 'useful
    compute' yardstick against which HLO FLOPs are compared."""
    sh = SHAPES[shape_name]
    d_tokens = sh["global_batch"] * (sh["seq_len"] if sh["kind"] != "decode" else 1)

    def leaf_count(path, leaf):
        parts = [getattr(k, "key", str(k)) for k in path]
        n = float(np.prod(leaf.shape))
        if "moe" in parts and any(
            name in parts[-1] for name in ("w_in", "w_gate", "w_out")
        ) and "shared" not in parts[-1]:
            n *= cfg.moe.top_k / cfg.moe.n_experts  # routed experts: active fraction
        return n

    import jax.tree_util as jtu

    n_active = sum(
        leaf_count(p, l) for p, l in jtu.tree_leaves_with_path(abstract_params["params"] if "params" in abstract_params else abstract_params)
    )
    factor = 6.0 if sh["kind"] == "train" else 2.0
    return factor * n_active * d_tokens


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, fsdp: bool = True,
               remat: str = "auto", optimizer: str = "adamw"):
    """Build + lower + compile one cell; returns (compiled, info dict)."""
    cfg = get_config(arch)
    ok, why = S.cell_runnable(cfg, shape_name)
    if not ok:
        return None, {"arch": arch, "shape": shape_name, "skipped": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    from ..distributed.constraints import activation_sharding

    with mesh, activation_sharding(dp=dp, tp="model", tp_size=mesh.shape["model"], mesh=mesh):
        if kind == "train":
            use_remat = (remat == "on") or (remat == "auto" and _needs_remat(cfg))
            step = make_train_step(cfg, remat=use_remat, optimizer=optimizer)
            state = S.abstract_train_state(cfg, optimizer=optimizer)
            batch = S.batch_specs(cfg, shape_name, with_labels=True)
            in_sh = (
                state_shardings(mesh, state, fsdp=fsdp),
                jax.tree.map(lambda l: batch_spec(mesh, l), batch),
            )
            out_sh = (in_sh[0], NamedSharding(mesh, P()))
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = fn.lower(state, batch)
        elif kind == "prefill":
            step = make_prefill_step(cfg)
            params = S.abstract_params(cfg)
            batch = S.batch_specs(cfg, shape_name, with_labels=False)
            psh = param_shardings(mesh, params, fsdp=fsdp)
            in_sh = (psh, jax.tree.map(lambda l: batch_spec(mesh, l), batch))
            cache_abs = jax.eval_shape(lambda p, b: step(p, b)[1], params, batch)
            out_sh = (batch_spec(mesh, 2), cache_shardings(mesh, cache_abs))
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = fn.lower(params, batch)
        elif kind == "decode":
            step = make_decode_step(cfg)
            params = S.abstract_params(cfg)
            token, pos, cache = S.decode_specs(cfg, shape_name)
            psh = param_shardings(mesh, params, fsdp=fsdp)
            csh = cache_shardings(mesh, cache)
            logits_abs = jax.eval_shape(step, params, token, pos, cache)[0]
            in_sh = (psh, batch_spec(mesh, token), batch_spec(mesh, pos), csh)
            out_sh = (batch_spec(mesh, logits_abs), csh)
            fn = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh)
            lowered = fn.lower(params, token, pos, cache)
        else:
            raise ValueError(kind)

        t0 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t0

    n_chips = int(np.prod(list(mesh.shape.values())))
    # scan-aware per-device cost from the post-SPMD HLO (see hlocost.py); the
    # builtin cost_analysis under-counts while bodies and is kept for reference
    hc = hlocost.analyze(compiled.as_text())
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    if kind == "train":
        mf = model_flops(cfg, S.abstract_train_state(cfg, optimizer=optimizer), shape_name)
    else:
        mf = model_flops(cfg, S.abstract_params(cfg), shape_name)
    hlo_flops_global = hc["flops"] * n_chips
    info = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": kind,
        "n_chips": n_chips,
        "compile_s": round(compile_s, 1),
        "per_device": {
            "hlo_gflops": hc["flops"] / 1e9,
            "hbm_gbytes": hc["bytes"] / 1e9,
            "collective_gbytes": hc["collectives"]["total"] / 1e9,
            "collectives": {k: v / 1e9 for k, v in hc["collectives"].items()},
        },
        "xla_cost_analysis_raw": {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        },
        "model_gflops_global": mf / 1e9,
        "useful_flops_ratio": mf / max(hlo_flops_global, 1.0),
        "per_device_bytes": {
            "argument": getattr(mem, "argument_size_in_bytes", 0),
            "output": getattr(mem, "output_size_in_bytes", 0),
            "temp": getattr(mem, "temp_size_in_bytes", 0),
            "peak": getattr(mem, "peak_memory_in_bytes", 0),
        },
        "roofline": roofline(hc["flops"], hc["bytes"], hc["collectives"]["total"]),
    }
    return compiled, info


def _needs_remat(cfg) -> bool:
    # large dense/moe models at 4k x 256 need activation checkpointing to fit;
    # enc-dec runs two stacks (encoder residuals + cross-attention), so always
    return cfg.enc_dec or cfg.d_model * cfg.n_layers >= 2048 * 28


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--remat", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = all_archs() if args.all or args.arch is None else [args.arch]
    shapes = list(SHAPES) if args.all or args.shape is None else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
                try:
                    compiled, info = lower_cell(
                        arch, shape, multi_pod=mp, fsdp=not args.no_fsdp, remat=args.remat
                    )
                except Exception as e:  # noqa: BLE001 -- report, don't abort the sweep
                    info = {"arch": arch, "shape": shape,
                            "mesh": "2x16x16" if mp else "16x16", "error": repr(e)[:500]}
                    print(f"[FAIL] {tag}: {info['error']}", flush=True)
                    results.append(info)
                    continue
                if compiled is None:
                    print(f"[SKIP] {tag}: {info['skipped']}", flush=True)
                else:
                    r = info["roofline"]
                    print(
                        f"[OK]   {tag}: compile={info['compile_s']}s "
                        f"compute={r['compute_s']:.4f}s memory={r['memory_s']:.4f}s "
                        f"collective={r['collective_s']:.4f}s -> {r['bottleneck']} "
                        f"peak/device={info['per_device_bytes']['peak']/2**30:.2f}GiB",
                        flush=True,
                    )
                results.append(info)
                del compiled

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {args.out}")
    failed = [r for r in results if "error" in r]
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
