"""ODE serving launcher: drive a SolveService with a synthetic request stream.

    PYTHONPATH=src python -m repro.launch.serve_ode \
        --requests 256 --max-batch 16 --features 2 4 --eval-points 0 8 \
        --method dopri5 --prewarm --max-inflight 4

Simulates the serving workload the batcher exists for -- a stream of
single-instance solve requests with mixed feature sizes, eval grids, spans
and tolerances -- and reports the service's stats surface (throughput, pad
waste, queue/pack/device time split, in-flight window, bucket/cache
behaviour).  Batches launch asynchronously and round-robin across every
visible device; ``--sync`` (or ``--max-inflight 0``) restores the blocking
pre-async service for comparison.  This is the operational smoke tool; the
apples-to-apples comparison against per-request dispatch lives in
``benchmarks/serving_bench.py``.
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.core import SolveRequest, SolveService


def _decay(t, y, args):
    return -y * args


def build_stream(opts, rng) -> list[SolveRequest]:
    reqs = []
    for _ in range(opts.requests):
        feat = int(rng.choice(opts.features))
        n_eval = int(rng.choice(opts.eval_points))
        reqs.append(SolveRequest(
            f=_decay,
            y0=jnp.asarray(rng.uniform(0.5, 1.5, (feat,)), jnp.float32),
            t0=0.0,
            t1=float(rng.uniform(0.5, 1.5)),
            t_eval=np.linspace(0.0, 0.5, n_eval) if n_eval else None,
            args=jnp.asarray(np.full((feat,), rng.uniform(0.5, 2.0), np.float32)),
            rtol=float(rng.choice([1e-3, 1e-4, 1e-5])),
            method=opts.method,
        ))
    return reqs


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--requests", type=int, default=256)
    parser.add_argument("--max-batch", type=int, default=16)
    parser.add_argument("--deadline-ms", type=float, default=2.0)
    parser.add_argument("--features", type=int, nargs="+", default=[2, 4],
                        help="feature sizes to mix in the stream")
    parser.add_argument("--eval-points", type=int, nargs="+", default=[0, 8],
                        help="eval-grid lengths to mix (0 = final state only)")
    parser.add_argument("--method", default="dopri5")
    parser.add_argument("--prewarm", action="store_true",
                        help="AOT-compile every batch class before the stream")
    parser.add_argument("--max-inflight", type=int, default=4,
                        help="launched-but-unharvested batch window "
                             "(0 = blocking service)")
    parser.add_argument("--sync", action="store_true",
                        help="shorthand for --max-inflight 0")
    parser.add_argument("--seed", type=int, default=0)
    opts = parser.parse_args()

    svc = SolveService(max_batch=opts.max_batch,
                       max_delay=opts.deadline_ms / 1e3,
                       max_inflight=0 if opts.sync else opts.max_inflight)
    print(f"serving on {len(svc.devices)} device(s), "
          f"max_inflight={svc.max_inflight}")
    rng = np.random.default_rng(opts.seed)
    stream = build_stream(opts, rng)

    if opts.prewarm:
        t0 = time.perf_counter()
        n = sum(svc.prewarm(r) for r in stream[: 4 * len(opts.features)])
        print(f"prewarm: {n} programs in {time.perf_counter() - t0:.2f}s")

    t0 = time.perf_counter()
    futures = [svc.submit(r) for r in stream]
    svc.flush()
    svc.drain()
    sols = [f.result() for f in futures]
    wall = time.perf_counter() - t0

    ok = sum(bool(s.success.all()) for s in sols)
    print(f"served {len(sols)} requests in {wall:.3f}s "
          f"({len(sols) / wall:.1f} req/s end-to-end), {ok} fully successful")
    for name, value in svc.stats().items():
        print(f"  {name:>24}: {value:.4g}" if isinstance(value, float)
              else f"  {name:>24}: {value}")


if __name__ == "__main__":
    main()
