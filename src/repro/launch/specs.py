"""Abstract input specs (ShapeDtypeStruct stand-ins) for every (arch x shape)
cell -- weak-type-correct, shardable, zero device allocation."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import init_cache, init_params
from ..models.config import SHAPES, ArchConfig


def abstract_train_state(cfg: ArchConfig, optimizer: str = "adamw"):
    def build():
        from ..train.steps import init_train_state

        return init_train_state(cfg, jax.random.PRNGKey(0), optimizer=optimizer)

    return jax.eval_shape(build)


def abstract_params(cfg: ArchConfig):
    return jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape_name: str, *, with_labels: bool):
    """Token/label/frontend-embedding specs for full-sequence steps."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    batch = {"tokens": sds((b, s), jnp.int32)}
    if with_labels:
        batch["labels"] = sds((b, s), jnp.int32)
    if cfg.n_img_tokens > 0:
        batch["img_embeds"] = sds((b, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.enc_dec:
        # mechanical: encoder frame count mirrors the assigned seq length
        batch["audio_embeds"] = sds((b, s, cfg.d_model), jnp.dtype(cfg.dtype))
    return batch


def decode_specs(cfg: ArchConfig, shape_name: str, *, enc_len: int = 1500):
    """(token, pos, cache) specs for one-token decode with a seq_len cache."""
    sh = SHAPES[shape_name]
    b, s = sh["global_batch"], sh["seq_len"]
    cache = jax.eval_shape(
        lambda: init_cache(cfg, b, s, enc_len=enc_len if cfg.enc_dec else None)
    )
    return sds((b,), jnp.int32), sds((b,), jnp.int32), cache


def cell_runnable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """Shape-cell applicability per the assignment rules."""
    if shape_name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k needs sub-quadratic attention; skipped for full-attention arch"
    return True, ""
