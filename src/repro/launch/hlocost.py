"""Scan-aware cost analysis of compiled (post-SPMD) HLO text.

XLA's built-in ``compiled.cost_analysis()`` visits every computation ONCE, so
anything inside a ``while`` loop (every ``lax.scan`` -- our layer stacks, flash
attention chunk loops) is under-counted by its trip count.  This module parses
the scheduled HLO text, recovers static trip counts from loop conditions, and
propagates execution counts through the call graph, yielding:

  - flops:        2 * prod(result_dims) * prod(contracting_dims) per dot,
                  weighted by execution count
  - bytes:        operand+result bytes of top-level (fusion-boundary) ops,
                  approximating HBM traffic, weighted by execution count
  - collectives:  bytes moved per collective kind, weighted by execution count
                  (convention: max array on the instruction line)

Validated in tests/test_hlocost.py against analytically known programs.
"""

from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_ARRAY_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|pred|c64|c128)\[([0-9,]*)\]")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")

# op kinds whose operands/results cross the HBM boundary (roughly: anything
# that is a scheduled thunk, i.e. not free metadata ops)
_TRAFFIC_KINDS = {
    "fusion", "dot", "convolution", "copy", "dynamic-slice", "dynamic-update-slice",
    "scatter", "gather", "sort", "reduce", "transpose", "broadcast", "concatenate",
    "convert", "custom-call", "reduce-window", "select-and-scatter", "pad", "reverse",
    "slice", "iota", "rng", "rng-bit-generator", "exp", "add", "multiply", "tanh",
    "cholesky", "triangular-solve", "reshape",
}
_NO_TRAFFIC = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
               "while", "conditional", "call", "after-all", "add-dependency",
               "partition-id", "replica-id", "domain", "opt-barrier"}


def _array_bytes(type_str: str) -> int:
    total = 0
    for t, dims in _ARRAY_RE.findall(type_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[t]
    return total


def _array_dims(type_str: str):
    """dims of the FIRST array in a type string, or None."""
    m = _ARRAY_RE.search(type_str)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    kind: str
    operands: list
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: list
    params: dict  # param name -> type str
    fused: bool = False  # body of a fusion op (not a scheduling boundary)


_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)\)\s*->\s*(.*?)\s*\{\s*$")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\)|\S+))\s+([\w\-]+)\((.*)$")


def parse_module(text: str):
    comps: dict[str, Computation] = {}
    entry = None
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        m = _COMP_HDR.match(line.strip())
        if m and (cur is None):
            is_entry, name, params_str, _ret = m.groups()
            params = {}
            for p in re.split(r",\s*(?![^\[]*\])", params_str):
                p = p.strip()
                if not p:
                    continue
                pm = re.match(r"([\w.\-]+)\s*:\s*(.+)", p)
                if pm:
                    params[pm.group(1)] = pm.group(2)
            cur = Computation(name=name, ops=[], params=params)
            if is_entry:
                entry = name
            continue
        if cur is not None:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            om = _OP_RE.match(line)
            if om:
                name, type_str, kind, rest = om.groups()
                # operand names: %foo references before the closing paren
                depth = 1
                args = []
                buf = ""
                for ch in rest:
                    if ch == "(":
                        depth += 1
                    elif ch == ")":
                        depth -= 1
                        if depth == 0:
                            args.append(buf)
                            buf = ""
                            break
                    if depth >= 1 and not (ch == "(" and depth == 2 and False):
                        buf += ch
                operand_names = re.findall(r"%([\w.\-]+)", args[0] if args else "")
                cur.ops.append(Op(name=name, type_str=type_str, kind=kind,
                                  operands=operand_names, line=line.strip()))
    return comps, entry


def _mark_fused(comps):
    for c in comps.values():
        for op in c.ops:
            if op.kind == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.line)
                if m and m.group(1) in comps:
                    comps[m.group(1)].fused = True
        # wrapped_* computations are always fusion bodies on CPU
    return comps


def _trip_count(comps, cond_name: str) -> int:
    """Largest s32 constant in the condition computation (scan trip count)."""
    best = None
    c = comps.get(cond_name)
    if c is None:
        return 1
    names = [cond_name]
    # include computations the condition fuses into
    for op in c.ops:
        m = re.search(r"calls=%?([\w.\-]+)", op.line)
        if m:
            names.append(m.group(1))
    for n in names:
        cc = comps.get(n)
        if cc is None:
            continue
        for op in cc.ops:
            if op.kind == "constant" and op.type_str.startswith("s32"):
                m = re.search(r"constant\((-?\d+)\)", op.line)
                if m:
                    v = int(m.group(1))
                    if best is None or v > best:
                        best = v
    return best if best and best > 0 else 1


def _call_edges(comps):
    """caller -> [(callee, multiplier per caller execution)]."""
    edges = defaultdict(list)
    for name, c in comps.items():
        for op in c.ops:
            if op.kind == "while":
                mb = re.search(r"body=%?([\w.\-]+)", op.line)
                mc = re.search(r"condition=%?([\w.\-]+)", op.line)
                if mb and mc:
                    t = _trip_count(comps, mc.group(1))
                    edges[name].append((mb.group(1), float(t)))
                    edges[name].append((mc.group(1), float(t + 1)))
            elif op.kind == "conditional":
                for m in re.finditer(
                    r"(?:true_computation|false_computation)=%?([\w.\-]+)", op.line
                ):
                    edges[name].append((m.group(1), 1.0))
                m = re.search(r"branch_computations=\{([^}]*)\}", op.line)
                if m:
                    for b in re.findall(r"%?([\w.\-]+)", m.group(1)):
                        edges[name].append((b, 1.0))
            else:
                for m in re.finditer(r"(?:calls|to_apply)=%?([\w.\-]+)", op.line):
                    edges[name].append((m.group(1), 1.0))
    return edges


def exec_counts(comps, entry: str):
    """Execution count per computation: topological propagation over the
    (acyclic) HLO call graph, with while bodies weighted by trip count."""
    edges = _call_edges(comps)
    order = []
    seen = set()

    def dfs(n):
        if n in seen or n not in comps:
            return
        seen.add(n)
        for callee, _ in edges.get(n, ()):
            dfs(callee)
        order.append(n)

    dfs(entry)
    counts = defaultdict(float)
    counts[entry] = 1.0
    for n in reversed(order):  # callers before callees
        for callee, k in edges.get(n, ()):
            counts[callee] += counts[n] * k
    return counts


def _dot_flops(comps, comp, op) -> float:
    res_dims = _array_dims(op.type_str) or []
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    lhs_c = [int(x) for x in m.group(1).split(",") if x] if m else []
    lhs_type = _resolve_operand_type(comps, comp, op, 0)
    lhs_dims = _array_dims(lhs_type or "") or []
    contract = 1
    for d in lhs_c:
        if d < len(lhs_dims):
            contract *= lhs_dims[d]
    out = 1
    for d in res_dims:
        out *= d
    return 2.0 * out * contract


def _resolve_operand_type(comps, comp, op, idx) -> str | None:
    if idx >= len(op.operands):
        return None
    target = op.operands[idx]
    for o in comp.ops:
        if o.name == target:
            return o.type_str
    if target in comp.params:
        return comp.params[target]
    return None


def analyze(text: str) -> dict:
    comps, entry = parse_module(text)
    _mark_fused(comps)
    counts = exec_counts(comps, entry)

    flops = 0.0
    bytes_hbm = 0.0
    coll = {k: 0.0 for k in COLLECTIVES}
    for name, comp in comps.items():
        mult = counts.get(name, 0.0)
        if mult == 0:
            continue
        for op in comp.ops:
            if op.kind == "dot":
                flops += mult * _dot_flops(comps, comp, op)
            base_kind = re.sub(r"-(start|done)$", "", op.kind)
            if base_kind in COLLECTIVES and not op.kind.endswith("-done"):
                coll[base_kind] += mult * max(
                    (_array_bytes(t) for t in _operand_and_result_types(comps, comp, op)),
                    default=0,
                )
            if not comp.fused and op.kind not in _NO_TRAFFIC:
                bytes_hbm += mult * _op_traffic(comps, comp, op)
    coll["total"] = sum(coll[k] for k in COLLECTIVES)
    return {"flops": flops, "bytes": bytes_hbm, "collectives": coll}


def _fusion_callee(comps, op):
    m = re.search(r"calls=%?([\w.\-]+)", op.line)
    return comps.get(m.group(1)) if m else None


def _fusion_root_bytes(callee) -> float | None:
    """Output bytes of a fusion, honoring in-place dynamic-update-slice roots:
    a scan accumulator fusion writes only its update slice, not the buffer."""
    root = next((o for o in callee.ops if o.line.startswith("ROOT")), None)
    if root is None:
        return None

    def op_out_bytes(o):
        if o.kind == "dynamic-update-slice":
            # bytes written = the update (operand 1)
            for cand in callee.ops:
                if cand.name == (o.operands[1] if len(o.operands) > 1 else ""):
                    return _array_bytes(cand.type_str)
            t = callee.params.get(o.operands[1]) if len(o.operands) > 1 else None
            return _array_bytes(t) if t else _array_bytes(o.type_str)
        return _array_bytes(o.type_str)

    if root.kind == "tuple":
        total = 0.0
        for nm in root.operands:
            defn = next((o for o in callee.ops if o.name == nm), None)
            if defn is not None:
                total += op_out_bytes(defn)
            elif nm in callee.params:
                total += 0.0  # pass-through of an input: no new write
        return total
    return op_out_bytes(root)


def _fusion_operand_bytes(callee, param_idx, full_bytes) -> float:
    """Input bytes of fusion operand ``param_idx``: if the parameter is only
    consumed via dynamic-slice (scan reading one layer's weights) or is only
    the destination of in-place dynamic-update-slice, charge the slice."""
    pnames = list(callee.params)
    if param_idx >= len(pnames):
        return full_bytes
    pname = pnames[param_idx]
    uses = [o for o in callee.ops if pname in o.operands]
    if not uses:
        return 0.0
    total = 0.0
    for o in uses:
        if o.kind == "dynamic-slice":
            total += _array_bytes(o.type_str)
        elif o.kind == "dynamic-update-slice" and o.operands and o.operands[0] == pname:
            total += 0.0  # aliased in-place destination: no read of the buffer
        elif o.kind in ("get-tuple-element", "bitcast", "tuple"):
            total += 0.0
        else:
            return full_bytes  # generic use: charge the full operand once
    return total


def _op_traffic(comps, comp, op) -> float:
    """Approximate HBM bytes moved by one execution of a scheduled op.

    Slicing/updating ops only touch the slice, NOT the full operand -- charging
    the whole operand would overcount scan parameter slicing by the trip count.
    """
    res = _array_bytes(op.type_str)
    if op.kind in ("dynamic-slice", "slice", "gather", "broadcast", "iota", "rng",
                   "rng-bit-generator"):
        return 2.0 * res
    if op.kind in ("dynamic-update-slice", "scatter"):
        # read+write of the updated window (operand 1 is the update)
        t = _resolve_operand_type(comps, comp, op, 1)
        upd = _array_bytes(t) if t else res
        return 2.0 * upd
    if op.kind in ("transpose", "copy", "convert", "reshape", "pad", "reverse",
                   "concatenate"):
        return 2.0 * res
    if op.kind == "fusion":
        callee = _fusion_callee(comps, op)
        if callee is not None:
            out_b = _fusion_root_bytes(callee)
            sz = float(out_b if out_b is not None else res)
            for i in range(len(op.operands)):
                t = _resolve_operand_type(comps, comp, op, i)
                if t:
                    sz += _fusion_operand_bytes(callee, i, _array_bytes(t))
            return sz
    sz = float(res)
    for i in range(len(op.operands)):
        t = _resolve_operand_type(comps, comp, op, i)
        if t:
            sz += _array_bytes(t)
    return sz


def _operand_and_result_types(comps, comp, op):
    types = [op.type_str]
    for i in range(len(op.operands)):
        t = _resolve_operand_type(comps, comp, op, i)
        if t:
            types.append(t)
    return types
