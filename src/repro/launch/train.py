"""End-to-end training launcher (runnable on CPU with reduced configs;
identical code path drives the production mesh on TPU).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b --reduced \
        --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Features exercised: sharded train step, activation-sharding constraints,
deterministic resumable data, async atomic checkpointing, watchdog + restart
supervision, optional per-period remat and continuous-depth (ODE) mode.
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax

from ..checkpoint import CheckpointManager, latest_step, restore
from ..configs import get_config
from ..data import SyntheticTokens
from ..distributed.constraints import activation_sharding
from ..distributed.sharding import batch_spec, state_shardings
from ..launch.fault_tolerance import RestartPolicy, Watchdog
from ..launch.mesh import make_local_mesh
from ..optim.adamw import AdamWConfig
from ..train.steps import init_train_state, make_train_step


def run(args) -> dict:
    cfg = get_config(args.arch, reduced=args.reduced)
    if args.ode_depth:
        cfg = dataclasses.replace(cfg, ode_depth=True, n_layers=len(cfg.pattern))

    mesh = make_local_mesh(model=args.model_parallel)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)
    step_fn = make_train_step(cfg, opt_cfg, remat=args.remat)

    ds = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    mgr = CheckpointManager(args.ckpt_dir, keep=2) if args.ckpt_dir else None

    with mesh, activation_sharding(dp=("data",), tp="model", tp_size=mesh.shape["model"], mesh=mesh):
        state = init_train_state(cfg, jax.random.PRNGKey(args.seed))
        sh = state_shardings(mesh, jax.eval_shape(lambda: state), fsdp=args.fsdp)
        state = jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh)

        start = 0
        if args.ckpt_dir and (ls := latest_step(args.ckpt_dir)) is not None:
            state = restore(args.ckpt_dir, ls, state, shardings=sh)
            start = ls + 1
            print(f"[train] resumed from step {ls}")

        jstep = jax.jit(
            step_fn,
            in_shardings=(sh, {"tokens": batch_spec(mesh, 2), "labels": batch_spec(mesh, 2)}),
            out_shardings=(sh, None),
            donate_argnums=(0,),
        )
        wd = Watchdog(timeout_s=args.step_timeout)

        losses = []
        t0 = time.time()
        for step in range(start, args.steps):
            batch = jax.tree.map(jax.numpy.asarray, ds.batch(step))
            state, metrics = wd.run(jstep, state, batch)
            losses.append(float(metrics["loss"]))
            if step % args.log_every == 0:
                print(
                    f"[train] step={step} loss={losses[-1]:.4f} "
                    f"gn={float(metrics['grad_norm']):.3f} lr={float(metrics['lr']):.2e}",
                    flush=True,
                )
            if mgr and step % args.ckpt_every == 0 and step > 0:
                mgr.save_async(step, state)
        dt = time.time() - t0
        if mgr:
            mgr.save_async(args.steps - 1, state)
            mgr.wait()
            mgr.close()
    return {"losses": losses, "wall_s": dt, "start": start}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-3b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ode-depth", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--step-timeout", type=float, default=600.0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--max-restarts", type=int, default=2)
    args = ap.parse_args(argv)

    policy = RestartPolicy(max_restarts=args.max_restarts)
    out = policy.supervise(lambda: run(args))
    print(f"[train] done: first loss {out['losses'][:1]} last loss {out['losses'][-1:]} "
          f"wall {out['wall_s']:.1f}s")
    return out


if __name__ == "__main__":
    main()
