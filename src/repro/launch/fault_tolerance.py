"""Fault tolerance & elasticity for the training launcher.

On a synchronous SPMD TPU fleet the failure model is simple and brutal: any
chip failure kills the whole step.  The production recipe (what this module
implements at its scale):

1. Frequent async checkpoints (checkpoint/store.py) -- atomic, resharding
   restores, bounded queue.
2. A step WATCHDOG: every train step must complete within ``timeout_s``;
   a straggling/hung step (common symptom of a failing host) raises, the
   supervisor restarts from the latest checkpoint.  On real fleets the restart
   re-provisions a spare node; here the restart path is exercised in-process.
3. ELASTIC RESCALE: restore() accepts a different mesh -- checkpoints store
   global arrays, so a job can restart on fewer/more pods (the dry-run's 16x16
   vs 2x16x16 meshes restore from the same checkpoint).
4. Data determinism: the pipeline is a pure function of (seed, step), so a
   restart replays no data and skips none.

At 1000+ nodes the same design holds with per-node local-SSD checkpoint
striping and a cluster supervisor; the interfaces here are deliberately those.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable


class StepTimeout(RuntimeError):
    pass


@dataclasses.dataclass
class Watchdog:
    """Wall-clock watchdog around blocking step calls (SIGALRM-based)."""

    timeout_s: float = 300.0

    def run(self, fn: Callable, *args):
        def _handler(signum, frame):
            raise StepTimeout(f"step exceeded {self.timeout_s}s (straggler/hang)")

        old = signal.signal(signal.SIGALRM, _handler)
        signal.setitimer(signal.ITIMER_REAL, self.timeout_s)
        try:
            out = fn(*args)
            # block until results are on host: a hung collective surfaces here
            import jax

            jax.block_until_ready(out)
            return out
        finally:
            signal.setitimer(signal.ITIMER_REAL, 0)
            signal.signal(signal.SIGALRM, old)


@dataclasses.dataclass
class RestartPolicy:
    max_restarts: int = 3
    backoff_s: float = 1.0

    def supervise(self, make_and_run: Callable[[], None]):
        """Run ``make_and_run`` (which restores from the latest checkpoint on
        entry) and restart it on failure up to ``max_restarts`` times."""
        attempts = 0
        while True:
            try:
                return make_and_run()
            except (StepTimeout, RuntimeError) as e:  # noqa: PERF203
                attempts += 1
                if attempts > self.max_restarts:
                    raise
                print(f"[fault-tolerance] restart {attempts} after: {e}")
                time.sleep(self.backoff_s * attempts)
