"""Batched serving launcher: continuous-batch decode loop on a sharded mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-14b --reduced \
        --batch 4 --prompt-len 16 --gen 32

Implements the production decode loop shape: one jit'd prefill (builds the KV
cache for a batch of prompts), then a jit'd per-token decode step with
donated cache buffers; per-sequence positions support ragged prompt lengths.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config
from ..distributed.constraints import activation_sharding
from ..distributed.sharding import batch_spec, cache_shardings, param_shardings
from ..launch.mesh import make_local_mesh
from ..models import init_params, pad_cache, prefill
from ..models.frontends import fake_audio_embeds, fake_img_embeds
from ..train.steps import make_decode_step


def run(args):
    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_local_mesh(model=args.model_parallel)
    key = jax.random.PRNGKey(args.seed)

    with mesh, activation_sharding(dp=("data",), tp="model", tp_size=mesh.shape["model"], mesh=mesh):
        params = init_params(cfg, key)
        psh = param_shardings(mesh, jax.eval_shape(lambda: params), fsdp=False)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh)

        b, plen, gen = args.batch, args.prompt_len, args.gen
        prompts = jax.random.randint(key, (b, plen), 0, cfg.vocab)
        batch = {"tokens": prompts}
        if cfg.n_img_tokens:
            batch["img_embeds"] = fake_img_embeds(cfg, b)
        if cfg.enc_dec:
            batch["audio_embeds"] = fake_audio_embeds(cfg, b, plen)

        t0 = time.time()
        logits, cache = jax.jit(lambda p, bt: prefill(cfg, p, bt))(params, batch)
        cache = pad_cache(cfg, cache, plen + gen)
        csh = cache_shardings(mesh, jax.eval_shape(lambda: cache))
        cache = jax.tree.map(lambda x, s: jax.device_put(x, s), cache, csh)
        t_prefill = time.time() - t0

        decode = jax.jit(
            make_decode_step(cfg),
            in_shardings=(psh, batch_spec(mesh, 1), batch_spec(mesh, 1), csh),
            out_shardings=(batch_spec(mesh, 2), csh),
            donate_argnums=(3,),
        )
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens = [tok]
        t0 = time.time()
        for i in range(gen - 1):
            pos = jnp.full((b,), plen + i, jnp.int32)
            logits, cache = decode(params, tok, pos, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        t_decode = time.time() - t0

    gen_tokens = np.stack([np.asarray(t) for t in out_tokens], 1)
    print(f"[serve] prefill {plen} tokens x {b} seqs: {t_prefill*1e3:.1f} ms")
    print(f"[serve] decode {gen-1} steps: {t_decode*1e3:.1f} ms "
          f"({(gen-1)*b/max(t_decode,1e-9):.1f} tok/s)")
    print(f"[serve] sample continuation: {gen_tokens[0, :16].tolist()}")
    return {"prefill_s": t_prefill, "decode_s": t_decode, "tokens": gen_tokens}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args(argv)
    return run(args)


if __name__ == "__main__":
    main()
