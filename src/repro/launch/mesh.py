"""Production mesh construction.

Defined as functions (never module-level constants) so importing this module
never touches jax device state.  The production target is a TPU v5e pod slice:
16x16 = 256 chips per pod ("data" x "model"), and 2 pods = 512 chips for the
multi-pod configuration with a leading "pod" axis (outer data parallelism /
FSDP axis; gradients reduce over ("pod", "data")).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model: int = 1):
    """Mesh over whatever devices exist (tests / reduced-config runs)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
