"""Pallas TPU flash-attention FORWARD kernel (GQA, causal block skipping).

TPU-native rethinking of the substrate's attention hot spot: the grid
enumerates only the valid (q-block, kv-block) pairs (the same static pair list
as models/attention.py), streaming one q/kv tile pair per program.  TPU grids
execute sequentially, so the online-softmax state (m, l) and the accumulator
are carried ACROSS a q-block's pairs by revisiting the same output blocks --
no scratch management, no recomputation.  MXU-friendly tiles: hd is the lane
dim, kv_chunk the contraction dim.

This is the TPU-target path for serving (prefill); the jnp formulation in
models/attention.py remains the CPU/dry-run path.  Validated with
interpret=True across shapes/dtypes against ref() in tests/test_flash_kernel.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _pairs(nq, nk, qc, kc, causal):
    out = []
    for qi in range(nq):
        for ki in range(nk):
            if causal and ki * kc > (qi + 1) * qc - 1:
                continue
            out.append((qi, ki))
    return out


def _kernel(sched_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *, qc, kc,
            causal, scale):
    # sched_ref: (4, n_pairs) int32 scalar-prefetch block schedule
    p = pl.program_id(0)
    qi = sched_ref[0, p]
    ki = sched_ref[1, p]
    first = sched_ref[2, p] == 1
    last = sched_ref[3, p] == 1

    @pl.when(first)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale  # (qc, hd)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (kc, hd)
    v = v_ref[0, :, 0, :].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (qc, kc)
    if causal:
        q_pos = qi * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
        k_pos = ki * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
        s = jnp.where(k_pos > q_pos, NEG_INF, s)

    m_prev = m_ref[0, :, 0, :]  # (qc, 1)
    l_prev = l_ref[0, :, 0, :]
    acc_prev = o_ref[0, :, 0, :].astype(jnp.float32)  # unnormalized accumulator

    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p_ = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p_, axis=1, keepdims=True)
    acc = acc_prev * corr + jax.lax.dot(p_, v)

    m_ref[0, :, 0, :] = m_new
    l_ref[0, :, 0, :] = l_new

    # write back: normalized on the block's LAST pair, raw accumulator otherwise
    o_ref[0, :, 0, :] = jnp.where(
        last, acc / jnp.maximum(l_new, 1e-30), acc
    ).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal=True, q_chunk=256, kv_chunk=128,
                        interpret=False):
    """q: (b, sq, H, hd); k, v: (b, sk, KV, hd).  Forward only, f32 state.

    Grid: (pairs, b, H).  Returns (b, sq, H, hd) in q.dtype.

    Note: the accumulator is carried in the (f32) output block between a
    q-block's pairs, so internally o is materialized in f32 and cast at the
    end; m/l live in small side outputs.
    """
    b, sq, H, hd = q.shape
    sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    assert sq % qc == 0 and sk % kc == 0, (sq, qc, sk, kc)
    nq, nk = sq // qc, sk // kc

    pairs = _pairs(nq, nk, qc, kc, causal)
    qi_list = tuple(int(p[0]) for p in pairs)
    ki_list = tuple(int(p[1]) for p in pairs)
    first_list = tuple(
        bool(i == 0 or pairs[i][0] != pairs[i - 1][0]) for i in range(len(pairs)))
    last_list = tuple(
        bool(i == len(pairs) - 1 or pairs[i][0] != pairs[i + 1][0])
        for i in range(len(pairs)))

    scale = float(1.0 / np.sqrt(hd))
    kernel = functools.partial(_kernel, qc=qc, kc=kc, causal=causal, scale=scale)
    sched = jnp.asarray(
        np.stack([qi_list, ki_list,
                  np.asarray(first_list, np.int32),
                  np.asarray(last_list, np.int32)]).astype(np.int32))

    grid = (len(pairs), b, H)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, qc, 1, hd), lambda p, bi, h, sc: (bi, sc[0, p], h, 0)),
            pl.BlockSpec((1, kc, 1, hd), lambda p, bi, h, sc: (bi, sc[1, p], h // G, 0)),
            pl.BlockSpec((1, kc, 1, hd), lambda p, bi, h, sc: (bi, sc[1, p], h // G, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, qc, 1, hd), lambda p, bi, h, sc: (bi, sc[0, p], h, 0)),
            pl.BlockSpec((1, qc, 1, 1), lambda p, bi, h, sc: (bi, sc[0, p], h, 0)),
            pl.BlockSpec((1, qc, 1, 1), lambda p, bi, h, sc: (bi, sc[0, p], h, 0)),
        ],
    )
    o32, m, l = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, sq, H, hd), jnp.float32),
            jax.ShapeDtypeStruct((b, sq, H, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, sq, H, 1), jnp.float32),
        ],
        interpret=interpret,
    )(sched, q, k, v)
    return o32.astype(q.dtype)


def ref(q, k, v, *, causal=True):
    """Pure-jnp oracle (quadratic)."""
    b, sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qf = q.astype(jnp.float32).reshape(b, sq, KV, G, hd) / np.sqrt(hd)
    s = jnp.einsum("bqKGh,bkKh->bKGqk", qf, k.astype(jnp.float32))
    if causal:
        qp = jnp.arange(sq)
        kp = jnp.arange(k.shape[1])
        s = jnp.where((kp[None, :] > qp[:, None])[None, None, None], NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bKGqk,bkKh->bKGqh", p, v.astype(jnp.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, H, hd).astype(q.dtype)
