"""Jitted dispatch layer over the solver hot-spot ops.

Backend selection (env var ``REPRO_KERNEL_BACKEND``):
  - ``ref``       pure-jnp oracle (default on CPU -- XLA:CPU fuses these well)
  - ``pallas``    compiled Pallas TPU kernels (default on TPU)
  - ``interpret`` Pallas kernels in interpret mode (CPU correctness validation)

The solver core (``core/stepper.py`` for the stage math, ``core/step.py`` for
the error norm and dense-output interpolation) only ever imports from this
module, so swapping the backend never touches solver logic.
"""

from __future__ import annotations

import os

import jax

from . import ref

_BACKEND = None


def backend() -> str:
    global _BACKEND
    if _BACKEND is None:
        choice = os.environ.get("REPRO_KERNEL_BACKEND", "auto")
        if choice == "auto":
            choice = "pallas" if jax.default_backend() == "tpu" else "ref"
        _BACKEND = choice
    return _BACKEND


_BACKENDS = ("ref", "pallas", "interpret")


def set_backend(name: str) -> None:
    """Override backend (tests use this to exercise interpret mode).  Raises
    ``ValueError`` on unknown names (an ``assert`` would vanish under
    ``python -O`` and silently route every op through a bogus backend)."""
    global _BACKEND
    if name not in _BACKENDS:
        raise ValueError(f"unknown kernel backend {name!r}; expected one of {_BACKENDS}")
    _BACKEND = name


def reset_backend() -> None:
    """Drop the cached backend choice so the next dispatch re-reads
    ``REPRO_KERNEL_BACKEND``.

    ``backend()`` latches its choice on the FIRST op dispatch; before this
    hook existed, setting the env var afterwards was silently ignored --
    processes that configure the environment late (notebooks, test fixtures,
    forked workers inheriting a stale parent choice) got whatever backend the
    first dispatch saw.  Note the JAX compilation cache is keyed on the traced
    program, so already-jitted solver programs keep the backend they were
    traced with; re-trace (new shapes/config) to pick up the change.
    """
    global _BACKEND
    _BACKEND = None


def _impl():
    b = backend()
    if b == "ref":
        return ref
    from . import pallas_impl

    return pallas_impl.interpret_impl() if b == "interpret" else pallas_impl.compiled_impl()


# --- op registry -------------------------------------------------------------
# Every hot-spot op dispatches identically: straight to ``ref`` on the ref
# backend (skipping the pallas_impl import entirely), through ``_impl()``
# otherwise.  The registry loop below stamps out one dispatcher per op name --
# adding a backend op means adding its name here and implementing it in
# ``ref.py`` / ``pallas_impl.py``, with no per-op boilerplate.

_OP_NAMES = (
    "stage_accum",
    "fused_update",
    "error_norm",
    "interp_eval",
    "batched_linsolve",
    "batched_lu_factor",
    "fused_newton_iter",
    "masked_newton_update",
    "masked_bisect_refine",
    "fused_step",
    "fused_step_poly",
    "fused_event_detect",
    "fused_event_commit",
)


def _make_dispatcher(name: str):
    ref_fn = getattr(ref, name)

    def dispatch(*args, **kwargs):
        if backend() == "ref":
            return ref_fn(*args, **kwargs)
        return getattr(_impl(), name)(*args, **kwargs)

    dispatch.__name__ = name
    dispatch.__qualname__ = name
    dispatch.__doc__ = ref_fn.__doc__
    return dispatch


for _name in _OP_NAMES:
    globals()[_name] = _make_dispatcher(_name)
del _name


hermite_coeffs = ref.hermite_coeffs  # pure arithmetic; fused into callers by XLA
rms_norm = ref.rms_norm  # init-time only (step-size selection); never in the hot loop
broadcast_tolerances = ref.broadcast_tolerances  # the shared tolerance-shape contract
pid_update = ref.pid_update  # the ONE controller program (PIDController + fused kernels)
poly_eval = ref.poly_eval  # the ONE polynomial-vf program (PolynomialTerm + megakernel)
