"""Jitted dispatch layer over the solver hot-spot ops.

Backend selection (env var ``REPRO_KERNEL_BACKEND``):
  - ``ref``       pure-jnp oracle (default on CPU -- XLA:CPU fuses these well)
  - ``pallas``    compiled Pallas TPU kernels (default on TPU)
  - ``interpret`` Pallas kernels in interpret mode (CPU correctness validation)

The solver core (``core/stepper.py`` for the stage math, ``core/step.py`` for
the error norm and dense-output interpolation) only ever imports from this
module, so swapping the backend never touches solver logic.
"""

from __future__ import annotations

import os

import jax

from . import ref

_BACKEND = None


def backend() -> str:
    global _BACKEND
    if _BACKEND is None:
        choice = os.environ.get("REPRO_KERNEL_BACKEND", "auto")
        if choice == "auto":
            choice = "pallas" if jax.default_backend() == "tpu" else "ref"
        _BACKEND = choice
    return _BACKEND


def set_backend(name: str) -> None:
    """Override backend (tests use this to exercise interpret mode)."""
    global _BACKEND
    assert name in ("ref", "pallas", "interpret")
    _BACKEND = name


def _impl():
    b = backend()
    if b == "ref":
        return ref
    from . import pallas_impl

    return pallas_impl.interpret_impl() if b == "interpret" else pallas_impl.compiled_impl()


def stage_accum(y, dt, K, coeffs):
    if backend() == "ref":
        return ref.stage_accum(y, dt, K, coeffs)
    return _impl().stage_accum(y, dt, K, coeffs)


def fused_update(y, K, dt, b_sol, b_err):
    if backend() == "ref":
        return ref.fused_update(y, K, dt, b_sol, b_err)
    return _impl().fused_update(y, K, dt, b_sol, b_err)


def error_norm(err, y0, y1, atol, rtol):
    if backend() == "ref":
        return ref.error_norm(err, y0, y1, atol, rtol)
    return _impl().error_norm(err, y0, y1, atol, rtol)


def interp_eval(coeffs, x, mask, out):
    if backend() == "ref":
        return ref.interp_eval(coeffs, x, mask, out)
    return _impl().interp_eval(coeffs, x, mask, out)


def batched_linsolve(A, rhs):
    """Batched dense solve A @ x = rhs: the Newton linear-algebra hot spot."""
    if backend() == "ref":
        return ref.batched_linsolve(A, rhs)
    return _impl().batched_linsolve(A, rhs)


def masked_newton_update(k, delta, active, scale):
    """Fused masked Newton commit + per-instance scaled update norm."""
    if backend() == "ref":
        return ref.masked_newton_update(k, delta, active, scale)
    return _impl().masked_newton_update(k, delta, active, scale)


def masked_bisect_refine(coeffs, lo, hi, v_lo, v_mid, active):
    """One masked bisection step of the event localizer: halve the bracket
    keeping the sign change inside, and evaluate the dense-output interpolant
    at the new midpoint."""
    if backend() == "ref":
        return ref.masked_bisect_refine(coeffs, lo, hi, v_lo, v_mid, active)
    return _impl().masked_bisect_refine(coeffs, lo, hi, v_lo, v_mid, active)


hermite_coeffs = ref.hermite_coeffs  # pure arithmetic; fused into callers by XLA
rms_norm = ref.rms_norm  # init-time only (step-size selection); never in the hot loop
broadcast_tolerances = ref.broadcast_tolerances  # the shared tolerance-shape contract
