"""Pallas TPU kernels for the solver's hot-spot ops.

Three kernels, mirroring the fused PyTorch kernels (einsum/addcmul) that make
torchode fast, re-thought for the TPU memory hierarchy:

  - ``fused_update``: one HBM->VMEM pass over the stage tensor K produces BOTH
    the solution update and the embedded error estimate.  The stage weights are
    compile-time constants (Butcher tableau), so the combination is a fully
    unrolled multiply-add chain on the VPU -- no reduction loop, no second pass.
  - ``stage_accum``: same structure for intermediate stage states.
  - ``error_norm``: the weighted-RMS error norm fused with its scale
    computation; accumulates sum-of-squares across feature tiles in the output
    block (grid is sequential on TPU), finalizing sqrt(mean) on the last tile.
  - ``interp_eval``: masked Horner evaluation of the dense-output cubic into the
    (aliased) output buffer -- torchode's "evaluation tracking" hot spot.
  - ``batched_linsolve``: per-instance dense Gauss-Jordan solve (with partial
    pivoting) for the implicit steppers' Newton systems, one batch tile per
    program with the full matrix resident in VMEM.
  - ``masked_newton_update``: the masked Newton commit fused with the
    per-instance scaled update norm (the inner-iteration analogue of
    ``error_norm``).
  - ``masked_bisect_refine``: one masked bisection step of the event-time
    localizer -- bracket halving fused with the Horner evaluation of the
    dense-output cubic at the new midpoint.

Tiling: (8, 128)-aligned blocks (f32 VREG lane layout); wrappers pad
non-aligned shapes and slice back, so kernels always see divisible shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from . import ref

BB = 8  # batch tile
BF = 128  # feature tile (lane dimension)
BN = 128  # eval-point tile


def _pad_to(x, axis, mult, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _cdiv(a, b):
    return (a + b - 1) // b


# ---------------------------------------------------------------- fused update


def _fused_update_kernel(y_ref, k_ref, dt_ref, y1_ref, err_ref, *, b_sol, b_err):
    y = y_ref[...]
    dt = dt_ref[...]  # (BB, 1)
    acc_sol = jnp.zeros_like(y)
    acc_err = jnp.zeros_like(y)
    for j in range(k_ref.shape[0]):  # unrolled: s is 1..7
        k = k_ref[j]
        if b_sol[j] != 0.0:
            acc_sol = acc_sol + b_sol[j] * k
        if b_err[j] != 0.0:
            acc_err = acc_err + b_err[j] * k
    y1_ref[...] = y + dt * acc_sol
    err_ref[...] = dt * acc_err


def fused_update(y, K, dt, b_sol, b_err, *, interpret=False):
    b_sol = np.asarray(b_sol, dtype=np.float64)
    b_err = np.asarray(b_err, dtype=np.float64)
    b, f = y.shape
    s = K.shape[0]
    yp = _pad_to(_pad_to(y, 0, BB), 1, BF)
    Kp = _pad_to(_pad_to(K, 1, BB), 2, BF)
    dtp = _pad_to(dt[:, None], 0, BB)
    bp, fp = yp.shape
    grid = (bp // BB, fp // BF)
    kernel = functools.partial(
        _fused_update_kernel, b_sol=tuple(b_sol.tolist()), b_err=tuple(b_err.tolist())
    )
    y1, err = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((s, BB, BF), lambda i, j: (0, i, j)),
            pl.BlockSpec((BB, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(yp.shape, y.dtype),
            jax.ShapeDtypeStruct(yp.shape, y.dtype),
        ],
        interpret=interpret,
    )(yp, Kp, dtp)
    return y1[:b, :f], err[:b, :f]


# ---------------------------------------------------------------- stage accum


def _stage_accum_kernel(y_ref, k_ref, dt_ref, out_ref, *, coeffs):
    acc = jnp.zeros_like(y_ref[...])
    for j in range(k_ref.shape[0]):
        if coeffs[j] != 0.0:
            acc = acc + coeffs[j] * k_ref[j]
    out_ref[...] = y_ref[...] + dt_ref[...] * acc


def stage_accum(y, dt, K, coeffs, *, interpret=False):
    coeffs = np.asarray(coeffs, dtype=np.float64)
    b, f = y.shape
    s = K.shape[0]
    yp = _pad_to(_pad_to(y, 0, BB), 1, BF)
    Kp = _pad_to(_pad_to(K, 1, BB), 2, BF)
    dtp = _pad_to(dt[:, None], 0, BB)
    bp, fp = yp.shape
    out = pl.pallas_call(
        functools.partial(_stage_accum_kernel, coeffs=tuple(coeffs.tolist())),
        grid=(bp // BB, fp // BF),
        in_specs=[
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((s, BB, BF), lambda i, j: (0, i, j)),
            pl.BlockSpec((BB, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(yp.shape, y.dtype),
        interpret=interpret,
    )(yp, Kp, dtp)
    return out[:b, :f]


# ----------------------------------------------------------------- error norm


def _error_norm_kernel(err_ref, y0_ref, y1_ref, atol_ref, rtol_ref, out_ref, *, n_feat, nf_tiles):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    scale = atol_ref[...] + rtol_ref[...] * jnp.maximum(
        jnp.abs(y0_ref[...]), jnp.abs(y1_ref[...])
    )
    r = err_ref[...] / scale
    out_ref[...] += jnp.sum(r * r, axis=1, keepdims=True)

    @pl.when(j == nf_tiles - 1)
    def _finalize():
        out_ref[...] = jnp.sqrt(out_ref[...] / n_feat)


def error_norm(err, y0, y1, atol, rtol, *, interpret=False):
    b, f = err.shape
    dtype = err.dtype
    # Tolerances may be scalar, per-instance (b,) or full (b, f) -- same
    # contract as the ref oracle.  Shape is static, so the common scalar/(b,)
    # case keeps streaming cheap (BB, 1) tolerance blocks; only genuine
    # per-feature tolerances pay for full (BB, BF) tiles.
    atol, rtol = ref.broadcast_tolerances(atol, rtol, dtype)
    per_feature = atol.ndim == 2 and atol.shape[1] > 1 or rtol.ndim == 2 and rtol.shape[1] > 1
    if per_feature:
        atol = jnp.broadcast_to(atol, (b, f))
        rtol = jnp.broadcast_to(rtol, (b, f))
        tol_block, tol_index = (BB, BF), (lambda i, j: (i, j))
        atolp = _pad_to(_pad_to(atol, 0, BB, value=1), 1, BF, value=1)
        rtolp = _pad_to(_pad_to(rtol, 0, BB, value=1), 1, BF, value=1)
    else:
        atol = jnp.broadcast_to(atol.reshape((-1, 1)) if atol.ndim else atol, (b, 1))
        rtol = jnp.broadcast_to(rtol.reshape((-1, 1)) if rtol.ndim else rtol, (b, 1))
        tol_block, tol_index = (BB, 1), (lambda i, j: (i, 0))
        atolp = _pad_to(atol, 0, BB, value=1)
        rtolp = _pad_to(rtol, 0, BB, value=1)
    # Padding is exact: padded err entries are 0, padded y entries 1 and padded
    # atol cells 1, so every padded cell contributes 0 / (positive scale) = 0 to
    # the sum of squares; we divide by the TRUE feature count.
    errp = _pad_to(_pad_to(err, 0, BB), 1, BF)
    y0p = _pad_to(_pad_to(y0, 0, BB, value=1), 1, BF, value=1)
    y1p = _pad_to(_pad_to(y1, 0, BB, value=1), 1, BF, value=1)
    bp, fp = errp.shape
    nf_tiles = fp // BF
    out = pl.pallas_call(
        functools.partial(_error_norm_kernel, n_feat=float(f), nf_tiles=nf_tiles),
        grid=(bp // BB, nf_tiles),
        in_specs=[
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec(tol_block, tol_index),
            pl.BlockSpec(tol_block, tol_index),
        ],
        out_specs=pl.BlockSpec((BB, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), dtype),
        interpret=interpret,
    )(errp, y0p, y1p, atolp, rtolp)
    return out[:b, 0]


# ------------------------------------------------------------------ interp


def _interp_kernel(c0_ref, c1_ref, c2_ref, c3_ref, x_ref, m_ref, prev_ref, out_ref):
    x = x_ref[...][:, :, None]  # (BB, BN, 1)
    c0 = c0_ref[...][:, None, :]  # (BB, 1, BF)
    c1 = c1_ref[...][:, None, :]
    c2 = c2_ref[...][:, None, :]
    c3 = c3_ref[...][:, None, :]
    acc = ((c3 * x + c2) * x + c1) * x + c0  # Horner
    out_ref[...] = jnp.where(m_ref[...][:, :, None], acc, prev_ref[...])


def interp_eval(coeffs, x, mask, out, *, interpret=False):
    c0, c1, c2, c3 = coeffs
    b, n = x.shape
    f = c0.shape[1]
    cs = [_pad_to(_pad_to(c, 0, BB), 1, BF) for c in (c0, c1, c2, c3)]
    xp = _pad_to(_pad_to(x, 0, BB), 1, BN)
    mp = _pad_to(_pad_to(mask, 0, BB), 1, BN)
    outp = _pad_to(_pad_to(_pad_to(out, 0, BB), 1, BN), 2, BF)
    bp, np_ = xp.shape
    fp = cs[0].shape[1]
    res = pl.pallas_call(
        _interp_kernel,
        grid=(bp // BB, np_ // BN, fp // BF),
        in_specs=[
            pl.BlockSpec((BB, BF), lambda i, j, k: (i, k)),
            pl.BlockSpec((BB, BF), lambda i, j, k: (i, k)),
            pl.BlockSpec((BB, BF), lambda i, j, k: (i, k)),
            pl.BlockSpec((BB, BF), lambda i, j, k: (i, k)),
            pl.BlockSpec((BB, BN), lambda i, j, k: (i, j)),
            pl.BlockSpec((BB, BN), lambda i, j, k: (i, j)),
            pl.BlockSpec((BB, BN, BF), lambda i, j, k: (i, j, k)),
        ],
        out_specs=pl.BlockSpec((BB, BN, BF), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct(outp.shape, out.dtype),
        interpret=interpret,
    )(*cs, xp, mp, outp)
    return res[:b, :n, :f]


# ------------------------------------------------------ masked bisect refine


def _bisect_refine_kernel(
    c0_ref, c1_ref, c2_ref, c3_ref, lo_ref, hi_ref, vlo_ref, vmid_ref, act_ref,
    lo_out, hi_out, vlo_out, mid_out, y_out,
):
    lo = lo_ref[...]  # (BB, 1)
    hi = hi_ref[...]
    v_lo = vlo_ref[...]
    v_mid = vmid_ref[...]
    active = act_ref[...]
    mid = 0.5 * (lo + hi)
    left = jnp.sign(v_lo) != jnp.sign(v_mid)
    hi_new = jnp.where(active & left, mid, hi)
    lo_new = jnp.where(active & ~left, mid, lo)
    vlo_new = jnp.where(active & ~left, v_mid, v_lo)
    mid_new = 0.5 * (lo_new + hi_new)
    # The (BB, 1) bracket outputs are written once per feature tile; the
    # values do not depend on the feature tile, so the rewrite is idempotent
    # (the TPU grid runs sequentially).
    lo_out[...] = lo_new
    hi_out[...] = hi_new
    vlo_out[...] = vlo_new
    mid_out[...] = mid_new
    x = mid_new  # (BB, 1), broadcasts against the (BB, BF) coefficient tiles
    y_out[...] = ((c3_ref[...] * x + c2_ref[...]) * x + c1_ref[...]) * x + c0_ref[...]


def masked_bisect_refine(coeffs, lo, hi, v_lo, v_mid, active, *, interpret=False):
    c0, c1, c2, c3 = coeffs  # the stepper's dense output is cubic Hermite
    b, f = c0.shape
    cs = [_pad_to(_pad_to(c, 0, BB), 1, BF) for c in (c0, c1, c2, c3)]
    # Padded rows: values 0, active False -> sign(0) == sign(0) keeps the
    # bracket untouched; the padded outputs are sliced away.
    lop = _pad_to(lo[:, None], 0, BB)
    hip = _pad_to(hi[:, None], 0, BB)
    vlop = _pad_to(v_lo[:, None], 0, BB)
    vmidp = _pad_to(v_mid[:, None], 0, BB)
    actp = _pad_to(active[:, None], 0, BB)
    bp, fp = cs[0].shape
    scalar_spec = pl.BlockSpec((BB, 1), lambda i, j: (i, 0))
    tile_spec = pl.BlockSpec((BB, BF), lambda i, j: (i, j))
    lo_n, hi_n, vlo_n, mid_n, y_mid = pl.pallas_call(
        _bisect_refine_kernel,
        grid=(bp // BB, fp // BF),
        in_specs=[tile_spec, tile_spec, tile_spec, tile_spec,
                  scalar_spec, scalar_spec, scalar_spec, scalar_spec, scalar_spec],
        out_specs=[scalar_spec, scalar_spec, scalar_spec, scalar_spec, tile_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), lo.dtype),
            jax.ShapeDtypeStruct((bp, 1), hi.dtype),
            jax.ShapeDtypeStruct((bp, 1), v_lo.dtype),
            jax.ShapeDtypeStruct((bp, 1), lo.dtype),
            jax.ShapeDtypeStruct((bp, fp), c0.dtype),
        ],
        interpret=interpret,
    )(*cs, lop, hip, vlop, vmidp, actp)
    return lo_n[:b, 0], hi_n[:b, 0], vlo_n[:b, 0], mid_n[:b, 0], y_mid[:b, :f]


# ------------------------------------------------------- batched linear solve


def _linsolve_kernel(a_ref, b_ref, x_ref, *, n):
    """Gauss-Jordan with partial pivoting, vectorized over the batch tile.

    One program owns BB instances and their full (R, C) matrices in VMEM
    (R = rows padded to the 8-sublane layout, C = columns padded to the
    128-lane layout -- stiff ODE systems are small, so rows are NOT padded
    to a full lane multiple).  Row selection/swap is done with one-hot masks
    (no dynamic gathers), the pivot search with a max-reduction + first-match
    instead of argmax, so every op vectorizes.  Only the true n columns are
    eliminated: the padded block is an identity that never mixes with real
    rows.
    """
    A = a_ref[...]  # (BB, R, C)
    rhs = b_ref[...]  # (BB, R)
    R = A.shape[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (A.shape[0], R), 1)  # (BB, R)

    def body(i, carry):
        A, rhs = carry
        col = jax.lax.dynamic_slice_in_dim(A, i, 1, axis=2)[..., 0]  # (BB, R)
        mag = jnp.where(rows >= i, jnp.abs(col), -1.0)
        m = jnp.max(mag, axis=1, keepdims=True)
        cand = mag == m
        p = jnp.min(jnp.where(cand, rows, R), axis=1, keepdims=True)  # (BB, 1)
        is_i = rows == i
        is_p = rows == p
        Ai = jnp.sum(jnp.where(is_i[:, :, None], A, 0.0), axis=1)  # (BB, C)
        Ap = jnp.sum(jnp.where(is_p[:, :, None], A, 0.0), axis=1)
        bi = jnp.sum(jnp.where(is_i, rhs, 0.0), axis=1, keepdims=True)  # (BB, 1)
        bp = jnp.sum(jnp.where(is_p, rhs, 0.0), axis=1, keepdims=True)
        # swap rows i <-> p (no-op when p == i: is_i wins and Ap == Ai)
        A = jnp.where(
            is_i[:, :, None], Ap[:, None, :], jnp.where(is_p[:, :, None], Ai[:, None, :], A)
        )
        rhs = jnp.where(is_i, bp, jnp.where(is_p, bi, rhs))
        # normalize the pivot row, eliminate column i from every other row
        piv = jax.lax.dynamic_slice_in_dim(Ap, i, 1, axis=1)  # (BB, 1)
        prow = Ap / piv
        pb = bp / piv
        colnew = jax.lax.dynamic_slice_in_dim(A, i, 1, axis=2)[..., 0]  # (BB, R)
        factor = jnp.where(is_i, 0.0, colnew)
        A = A - factor[:, :, None] * prow[:, None, :]
        rhs = rhs - factor * pb
        A = jnp.where(is_i[:, :, None], prow[:, None, :], A)
        rhs = jnp.where(is_i, pb, rhs)
        return A, rhs

    _, rhs = jax.lax.fori_loop(0, n, body, (A, rhs))
    x_ref[...] = rhs


def batched_linsolve(A, rhs, *, interpret=False):
    b, f = rhs.shape
    # Rows only need the 8-sublane layout; columns are the lane dimension.
    Ap = _pad_to(_pad_to(_pad_to(A, 0, BB), 1, BB), 2, BF)
    bp_, fr, fc = Ap.shape
    # The padded block must stay nonsingular: identity on the padded diagonal.
    pad_eye = (
        (jnp.arange(fr)[:, None] == jnp.arange(fc)[None, :])
        & (jnp.arange(fr)[:, None] >= f)
    ).astype(A.dtype)
    Ap = Ap + pad_eye[None, :, :]
    rhsp = _pad_to(_pad_to(rhs, 0, BB), 1, BB)
    out = pl.pallas_call(
        functools.partial(_linsolve_kernel, n=f),
        grid=(bp_ // BB,),
        in_specs=[
            pl.BlockSpec((BB, fr, fc), lambda i: (i, 0, 0)),
            pl.BlockSpec((BB, fr), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BB, fr), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp_, fr), rhs.dtype),
        interpret=interpret,
    )(Ap, rhsp)
    return out[:b, :f]


# ----------------------------------------------------- batched LU factorization


def _lu_factor_kernel(a_ref, lu_out, perm_out, *, n):
    """Partial-pivoted LU factorization, vectorized over the batch tile.

    Same memory plan as ``_linsolve_kernel`` (one program owns BB instances
    with the full (R, C) matrix in VMEM, one-hot row extraction/swap, pivot
    by max-reduction + first-match), but instead of eliminating a right-hand
    side it stores the factors in place -- the unit-lower multipliers below
    the diagonal, U on and above -- and tracks the row permutation as a
    (BB, R) int32 vector (entry swaps mirror the row swaps).  This runs ONCE
    per implicit solver step; every ``fused_newton_iter`` launch then
    back-substitutes against the stored factors, which is what turns the
    per-iteration O(n^3) elimination into O(n^2) triangular solves.
    """
    A = a_ref[...]  # (BB, R, C)
    bt, R, C = A.shape
    rows = jax.lax.broadcasted_iota(jnp.int32, (bt, R), 1)  # (BB, R)
    row3 = jax.lax.broadcasted_iota(jnp.int32, (bt, R, C), 1)
    col3 = jax.lax.broadcasted_iota(jnp.int32, (bt, R, C), 2)

    def body(i, carry):
        A, perm = carry
        col = jax.lax.dynamic_slice_in_dim(A, i, 1, axis=2)[..., 0]  # (BB, R)
        mag = jnp.where(rows >= i, jnp.abs(col), -1.0)
        m = jnp.max(mag, axis=1, keepdims=True)
        cand = mag == m
        p = jnp.min(jnp.where(cand, rows, R), axis=1, keepdims=True)  # (BB, 1)
        is_i = rows == i
        is_p = rows == p
        Ai = jnp.sum(jnp.where(is_i[:, :, None], A, 0.0), axis=1)  # (BB, C)
        Ap = jnp.sum(jnp.where(is_p[:, :, None], A, 0.0), axis=1)
        # swap rows i <-> p (no-op when p == i: is_i wins and Ap == Ai)
        A = jnp.where(
            is_i[:, :, None], Ap[:, None, :], jnp.where(is_p[:, :, None], Ai[:, None, :], A)
        )
        # dtype pinned: under x64 jnp.sum would promote int32 -> int64 and
        # break the fori_loop carry contract
        pi = jnp.sum(jnp.where(is_i, perm, 0), axis=1, keepdims=True,
                     dtype=jnp.int32)
        pp = jnp.sum(jnp.where(is_p, perm, 0), axis=1, keepdims=True,
                     dtype=jnp.int32)
        perm = jnp.where(is_i, pp, jnp.where(is_p, pi, perm))
        # multipliers below the diagonal; eliminate only the trailing columns
        piv = jax.lax.dynamic_slice_in_dim(Ap, i, 1, axis=1)  # (BB, 1)
        colnew = jax.lax.dynamic_slice_in_dim(A, i, 1, axis=2)[..., 0]
        factor = jnp.where(rows > i, colnew / piv, 0.0)  # (BB, R)
        A = A - jnp.where(col3 > i, factor[:, :, None] * Ap[:, None, :], 0.0)
        # store the multipliers in place of the eliminated column entries
        A = jnp.where((col3 == i) & (row3 > i), factor[:, :, None], A)
        return A, perm

    A, perm = jax.lax.fori_loop(0, n, body, (A, rows))
    lu_out[...] = A
    perm_out[...] = perm


def batched_lu_factor(A, *, interpret=False):
    b, f = A.shape[0], A.shape[1]
    # Same padding plan as ``batched_linsolve``: rows to the 8-sublane
    # layout, columns to the lane dimension, identity on the padded diagonal
    # so the padded block never pivots into the real rows.
    Ap = _pad_to(_pad_to(_pad_to(A, 0, BB), 1, BB), 2, BF)
    bp_, fr, fc = Ap.shape
    pad_eye = (
        (jnp.arange(fr)[:, None] == jnp.arange(fc)[None, :])
        & (jnp.arange(fr)[:, None] >= f)
    ).astype(A.dtype)
    Ap = Ap + pad_eye[None, :, :]
    lu, perm = pl.pallas_call(
        functools.partial(_lu_factor_kernel, n=f),
        grid=(bp_ // BB,),
        in_specs=[pl.BlockSpec((BB, fr, fc), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((BB, fr, fc), lambda i: (i, 0, 0)),
            pl.BlockSpec((BB, fr), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp_, fr, fc), A.dtype),
            jax.ShapeDtypeStruct((bp_, fr), jnp.int32),
        ],
        interpret=interpret,
    )(Ap)
    return lu[:b, :f, :f], perm[:b, :f]


# ----------------------------------------------------------- fused newton iter


def _newton_iter_kernel(
    lu_ref, perm_ref, k_ref, fk_ref, act_ref, scale_ref, k_out, res_out,
    *, n, n_feat,
):
    """One whole chord-Newton iteration against the prefactored LU, as ONE
    program per batch tile: residual, permutation scatter, forward (unit
    lower) and backward (upper) substitution, the masked commit and the
    scaled-RMS convergence norm -- the fusion of ``batched_linsolve`` +
    ``masked_newton_update`` with the elimination already paid for.

    Substitution is COLUMN-oriented: each fori iteration pulls one factor
    column with a lane-axis ``dynamic_slice`` (cheap; the sublane axis never
    needs dynamic indexing) and does O(R) vector work, so a whole triangular
    solve is O(n^2) -- this is what makes the per-iteration launch strictly
    cheaper than the O(n^3) elimination it replaces.  The padded tail never
    mixes in: padded residual entries are 0 and real-row padded-column
    factors are 0.
    """
    LU = lu_ref[...]  # (BB, R, C)
    bt, R, _ = LU.shape
    perm = perm_ref[...]  # (BB, R) int32
    k = k_ref[...]  # (BB, R)
    g = k - fk_ref[...]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bt, R), 1)
    src3 = jax.lax.broadcasted_iota(jnp.int32, (bt, R, R), 2)

    # permutation row-gather: x[r] = g[perm[r]] (one-hot, no dynamic gathers)
    x = jnp.sum(jnp.where(perm[:, :, None] == src3, g[:, None, :], 0.0), axis=2)

    def col_of(j):
        return jax.lax.dynamic_slice_in_dim(LU, j, 1, axis=2)[..., 0]  # (BB, R)

    def at(j, v):  # extract entry j of a (BB, R) vector as (BB, 1)
        return jnp.sum(jnp.where(rows == j, v, 0.0), axis=1, keepdims=True)

    def fwd(j, x):  # unit lower: x[i > j] -= L[i, j] * x[j]
        return jnp.where(rows > j, x - col_of(j) * at(j, x), x)

    x = jax.lax.fori_loop(0, n, fwd, x)

    def bwd(t, x):  # upper: x[j] /= U[j, j]; then x[i < j] -= U[i, j] * x[j]
        j = n - 1 - t
        Ucol = col_of(j)
        xj = at(j, x) / at(j, Ucol)
        return jnp.where(rows == j, xj, jnp.where(rows < j, x - Ucol * xj, x))

    delta = jax.lax.fori_loop(0, n, bwd, x)

    active = act_ref[...]  # (BB, 1) bool
    k_out[...] = jnp.where(active, k - delta, k)
    r = delta / scale_ref[...]
    res_out[...] = jnp.sqrt(jnp.sum(r * r, axis=1, keepdims=True) / n_feat)


def fused_newton_iter(lu, perm, k, fk, active, scale, *, interpret=False):
    b, f = k.shape
    scale = jnp.broadcast_to(jnp.asarray(scale, k.dtype), (b, f))
    lup = _pad_to(_pad_to(_pad_to(lu, 0, BB), 1, BB), 2, BF)
    bp_, fr, fc = lup.shape
    # Re-seat the padded diagonal (the wrapper contract is the sliced true
    # factors) so the backward substitution never divides by a padded zero
    # on real batch rows; padded residual entries are 0 either way.
    pad_eye = (
        (jnp.arange(fr)[:, None] == jnp.arange(fc)[None, :])
        & (jnp.arange(fr)[:, None] >= f)
    ).astype(lu.dtype)
    lup = lup + pad_eye[None, :, :]
    ids = jnp.arange(fr, dtype=perm.dtype)
    permp = _pad_to(_pad_to(perm, 0, BB), 1, BB)
    permp = jnp.where(ids[None, :] >= f, ids[None, :], permp)
    # Padded deltas are 0 and padded scales 1 -> padded cells add 0 to the
    # sum of squares; divide by the TRUE feature count.
    kp = _pad_to(_pad_to(k, 0, BB), 1, BB)
    fkp = _pad_to(_pad_to(fk, 0, BB), 1, BB)
    sp = _pad_to(_pad_to(scale, 0, BB, value=1), 1, BB, value=1)
    ap = _pad_to(active[:, None], 0, BB)
    k_new, res = pl.pallas_call(
        functools.partial(_newton_iter_kernel, n=f, n_feat=float(f)),
        grid=(bp_ // BB,),
        in_specs=[
            pl.BlockSpec((BB, fr, fc), lambda i: (i, 0, 0)),
            pl.BlockSpec((BB, fr), lambda i: (i, 0)),
            pl.BlockSpec((BB, fr), lambda i: (i, 0)),
            pl.BlockSpec((BB, fr), lambda i: (i, 0)),
            pl.BlockSpec((BB, 1), lambda i: (i, 0)),
            pl.BlockSpec((BB, fr), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BB, fr), lambda i: (i, 0)),
            pl.BlockSpec((BB, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp_, fr), k.dtype),
            jax.ShapeDtypeStruct((bp_, 1), k.dtype),
        ],
        interpret=interpret,
    )(lup, permp, kp, fkp, ap, sp)
    return k_new[:b, :f], res[:b, 0]


# --------------------------------------------------------- masked newton update


def _newton_update_kernel(k_ref, d_ref, act_ref, scale_ref, k_out, res_out, *, n_feat, nf_tiles):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        res_out[...] = jnp.zeros_like(res_out)

    k = k_ref[...]
    d = d_ref[...]
    active = act_ref[...]  # (BB, 1) bool
    k_out[...] = jnp.where(active, k - d, k)
    r = d / scale_ref[...]
    res_out[...] += jnp.sum(r * r, axis=1, keepdims=True)

    @pl.when(j == nf_tiles - 1)
    def _finalize():
        res_out[...] = jnp.sqrt(res_out[...] / n_feat)


def masked_newton_update(k, delta, active, scale, *, interpret=False):
    b, f = k.shape
    scale = jnp.broadcast_to(jnp.asarray(scale, k.dtype), (b, f))
    # Padding is exact: padded deltas are 0 and padded scales 1, so padded
    # cells add 0 to the sum of squares; we divide by the TRUE feature count.
    kp = _pad_to(_pad_to(k, 0, BB), 1, BF)
    dp = _pad_to(_pad_to(delta, 0, BB), 1, BF)
    ap = _pad_to(active[:, None], 0, BB)
    sp = _pad_to(_pad_to(scale, 0, BB, value=1), 1, BF, value=1)
    bp_, fp = kp.shape
    nf_tiles = fp // BF
    k_new, res = pl.pallas_call(
        functools.partial(_newton_update_kernel, n_feat=float(f), nf_tiles=nf_tiles),
        grid=(bp_ // BB, nf_tiles),
        in_specs=[
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((BB, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((BB, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(kp.shape, k.dtype),
            jax.ShapeDtypeStruct((bp_, 1), k.dtype),
        ],
        interpret=interpret,
    )(kp, dp, ap, sp)
    return k_new[:b, :f], res[:b, 0]


# -------------------------------------------------------------- fused RK step
#
# The megakernel: one kernel launch per explicit-RK step attempt.  One grid
# program owns a BB-row batch tile with the FULL feature axis resident in
# VMEM ((s + ~8) * BB * fp * 4 bytes -- comfortably inside VMEM for the
# torchode regime f <= ~256 and far beyond), so the cross-feature error-norm
# reduction, the (b,)-shaped controller decision and the (b, f) commits all
# happen in-register without a second pass or a cross-tile accumulator.


def _ctrl_decide(ratio, dt_cur, run, pi1, pi2, *, ctrl, ctrl_mode):
    """The (BB, 1) controller decision of the kernel tail.  ``ctrl_mode``
    selects between the two baked-in programs: ``"pid"`` mirrors
    ``ref.pid_update`` exactly; ``"fixed"`` is the ``FixedController``
    contract -- accept everything running, keep the standing dt proposal,
    pass the error history through.  Note ``new_inv``/``new_inv2`` use the
    UNMASKED accept (the controller's decision), matching the unfused order
    of operations; only the returned ``accept`` carries the ``run`` mask."""
    if ctrl_mode == "fixed":
        return jnp.ones_like(run) & run, dt_cur, pi1, pi2
    b1, b2, b3, safety, factor_min, factor_max, dt_min, dt_max = ctrl
    finite = jnp.isfinite(ratio)
    safe_ratio = jnp.where(finite & (ratio > 0.0), ratio, 1.0)
    inv = 1.0 / safe_ratio
    factor = safety * inv**b1 * pi1**b2 * pi2**b3
    factor = jnp.where(ratio == 0.0, factor_max, factor)
    factor = jnp.where(finite, factor, 0.5)
    factor = jnp.clip(factor, factor_min, factor_max)
    accept = finite & (ratio <= 1.0)
    factor = jnp.where(accept, factor, jnp.minimum(factor, 1.0))
    mag = jnp.clip(jnp.abs(dt_cur) * factor.astype(dt_cur.dtype), dt_min, dt_max)
    dt_next = jnp.sign(dt_cur) * mag
    new_inv = jnp.where(accept, inv, pi1)
    new_inv2 = jnp.where(accept, pi1, pi2)
    return accept & run, dt_next, new_inv, new_inv2


def _ctrl_commit(
    y, y1, err, f0, f1, t, t_new, dt_cur, run, pi1, pi2, atol, rtol, sdt,
    *, ctrl, ctrl_mode, n_feat, failed=None,
):
    """Shared kernel tail: WRMS norm -> controller decision -> masked commit
    -> Hermite coefficients, on one (BB, fp) tile.  Mirrors the ref-oracle
    expressions exactly.  ``failed`` (solver-failure column, implicit steps)
    forces the ratio to inf BEFORE the decision -- so the pid program rejects
    and shrinks dt -- and masks accept afterwards for the fixed program,
    matching ``ref.fused_step``'s order of operations."""
    scale = atol + rtol * jnp.maximum(jnp.abs(y), jnp.abs(y1))
    r = err / scale
    ratio = jnp.sqrt(jnp.sum(r * r, axis=1, keepdims=True) / n_feat)  # (BB, 1)
    if failed is not None:
        ratio = jnp.where(failed, jnp.inf, ratio)

    accept, dt_next, new_inv, new_inv2 = _ctrl_decide(
        ratio, dt_cur, run, pi1, pi2, ctrl=ctrl, ctrl_mode=ctrl_mode
    )
    if failed is not None:
        accept = accept & ~failed
    y_out = jnp.where(accept, y1, y)
    f_out = jnp.where(accept, f1, f0)
    t_out = jnp.where(accept, t_new, t)
    dt_out = jnp.where(run, dt_next, dt_cur)

    c1 = sdt * f0
    c2 = 3.0 * (y1 - y) - sdt * (2.0 * f0 + f1)
    c3 = 2.0 * (y - y1) + sdt * (f0 + f1)
    return ratio, accept, y_out, f_out, t_out, dt_out, new_inv, new_inv2, (c1, c2, c3)


def _stage_combine(y, sdt, ks, b_sol, b_err):
    """b_sol/b_err combination over a list/ref of stage tiles (unrolled)."""
    acc_sol = jnp.zeros_like(y)
    acc_err = jnp.zeros_like(y)
    for j in range(len(b_sol)):  # unrolled: s is 1..7
        k = ks[j]
        if b_sol[j] != 0.0:
            acc_sol = acc_sol + b_sol[j] * k
        if b_err[j] != 0.0:
            acc_err = acc_err + b_err[j] * k
    return y + sdt * acc_sol, sdt * acc_err


def _poly_stages(y, sdt, f0, poly_ref, a, s):
    """The fully unrolled in-kernel stage recursion for polynomial vector
    fields.  Returns ``(ks, vf)``; ``vf`` is reused for the non-FSAL trailing
    evaluation."""

    def vf(yi):  # Horner over the (deg+1, tile) coefficient rows
        acc = jnp.broadcast_to(poly_ref[poly_ref.shape[0] - 1][None, :], yi.shape)
        for d in range(poly_ref.shape[0] - 2, -1, -1):
            acc = acc * yi + poly_ref[d][None, :]
        return acc

    ks = [f0]
    for i in range(1, s):  # fully unrolled stage recursion, zero vf launches
        acc = jnp.zeros_like(y)
        for j in range(i):
            if a[i][j] != 0.0:
                acc = acc + a[i][j] * ks[j]
        ks.append(vf(y + sdt * acc))
    return ks, vf


def _fused_step_kernel(
    y_ref, k_ref, f1_ref, t_ref, tnew_ref, dtc_ref, sdt_ref, run_ref,
    pi1_ref, pi2_ref, atol_ref, rtol_ref, fail_ref,
    y1_out, ratio_out, acc_out, yo_out, fo_out, to_out, dto_out,
    i1_out, i2_out, c1_out, c2_out, c3_out,
    *, b_sol, b_err, ctrl, ctrl_mode, n_feat,
):
    y = y_ref[...]
    sdt = sdt_ref[...]  # (BB, 1)
    y1, err = _stage_combine(y, sdt, k_ref, b_sol, b_err)

    ratio, accept, y_out, f_out, t_out, dt_out, i1, i2, (c1, c2, c3) = _ctrl_commit(
        y, y1, err, k_ref[0], f1_ref[...], t_ref[...], tnew_ref[...], dtc_ref[...],
        run_ref[...], pi1_ref[...], pi2_ref[...], atol_ref[...], rtol_ref[...], sdt,
        ctrl=ctrl, ctrl_mode=ctrl_mode, n_feat=n_feat, failed=fail_ref[...] != 0,
    )
    y1_out[...] = y1
    ratio_out[...] = ratio
    acc_out[...] = accept.astype(jnp.int32)
    yo_out[...] = y_out
    fo_out[...] = f_out
    to_out[...] = t_out
    dto_out[...] = dt_out
    i1_out[...] = i1
    i2_out[...] = i2
    c1_out[...] = c1
    c2_out[...] = c2
    c3_out[...] = c3


def _fused_step_poly_kernel(
    y_ref, f0_ref, poly_ref, t_ref, tnew_ref, dtc_ref, sdt_ref, run_ref,
    pi1_ref, pi2_ref, atol_ref, rtol_ref,
    y1_out, ratio_out, acc_out, yo_out, fo_out, to_out, dto_out,
    i1_out, i2_out, c1_out, c2_out, c3_out,
    *, a, b_sol, b_err, ctrl, ctrl_mode, fsal, n_feat,
):
    y = y_ref[...]
    sdt = sdt_ref[...]

    ks, vf = _poly_stages(y, sdt, f0_ref[...], poly_ref, a, len(b_sol))
    y1, err = _stage_combine(y, sdt, ks, b_sol, b_err)
    # Non-FSAL tableaus: the trailing evaluation f(t + dt, y1) is one more
    # in-kernel Horner pass, not a launch.
    f1 = ks[-1] if fsal else vf(y1)

    ratio, accept, y_out, f_out, t_out, dt_out, i1, i2, (c1, c2, c3) = _ctrl_commit(
        y, y1, err, ks[0], f1, t_ref[...], tnew_ref[...], dtc_ref[...],
        run_ref[...], pi1_ref[...], pi2_ref[...], atol_ref[...], rtol_ref[...], sdt,
        ctrl=ctrl, ctrl_mode=ctrl_mode, n_feat=n_feat,
    )
    y1_out[...] = y1
    ratio_out[...] = ratio
    acc_out[...] = accept.astype(jnp.int32)
    yo_out[...] = y_out
    fo_out[...] = f_out
    to_out[...] = t_out
    dto_out[...] = dt_out
    i1_out[...] = i1
    i2_out[...] = i2
    c1_out[...] = c1
    c2_out[...] = c2
    c3_out[...] = c3


# ------------------------------------------------- feature-tiled schedule
#
# When the padded feature axis exceeds one (BB, BF) tile, the single-pass
# schedule above would stage (s + ~8) full (BB, fp) rows in VMEM -- fine for
# the torchode regime, a VMEM blowup for large f.  The tiled schedule runs
# grid (nb, 2, nf): phase p=0 sweeps the feature tiles accumulating per-tile
# WRMS partial sums into the (BB, 1) ratio output (constant block index, so
# it stays VMEM-resident across the sweep), finalizing the controller
# decision on the last tile; phase p=1 re-sweeps the tiles and writes every
# (BB, BF) tile output under the decided accept mask.  Per-tile state (y1,
# err, stages) is recomputed in phase 1 rather than staged in scratch --
# cheap VPU arithmetic against O(tile) VMEM, so f is unbounded.  Tile
# outputs are written ONLY in phase 1 (the final visit of each block, the
# revisit-safe contract); the (BB, 1) column outputs are written in phase 0
# and persist because their block index never changes within a batch tile.


def _tiled_commit(
    p, k, y, y1, err, f0, f1, sdt,
    t_ref, tnew_ref, dtc_ref, run_ref, pi1_ref, pi2_ref, atol_ref, rtol_ref,
    y1_out, ratio_out, acc_out, yo_out, fo_out, to_out, dto_out,
    i1_out, i2_out, c1_out, c2_out, c3_out,
    *, ctrl, ctrl_mode, n_feat, nf_tiles, fail_ref=None,
):
    """The two-phase tail shared by the tiled megakernels: WRMS partial-sum
    accumulation + controller decision (phase 0), masked tile commits +
    Hermite coefficients (phase 1).  Same expressions as ``_ctrl_commit``,
    split across the two feature sweeps."""

    @pl.when(p == 0)
    def _reduce():
        @pl.when(k == 0)
        def _init():
            ratio_out[...] = jnp.zeros_like(ratio_out)

        scale = atol_ref[...] + rtol_ref[...] * jnp.maximum(jnp.abs(y), jnp.abs(y1))
        r = err / scale
        ratio_out[...] += jnp.sum(r * r, axis=1, keepdims=True)

        @pl.when(k == nf_tiles - 1)
        def _decide():
            ratio = jnp.sqrt(ratio_out[...] / n_feat)  # (BB, 1)
            if fail_ref is not None:  # solver-failure column (implicit steps)
                failed = fail_ref[...] != 0
                ratio = jnp.where(failed, jnp.inf, ratio)
            run = run_ref[...]
            dt_cur = dtc_ref[...]
            accept, dt_next, new_inv, new_inv2 = _ctrl_decide(
                ratio, dt_cur, run, pi1_ref[...], pi2_ref[...],
                ctrl=ctrl, ctrl_mode=ctrl_mode,
            )
            if fail_ref is not None:
                accept = accept & ~failed
            ratio_out[...] = ratio
            acc_out[...] = accept.astype(jnp.int32)
            to_out[...] = jnp.where(accept, tnew_ref[...], t_ref[...])
            dto_out[...] = jnp.where(run, dt_next, dt_cur)
            i1_out[...] = new_inv
            i2_out[...] = new_inv2

    @pl.when(p == 1)
    def _commit():
        accept = acc_out[...] != 0  # decided in phase 0, still resident
        y1_out[...] = y1
        yo_out[...] = jnp.where(accept, y1, y)
        fo_out[...] = jnp.where(accept, f1, f0)
        c1_out[...] = sdt * f0
        c2_out[...] = 3.0 * (y1 - y) - sdt * (2.0 * f0 + f1)
        c3_out[...] = 2.0 * (y - y1) + sdt * (f0 + f1)


def _fused_step_tiled_kernel(
    y_ref, k_ref, f1_ref, t_ref, tnew_ref, dtc_ref, sdt_ref, run_ref,
    pi1_ref, pi2_ref, atol_ref, rtol_ref, fail_ref,
    y1_out, ratio_out, acc_out, yo_out, fo_out, to_out, dto_out,
    i1_out, i2_out, c1_out, c2_out, c3_out,
    *, b_sol, b_err, ctrl, ctrl_mode, n_feat, nf_tiles,
):
    p = pl.program_id(1)
    k = pl.program_id(2)
    y = y_ref[...]  # (BB, BF) tile
    sdt = sdt_ref[...]
    y1, err = _stage_combine(y, sdt, k_ref, b_sol, b_err)
    _tiled_commit(
        p, k, y, y1, err, k_ref[0], f1_ref[...], sdt,
        t_ref, tnew_ref, dtc_ref, run_ref, pi1_ref, pi2_ref, atol_ref, rtol_ref,
        y1_out, ratio_out, acc_out, yo_out, fo_out, to_out, dto_out,
        i1_out, i2_out, c1_out, c2_out, c3_out,
        ctrl=ctrl, ctrl_mode=ctrl_mode, n_feat=n_feat, nf_tiles=nf_tiles,
        fail_ref=fail_ref,
    )


def _fused_step_poly_tiled_kernel(
    y_ref, f0_ref, poly_ref, t_ref, tnew_ref, dtc_ref, sdt_ref, run_ref,
    pi1_ref, pi2_ref, atol_ref, rtol_ref,
    y1_out, ratio_out, acc_out, yo_out, fo_out, to_out, dto_out,
    i1_out, i2_out, c1_out, c2_out, c3_out,
    *, a, b_sol, b_err, ctrl, ctrl_mode, fsal, n_feat, nf_tiles,
):
    p = pl.program_id(1)
    k = pl.program_id(2)
    y = y_ref[...]
    sdt = sdt_ref[...]
    # The polynomial vf is elementwise, so the whole stage recursion is
    # tile-local (recomputed per phase; see the schedule note above).
    ks, vf = _poly_stages(y, sdt, f0_ref[...], poly_ref, a, len(b_sol))
    y1, err = _stage_combine(y, sdt, ks, b_sol, b_err)
    f1 = ks[-1] if fsal else vf(y1)
    _tiled_commit(
        p, k, y, y1, err, ks[0], f1, sdt,
        t_ref, tnew_ref, dtc_ref, run_ref, pi1_ref, pi2_ref, atol_ref, rtol_ref,
        y1_out, ratio_out, acc_out, yo_out, fo_out, to_out, dto_out,
        i1_out, i2_out, c1_out, c2_out, c3_out,
        ctrl=ctrl, ctrl_mode=ctrl_mode, n_feat=n_feat, nf_tiles=nf_tiles,
    )


def _fused_tol_blocks(atol, rtol, b, f, bp, fp, dtype, *, tiled=False):
    """Tolerance blocks for the fused kernels, mirroring ``error_norm``'s
    shape contract: scalar/(b,) stream cheap (BB, 1) blocks, genuine (b, f)
    tolerances pay for full rows (feature tiles under the tiled schedule).
    Padded cells are 1 so padded err cells (always 0) contribute 0/positive
    = 0 to the norm."""
    atol, rtol = ref.broadcast_tolerances(atol, rtol, dtype)
    per_feature = atol.ndim == 2 and atol.shape[1] > 1 or rtol.ndim == 2 and rtol.shape[1] > 1
    if per_feature:
        atolp = _pad_to(_pad_to(jnp.broadcast_to(atol, (b, f)), 0, BB, value=1), 1, BF, value=1)
        rtolp = _pad_to(_pad_to(jnp.broadcast_to(rtol, (b, f)), 0, BB, value=1), 1, BF, value=1)
        spec = (
            pl.BlockSpec((BB, BF), lambda i, p, k: (i, k))
            if tiled else pl.BlockSpec((BB, fp), lambda i: (i, 0))
        )
    else:
        atolp = _pad_to(jnp.broadcast_to(atol.reshape((-1, 1)) if atol.ndim else atol, (b, 1)),
                        0, BB, value=1)
        rtolp = _pad_to(jnp.broadcast_to(rtol.reshape((-1, 1)) if rtol.ndim else rtol, (b, 1)),
                        0, BB, value=1)
        spec = (
            pl.BlockSpec((BB, 1), lambda i, p, k: (i, 0))
            if tiled else pl.BlockSpec((BB, 1), lambda i: (i, 0))
        )
    return atolp, rtolp, spec


def _fused_row_col_specs(fp, *, tiled):
    """(row, col) block specs matching the schedule's grid arity."""
    if tiled:
        return (
            pl.BlockSpec((BB, BF), lambda i, p, k: (i, k)),
            pl.BlockSpec((BB, 1), lambda i, p, k: (i, 0)),
        )
    return (
        pl.BlockSpec((BB, fp), lambda i: (i, 0)),
        pl.BlockSpec((BB, 1), lambda i: (i, 0)),
    )


def _fused_out_specs(bp, fp, dtype, *, tiled=False):
    row, col = _fused_row_col_specs(fp, tiled=tiled)
    specs = [row, col, col, row, row, col, col, col, col, row, row, row]
    shapes = [
        jax.ShapeDtypeStruct((bp, fp), dtype),  # y1
        jax.ShapeDtypeStruct((bp, 1), dtype),   # err_ratio
        jax.ShapeDtypeStruct((bp, 1), jnp.int32),  # accept
        jax.ShapeDtypeStruct((bp, fp), dtype),  # y_out
        jax.ShapeDtypeStruct((bp, fp), dtype),  # f_out
        jax.ShapeDtypeStruct((bp, 1), dtype),   # t_out
        jax.ShapeDtypeStruct((bp, 1), dtype),   # dt_out
        jax.ShapeDtypeStruct((bp, 1), dtype),   # new_inv
        jax.ShapeDtypeStruct((bp, 1), dtype),   # new_inv2
        jax.ShapeDtypeStruct((bp, fp), dtype),  # c1
        jax.ShapeDtypeStruct((bp, fp), dtype),  # c2
        jax.ShapeDtypeStruct((bp, fp), dtype),  # c3
    ]
    return specs, shapes


def _fused_returns(outs, y, b, f, want_coeffs):
    y1, ratio, accept, y_out, f_out, t_out, dt_out, i1, i2, c1, c2, c3 = outs
    coeffs = None
    if want_coeffs:
        # c0 is the (unpadded) input state itself -- no kernel output needed.
        coeffs = (y, c1[:b, :f], c2[:b, :f], c3[:b, :f])
    return (
        y1[:b, :f], ratio[:b, 0], accept[:b, 0].astype(bool),
        y_out[:b, :f], f_out[:b, :f], t_out[:b, 0], dt_out[:b, 0],
        i1[:b, 0], i2[:b, 0], coeffs,
    )


def fused_step(
    y, K, f1, t, t_new, dt_cur, safe_dt, running, prev_inv, prev2_inv,
    atol, rtol, *, b_sol, b_err, ctrl, want_coeffs, ctrl_mode="pid",
    failed=None, interpret=False,
):
    b, f = y.shape
    s = K.shape[0]
    dtype = y.dtype
    # Feature padding: y pads with 1 and K/f1 with 0, so padded err cells are
    # 0 and the norm is exact (divide by the TRUE feature count below).
    yp = _pad_to(_pad_to(y, 0, BB, value=1), 1, BF, value=1)
    Kp = _pad_to(_pad_to(K, 1, BB), 2, BF)
    f1p = _pad_to(_pad_to(f1, 0, BB), 1, BF)
    bp, fp = yp.shape
    nf = fp // BF
    tiled = nf > 1  # one tile -> the verified single-pass schedule
    atolp, rtolp, tol_spec = _fused_tol_blocks(atol, rtol, b, f, bp, fp, dtype, tiled=tiled)
    cols = [t, t_new, dt_cur, safe_dt, running, prev_inv, prev2_inv]
    colp = [_pad_to(x[:, None], 0, BB) for x in cols]
    # Solver-failure column (implicit steps); all-zeros when absent so the
    # kernel's failure masking is a numeric no-op on the explicit path.
    fail = jnp.zeros((b,), jnp.int32) if failed is None else failed.astype(jnp.int32)
    failp = _pad_to(fail[:, None], 0, BB)
    row, col = _fused_row_col_specs(fp, tiled=tiled)
    out_specs, out_shapes = _fused_out_specs(bp, fp, dtype, tiled=tiled)
    if tiled:
        grid = (bp // BB, 2, nf)
        k_spec = pl.BlockSpec((s, BB, BF), lambda i, p, k: (0, i, k))
        kernel = functools.partial(
            _fused_step_tiled_kernel, b_sol=tuple(b_sol), b_err=tuple(b_err),
            ctrl=tuple(ctrl), ctrl_mode=ctrl_mode, n_feat=float(f), nf_tiles=nf,
        )
    else:
        grid = (bp // BB,)
        k_spec = pl.BlockSpec((s, BB, fp), lambda i: (0, i, 0))
        kernel = functools.partial(
            _fused_step_kernel, b_sol=tuple(b_sol), b_err=tuple(b_err),
            ctrl=tuple(ctrl), ctrl_mode=ctrl_mode, n_feat=float(f),
        )
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            row,
            k_spec,
            row,
            col, col, col, col, col, col, col,  # t, t_new, dt_cur, sdt, run, pi1, pi2
            tol_spec, tol_spec,
            col,  # failed
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(yp, Kp, f1p, colp[0], colp[1], colp[2], colp[3], colp[4], colp[5], colp[6],
      atolp, rtolp, failp)
    return _fused_returns(outs, y, b, f, want_coeffs)


def fused_step_poly(
    y, f0, t, t_new, dt_cur, safe_dt, running, prev_inv, prev2_inv,
    atol, rtol, *, a, c, b_sol, b_err, poly, ctrl, want_coeffs, fsal=True,
    ctrl_mode="pid", interpret=False,
):
    del c  # autonomous polynomial dynamics
    b, f = y.shape
    dtype = y.dtype
    yp = _pad_to(_pad_to(y, 0, BB, value=1), 1, BF, value=1)
    f0p = _pad_to(_pad_to(f0, 0, BB), 1, BF)
    bp, fp = yp.shape
    nf = fp // BF
    tiled = nf > 1
    # Static polynomial coefficients materialize as one small (deg+1, fp)
    # input streamed to every program (scalars broadcast across features).
    poly_rows = np.stack(
        [np.broadcast_to(np.asarray(cd, dtype=dtype), (f,)) for cd in poly]
    )
    polyp = _pad_to(jnp.asarray(poly_rows), 1, BF)
    atolp, rtolp, tol_spec = _fused_tol_blocks(atol, rtol, b, f, bp, fp, dtype, tiled=tiled)
    cols = [t, t_new, dt_cur, safe_dt, running, prev_inv, prev2_inv]
    colp = [_pad_to(x[:, None], 0, BB) for x in cols]
    row, col = _fused_row_col_specs(fp, tiled=tiled)
    out_specs, out_shapes = _fused_out_specs(bp, fp, dtype, tiled=tiled)
    static = dict(
        a=tuple(tuple(r) for r in a), b_sol=tuple(b_sol), b_err=tuple(b_err),
        ctrl=tuple(ctrl), ctrl_mode=ctrl_mode, fsal=fsal, n_feat=float(f),
    )
    if tiled:
        grid = (bp // BB, 2, nf)
        poly_spec = pl.BlockSpec((len(poly), BF), lambda i, p, k: (0, k))
        kernel = functools.partial(_fused_step_poly_tiled_kernel, nf_tiles=nf, **static)
    else:
        grid = (bp // BB,)
        poly_spec = pl.BlockSpec((len(poly), fp), lambda i: (0, 0))
        kernel = functools.partial(_fused_step_poly_kernel, **static)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            row,
            row,
            poly_spec,
            col, col, col, col, col, col, col,
            tol_spec, tol_spec,
        ],
        out_specs=out_specs,
        out_shape=out_shapes,
        interpret=interpret,
    )(yp, f0p, polyp, colp[0], colp[1], colp[2], colp[3], colp[4], colp[5], colp[6],
      atolp, rtolp)
    return _fused_returns(outs, y, b, f, want_coeffs)


# ------------------------------------------------------------ fused event ops
#
# The event layer's per-step fixed cost -- E sign tests at detection, the
# terminal resolution + bookkeeping update at commit -- runs as two kernels
# so a solve with events launches O(1) extra programs per step instead of
# O(E) elementwise ops.  E is tiny (a handful of events), so the E axis
# rides whole inside each block like the (BB, 1) scalar columns elsewhere;
# bool in/outputs travel as bool in / int32 out, the ``fused_step`` accept
# convention.


def _event_detect_kernel(
    vp_ref, vn_ref, fired_ref, acc_ref, newly_out, vkeep_out, *, directions
):
    v0 = vp_ref[...]  # (BB, E)
    v1 = vn_ref[...]
    accept = acc_ref[...]  # (BB, 1), broadcasts over E
    up = (v0 <= 0.0) & (v1 >= 0.0)
    down = (v0 >= 0.0) & (v1 <= 0.0)
    # Per-event direction choice unrolled over the static tuple (a materialized
    # direction vector would be a captured constant, which pallas forbids).
    cols = []
    for i, d in enumerate(directions):
        c = up if d > 0 else down if d < 0 else up | down
        cols.append(c[:, i:i + 1])
    crossed = cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)
    crossed = crossed & ((v0 != 0.0) | (v1 != 0.0))
    newly = crossed & ~fired_ref[...] & accept
    newly_out[...] = newly.astype(jnp.int32)
    vkeep_out[...] = jnp.where(accept, v1, v0)


def fused_event_detect(v_prev, v_new, fired, accept, *, directions, interpret=False):
    b, E = v_prev.shape
    vpp = _pad_to(v_prev, 0, BB)
    vnp_ = _pad_to(v_new, 0, BB)
    firedp = _pad_to(fired, 0, BB)
    accp = _pad_to(accept[:, None], 0, BB)
    bp = vpp.shape[0]
    espec = pl.BlockSpec((BB, E), lambda i: (i, 0))
    cspec = pl.BlockSpec((BB, 1), lambda i: (i, 0))
    newly, v_keep = pl.pallas_call(
        functools.partial(
            _event_detect_kernel, directions=tuple(float(d) for d in directions)
        ),
        grid=(bp // BB,),
        in_specs=[espec, espec, espec, cspec],
        out_specs=[espec, espec],
        out_shape=[
            jax.ShapeDtypeStruct((bp, E), jnp.int32),
            jax.ShapeDtypeStruct((bp, E), v_prev.dtype),
        ],
        interpret=interpret,
    )(vpp, vnp_, firedp, accp)
    return newly[:b].astype(bool), v_keep[:b]


def _event_commit_kernel(
    x_ref, yev_ref, newly_ref, ynew_ref, t0_ref, dt_ref,
    fired_ref, evt_ref, evy_ref,
    fired_out, evt_out, evy_out, stop_out, tstop_out, ystop_out, nnew_out,
    *, terminal,
):
    x = x_ref[...]  # (BB, E)
    newly = newly_ref[...]
    t0 = t0_ref[...]  # (BB, 1)
    dt = dt_ref[...]
    yev = yev_ref[...]  # (BB, E, BF) feature tile
    # Terminal resolution: the earliest terminal crossing wins.  Unrolled
    # over the static terminal flags, same expressions as the ref op.
    x_stop = jnp.full(t0.shape, jnp.asarray(jnp.inf, x.dtype), dtype=x.dtype)
    y_stop = ynew_ref[...]  # (BB, BF)
    stop = jnp.zeros(t0.shape, dtype=bool)
    for i, term in enumerate(terminal):
        if not term:
            continue
        n_i = newly[:, i:i + 1]  # (BB, 1)
        stop = stop | n_i
        earlier = n_i & (x[:, i:i + 1] < x_stop)
        y_stop = jnp.where(earlier, yev[:, i, :], y_stop)
        x_stop = jnp.where(earlier, x[:, i:i + 1], x_stop)
    rec = newly & (x <= x_stop)  # (BB, E)
    # The E-column and scalar-column outputs do not depend on the feature
    # tile; rewriting them once per tile is idempotent (bisect-kernel rule).
    fired_out[...] = (fired_ref[...] | rec).astype(jnp.int32)
    evt_out[...] = jnp.where(rec, t0 + x * dt, evt_ref[...])
    evy_out[...] = jnp.where(rec[:, :, None], yev, evy_ref[...])
    stop_out[...] = stop.astype(jnp.int32)
    tstop_out[...] = t0 + jnp.where(stop, x_stop, 0.0) * dt
    ystop_out[...] = y_stop
    nnew_out[...] = jnp.sum(rec.astype(jnp.int32), axis=1, keepdims=True)


def fused_event_commit(
    x, y_ev, newly, y_new, t0, dt, fired, ev_t, ev_y, *, terminal, interpret=False
):
    b, E = x.shape
    f = y_new.shape[1]
    xp = _pad_to(x, 0, BB)
    yevp = _pad_to(_pad_to(y_ev, 0, BB), 2, BF)
    newlyp = _pad_to(newly, 0, BB)
    ynewp = _pad_to(_pad_to(y_new, 0, BB), 1, BF)
    t0p = _pad_to(t0[:, None], 0, BB)
    dtp = _pad_to(dt[:, None], 0, BB)
    firedp = _pad_to(fired, 0, BB)
    evtp = _pad_to(ev_t, 0, BB)
    evyp = _pad_to(_pad_to(ev_y, 0, BB), 2, BF)
    bp = xp.shape[0]
    fp = ynewp.shape[1]
    espec = pl.BlockSpec((BB, E), lambda i, k: (i, 0))
    cspec = pl.BlockSpec((BB, 1), lambda i, k: (i, 0))
    rowspec = pl.BlockSpec((BB, BF), lambda i, k: (i, k))
    e3spec = pl.BlockSpec((BB, E, BF), lambda i, k: (i, 0, k))
    outs = pl.pallas_call(
        functools.partial(
            _event_commit_kernel, terminal=tuple(bool(t) for t in terminal)
        ),
        grid=(bp // BB, fp // BF),
        in_specs=[espec, e3spec, espec, rowspec, cspec, cspec, espec, espec, e3spec],
        out_specs=[espec, espec, e3spec, cspec, cspec, rowspec, cspec],
        out_shape=[
            jax.ShapeDtypeStruct((bp, E), jnp.int32),       # fired
            jax.ShapeDtypeStruct((bp, E), t0.dtype),        # ev_t
            jax.ShapeDtypeStruct((bp, E, fp), y_ev.dtype),  # ev_y
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),       # stop
            jax.ShapeDtypeStruct((bp, 1), t0.dtype),        # t_stop
            jax.ShapeDtypeStruct((bp, fp), y_new.dtype),    # y_stop
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),       # n_new
        ],
        interpret=interpret,
    )(xp, yevp, newlyp, ynewp, t0p, dtp, firedp, evtp, evyp)
    fired_n, evt_n, evy_n, stop, t_stop, y_stop, n_new = outs
    return (
        fired_n[:b].astype(bool), evt_n[:b], evy_n[:b, :, :f],
        stop[:b, 0].astype(bool), t_stop[:b, 0], y_stop[:b, :f], n_new[:b, 0],
    )


# ------------------------------------------------------------- impl namespaces


class _Impl:
    def __init__(self, interpret: bool):
        self._i = interpret

    def stage_accum(self, y, dt, K, coeffs):
        return stage_accum(y, dt, K, coeffs, interpret=self._i)

    def fused_update(self, y, K, dt, b_sol, b_err):
        return fused_update(y, K, dt, b_sol, b_err, interpret=self._i)

    def error_norm(self, err, y0, y1, atol, rtol):
        return error_norm(err, y0, y1, atol, rtol, interpret=self._i)

    def interp_eval(self, coeffs, x, mask, out):
        return interp_eval(coeffs, x, mask, out, interpret=self._i)

    def batched_linsolve(self, A, rhs):
        return batched_linsolve(A, rhs, interpret=self._i)

    def batched_lu_factor(self, A):
        return batched_lu_factor(A, interpret=self._i)

    def fused_newton_iter(self, lu, perm, k, fk, active, scale):
        return fused_newton_iter(lu, perm, k, fk, active, scale, interpret=self._i)

    def masked_newton_update(self, k, delta, active, scale):
        return masked_newton_update(k, delta, active, scale, interpret=self._i)

    def masked_bisect_refine(self, coeffs, lo, hi, v_lo, v_mid, active):
        return masked_bisect_refine(coeffs, lo, hi, v_lo, v_mid, active, interpret=self._i)

    def fused_step(self, *args, **kwargs):
        return fused_step(*args, **kwargs, interpret=self._i)

    def fused_step_poly(self, *args, **kwargs):
        return fused_step_poly(*args, **kwargs, interpret=self._i)

    def fused_event_detect(self, *args, **kwargs):
        return fused_event_detect(*args, **kwargs, interpret=self._i)

    def fused_event_commit(self, *args, **kwargs):
        return fused_event_commit(*args, **kwargs, interpret=self._i)


_INTERPRET = _Impl(True)
_COMPILED = _Impl(False)


def interpret_impl() -> _Impl:
    return _INTERPRET


def compiled_impl() -> _Impl:
    return _COMPILED
