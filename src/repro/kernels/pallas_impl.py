"""Pallas TPU kernels for the solver's hot-spot ops.

Three kernels, mirroring the fused PyTorch kernels (einsum/addcmul) that make
torchode fast, re-thought for the TPU memory hierarchy:

  - ``fused_update``: one HBM->VMEM pass over the stage tensor K produces BOTH
    the solution update and the embedded error estimate.  The stage weights are
    compile-time constants (Butcher tableau), so the combination is a fully
    unrolled multiply-add chain on the VPU -- no reduction loop, no second pass.
  - ``stage_accum``: same structure for intermediate stage states.
  - ``error_norm``: the weighted-RMS error norm fused with its scale
    computation; accumulates sum-of-squares across feature tiles in the output
    block (grid is sequential on TPU), finalizing sqrt(mean) on the last tile.
  - ``interp_eval``: masked Horner evaluation of the dense-output cubic into the
    (aliased) output buffer -- torchode's "evaluation tracking" hot spot.

Tiling: (8, 128)-aligned blocks (f32 VREG lane layout); wrappers pad
non-aligned shapes and slice back, so kernels always see divisible shapes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BB = 8  # batch tile
BF = 128  # feature tile (lane dimension)
BN = 128  # eval-point tile


def _pad_to(x, axis, mult, value=0):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _cdiv(a, b):
    return (a + b - 1) // b


# ---------------------------------------------------------------- fused update


def _fused_update_kernel(y_ref, k_ref, dt_ref, y1_ref, err_ref, *, b_sol, b_err):
    y = y_ref[...]
    dt = dt_ref[...]  # (BB, 1)
    acc_sol = jnp.zeros_like(y)
    acc_err = jnp.zeros_like(y)
    for j in range(k_ref.shape[0]):  # unrolled: s is 1..7
        k = k_ref[j]
        if b_sol[j] != 0.0:
            acc_sol = acc_sol + b_sol[j] * k
        if b_err[j] != 0.0:
            acc_err = acc_err + b_err[j] * k
    y1_ref[...] = y + dt * acc_sol
    err_ref[...] = dt * acc_err


def fused_update(y, K, dt, b_sol, b_err, *, interpret=False):
    b_sol = np.asarray(b_sol, dtype=np.float64)
    b_err = np.asarray(b_err, dtype=np.float64)
    b, f = y.shape
    s = K.shape[0]
    yp = _pad_to(_pad_to(y, 0, BB), 1, BF)
    Kp = _pad_to(_pad_to(K, 1, BB), 2, BF)
    dtp = _pad_to(dt[:, None], 0, BB)
    bp, fp = yp.shape
    grid = (bp // BB, fp // BF)
    kernel = functools.partial(
        _fused_update_kernel, b_sol=tuple(b_sol.tolist()), b_err=tuple(b_err.tolist())
    )
    y1, err = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((s, BB, BF), lambda i, j: (0, i, j)),
            pl.BlockSpec((BB, 1), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(yp.shape, y.dtype),
            jax.ShapeDtypeStruct(yp.shape, y.dtype),
        ],
        interpret=interpret,
    )(yp, Kp, dtp)
    return y1[:b, :f], err[:b, :f]


# ---------------------------------------------------------------- stage accum


def _stage_accum_kernel(y_ref, k_ref, dt_ref, out_ref, *, coeffs):
    acc = jnp.zeros_like(y_ref[...])
    for j in range(k_ref.shape[0]):
        if coeffs[j] != 0.0:
            acc = acc + coeffs[j] * k_ref[j]
    out_ref[...] = y_ref[...] + dt_ref[...] * acc


def stage_accum(y, dt, K, coeffs, *, interpret=False):
    coeffs = np.asarray(coeffs, dtype=np.float64)
    b, f = y.shape
    s = K.shape[0]
    yp = _pad_to(_pad_to(y, 0, BB), 1, BF)
    Kp = _pad_to(_pad_to(K, 1, BB), 2, BF)
    dtp = _pad_to(dt[:, None], 0, BB)
    bp, fp = yp.shape
    out = pl.pallas_call(
        functools.partial(_stage_accum_kernel, coeffs=tuple(coeffs.tolist())),
        grid=(bp // BB, fp // BF),
        in_specs=[
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((s, BB, BF), lambda i, j: (0, i, j)),
            pl.BlockSpec((BB, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(yp.shape, y.dtype),
        interpret=interpret,
    )(yp, Kp, dtp)
    return out[:b, :f]


# ----------------------------------------------------------------- error norm


def _error_norm_kernel(err_ref, y0_ref, y1_ref, atol_ref, rtol_ref, out_ref, *, n_feat, nf_tiles):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    scale = atol_ref[...] + rtol_ref[...] * jnp.maximum(
        jnp.abs(y0_ref[...]), jnp.abs(y1_ref[...])
    )
    r = err_ref[...] / scale
    out_ref[...] += jnp.sum(r * r, axis=1, keepdims=True)

    @pl.when(j == nf_tiles - 1)
    def _finalize():
        out_ref[...] = jnp.sqrt(out_ref[...] / n_feat)


def error_norm(err, y0, y1, atol, rtol, *, interpret=False):
    b, f = err.shape
    dtype = err.dtype
    atol = jnp.broadcast_to(jnp.asarray(atol, dtype), (b,))[:, None]
    rtol = jnp.broadcast_to(jnp.asarray(rtol, dtype), (b,))[:, None]
    # Padding is exact: padded err entries are 0, padded y entries 1 and padded
    # atol rows 1, so every padded cell contributes 0 / (positive scale) = 0 to
    # the sum of squares; we divide by the TRUE feature count.
    errp = _pad_to(_pad_to(err, 0, BB), 1, BF)
    y0p = _pad_to(_pad_to(y0, 0, BB, value=1), 1, BF, value=1)
    y1p = _pad_to(_pad_to(y1, 0, BB, value=1), 1, BF, value=1)
    atolp = _pad_to(atol, 0, BB, value=1)
    rtolp = _pad_to(rtol, 0, BB, value=1)
    bp, fp = errp.shape
    nf_tiles = fp // BF
    out = pl.pallas_call(
        functools.partial(_error_norm_kernel, n_feat=float(f), nf_tiles=nf_tiles),
        grid=(bp // BB, nf_tiles),
        in_specs=[
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((BB, BF), lambda i, j: (i, j)),
            pl.BlockSpec((BB, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((BB, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BB, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bp, 1), dtype),
        interpret=interpret,
    )(errp, y0p, y1p, atolp, rtolp)
    return out[:b, 0]


# ------------------------------------------------------------------ interp


def _interp_kernel(c0_ref, c1_ref, c2_ref, c3_ref, x_ref, m_ref, prev_ref, out_ref):
    x = x_ref[...][:, :, None]  # (BB, BN, 1)
    c0 = c0_ref[...][:, None, :]  # (BB, 1, BF)
    c1 = c1_ref[...][:, None, :]
    c2 = c2_ref[...][:, None, :]
    c3 = c3_ref[...][:, None, :]
    acc = ((c3 * x + c2) * x + c1) * x + c0  # Horner
    out_ref[...] = jnp.where(m_ref[...][:, :, None], acc, prev_ref[...])


def interp_eval(coeffs, x, mask, out, *, interpret=False):
    c0, c1, c2, c3 = coeffs
    b, n = x.shape
    f = c0.shape[1]
    cs = [_pad_to(_pad_to(c, 0, BB), 1, BF) for c in (c0, c1, c2, c3)]
    xp = _pad_to(_pad_to(x, 0, BB), 1, BN)
    mp = _pad_to(_pad_to(mask, 0, BB), 1, BN)
    outp = _pad_to(_pad_to(_pad_to(out, 0, BB), 1, BN), 2, BF)
    bp, np_ = xp.shape
    fp = cs[0].shape[1]
    res = pl.pallas_call(
        _interp_kernel,
        grid=(bp // BB, np_ // BN, fp // BF),
        in_specs=[
            pl.BlockSpec((BB, BF), lambda i, j, k: (i, k)),
            pl.BlockSpec((BB, BF), lambda i, j, k: (i, k)),
            pl.BlockSpec((BB, BF), lambda i, j, k: (i, k)),
            pl.BlockSpec((BB, BF), lambda i, j, k: (i, k)),
            pl.BlockSpec((BB, BN), lambda i, j, k: (i, j)),
            pl.BlockSpec((BB, BN), lambda i, j, k: (i, j)),
            pl.BlockSpec((BB, BN, BF), lambda i, j, k: (i, j, k)),
        ],
        out_specs=pl.BlockSpec((BB, BN, BF), lambda i, j, k: (i, j, k)),
        out_shape=jax.ShapeDtypeStruct(outp.shape, out.dtype),
        interpret=interpret,
    )(*cs, xp, mp, outp)
    return res[:b, :n, :f]


# ------------------------------------------------------------- impl namespaces


class _Impl:
    def __init__(self, interpret: bool):
        self._i = interpret

    def stage_accum(self, y, dt, K, coeffs):
        return stage_accum(y, dt, K, coeffs, interpret=self._i)

    def fused_update(self, y, K, dt, b_sol, b_err):
        return fused_update(y, K, dt, b_sol, b_err, interpret=self._i)

    def error_norm(self, err, y0, y1, atol, rtol):
        return error_norm(err, y0, y1, atol, rtol, interpret=self._i)

    def interp_eval(self, coeffs, x, mask, out):
        return interp_eval(coeffs, x, mask, out, interpret=self._i)


_INTERPRET = _Impl(True)
_COMPILED = _Impl(False)


def interpret_impl() -> _Impl:
    return _INTERPRET


def compiled_impl() -> _Impl:
    return _COMPILED
