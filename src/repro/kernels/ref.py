"""Pure-jnp reference oracles for the solver's fused hot-spot ops.

These are the semantics the Pallas kernels must match (tests assert allclose
against these).  They are also the execution path on CPU, where Pallas interpret
mode would be much slower than XLA:CPU fusion.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.lax import linalg as lax_linalg


def stage_accum(y, dt, K, coeffs):
    """y + dt * sum_j coeffs[j] * K[j].

    y:      (b, f)
    dt:     (b,)
    K:      (j, b, f)  -- stacked stage derivatives
    coeffs: (j,)       -- tableau row a[i, :j]
    """
    acc = jnp.tensordot(coeffs.astype(K.dtype), K, axes=1)
    return y + dt[:, None] * acc


def fused_update(y, K, dt, b_sol, b_err):
    """One fused pass producing the solution update and the embedded error.

    y1  = y + dt * (b_sol . K)
    err =     dt * (b_err . K)

    K: (s, b, f); b_sol, b_err: (s,).  Returns (y1, err), both (b, f).
    """
    y1 = y + dt[:, None] * jnp.tensordot(b_sol.astype(K.dtype), K, axes=1)
    err = dt[:, None] * jnp.tensordot(b_err.astype(K.dtype), K, axes=1)
    return y1, err


def broadcast_tolerances(atol, rtol, dtype):
    """Normalize tolerances onto column-broadcastable arrays.

    Accepted shapes -- the ONE tolerance contract shared by the error norm
    (both backends), the Newton convergence scale and the initial-step
    heuristic: scalar (batch-shared), (b,) per-instance, or full (b, f).
    Returns (atol, rtol) ready to broadcast against a (b, f) state.
    """
    atol = jnp.asarray(atol, dtype=dtype)
    rtol = jnp.asarray(rtol, dtype=dtype)
    if atol.ndim == 1:
        atol = atol[:, None]
    if rtol.ndim == 1:
        rtol = rtol[:, None]
    return atol, rtol


def error_norm(err, y0, y1, atol, rtol):
    """Weighted RMS norm, per instance.

    ||err / (atol + rtol * max(|y0|, |y1|))||_rms  over the feature axis.

    err, y0, y1: (b, f);  atol, rtol: scalar or (b,) or (b, f).
    Returns (b,).
    """
    atol, rtol = broadcast_tolerances(atol, rtol, err.dtype)
    scale = atol + rtol * jnp.maximum(jnp.abs(y0), jnp.abs(y1))
    ratio = err / scale
    return jnp.sqrt(jnp.mean(ratio * ratio, axis=-1))


def rms_norm(x, scale):
    """Scaled RMS over the feature axis: ||x / scale||_rms.

    x, scale: (b, f) (scale may broadcast).  Returns (b,).  Used by the
    automatic initial-step-size heuristic; ``error_norm`` is the in-loop
    variant with the accept/reject scale convention.
    """
    ratio = x / scale
    return jnp.sqrt(jnp.mean(ratio * ratio, axis=-1))


def hermite_coeffs(y0, y1, f0, f1, dt):
    """Cubic-Hermite dense-output coefficients in Horner form.

    p(x) = ((c3 * x + c2) * x + c1) * x + c0,  x = (t - t0)/dt in [0, 1].
    Returns (c0, c1, c2, c3), each (b, f).
    """
    hdt = dt[:, None]
    c0 = y0
    c1 = hdt * f0
    c2 = 3.0 * (y1 - y0) - hdt * (2.0 * f0 + f1)
    c3 = 2.0 * (y0 - y1) + hdt * (f0 + f1)
    return c0, c1, c2, c3


def batched_linsolve(A, rhs):
    """Batched dense linear solve: x s.t. A @ x = rhs, per instance.

    A:   (b, f, f) Newton matrices (I - dt*gamma*J -- well conditioned for
         any stable step size, diagonally dominant in the stiff limit)
    rhs: (b, f)

    Returns (b, f).  The inner hot spot of the masked-Newton layer.
    """
    return jnp.linalg.solve(A, rhs[..., None])[..., 0]


def batched_lu_factor(A):
    """Batched partial-pivoted LU factorization: factor ONCE per solver step.

    A: (b, f, f) chord matrices I - dt*gamma*J.

    Returns ``(lu, permutation)``: the packed LU factors (unit lower + upper
    triangle in one (b, f, f) array) and the (b, f) int32 row permutation.
    This is the factor-once half of the fused Newton path -- every subsequent
    ``fused_newton_iter`` launch back-substitutes against these factors
    instead of re-eliminating the same matrix.

    ``lax.linalg.lu`` is the exact factorization ``jnp.linalg.solve`` (and
    hence ``batched_linsolve``) performs internally, so the factor +
    back-substitution composition reproduces the unfused solve bitwise on
    this backend.
    """
    lu, _, permutation = lax_linalg.lu(A)
    return lu, permutation


def fused_newton_iter(lu, perm, k, fk, active, scale):
    """One whole chord-Newton iteration against a prefactored LU, as ONE op:
    residual, permutation scatter, the two triangular back-substitutions,
    the masked commit and the scaled-RMS convergence norm.

    lu:     (b, f, f) packed LU factors from ``batched_lu_factor``
    perm:   (b, f) int32 row permutation from ``batched_lu_factor``
    k:      (b, f) current stage iterate
    fk:     (b, f) vf evaluation at the iterate, ``eval_fn(k)``
    active: (b,) bool -- instances still iterating
    scale:  (b, f) error scale atol + rtol*|y| (may broadcast)

    Returns ``(k_new, res_norm)`` exactly like ``masked_newton_update``; the
    update solved here is ``delta = M^{-1} (k - fk)`` via the LU factors.
    The triangular-solve sequence mirrors ``lax.linalg``'s own ``lu_solve``
    lowering (permutation row-gather, unit-lower then upper solve), which is
    what ``jnp.linalg.solve`` runs after factorizing -- so a solve composed of
    ``batched_lu_factor`` + this op is bitwise-equal to ``batched_linsolve``.
    """
    g = k - fk
    x = jnp.take_along_axis(g[..., None], perm[..., None], axis=-2)
    x = lax_linalg.triangular_solve(lu, x, left_side=True, lower=True,
                                    unit_diagonal=True)
    x = lax_linalg.triangular_solve(lu, x, left_side=True, lower=False)
    delta = x[..., 0]
    k_new = jnp.where(active[:, None], k - delta, k)
    ratio = delta / scale
    return k_new, jnp.sqrt(jnp.mean(ratio * ratio, axis=-1))


def masked_newton_update(k, delta, active, scale):
    """One fused masked Newton commit: apply the update only where an
    instance's nonlinear solve is still active, and report the scaled RMS
    norm of the update (the per-instance convergence measure).

    k:      (b, f) current stage iterate
    delta:  (b, f) Newton update (solution of the linearized system)
    active: (b,) bool -- instances still iterating
    scale:  (b, f) error scale atol + rtol*|y| (may broadcast)

    Returns (k_new, res_norm): k - delta where active (k elsewhere), and the
    (b,) RMS of delta/scale.
    """
    k_new = jnp.where(active[:, None], k - delta, k)
    ratio = delta / scale
    return k_new, jnp.sqrt(jnp.mean(ratio * ratio, axis=-1))


def masked_bisect_refine(coeffs, lo, hi, v_lo, v_mid, active):
    """One masked bisection refinement on the dense-output interpolant.

    The event localizer brackets a sign change of the condition function in
    interpolant coordinates x in [0, 1].  Given the bracket, the condition
    value at its low end and at its midpoint, this op halves the bracket
    (keeping the sign change inside) and evaluates the interpolant at the NEW
    midpoint -- the caller then evaluates the condition there and iterates.

    coeffs: tuple of (b, f) Horner coefficients, low -> high degree
    lo, hi: (b,) current bracket
    v_lo:   (b,) condition value at lo
    v_mid:  (b,) condition value at (lo + hi)/2
    active: (b,) bool -- instances still refining (others keep their bracket)

    Returns ``(lo', hi', v_lo', mid', y_mid')`` with ``mid' = (lo' + hi')/2``
    and ``y_mid'`` the interpolant there (evaluated for every row; inactive
    rows' brackets are frozen).
    """
    mid = 0.5 * (lo + hi)
    # The crossing is in [lo, mid] iff the condition changes sign there
    # (v_mid == 0 counts: the event is at/before the midpoint).
    left = jnp.sign(v_lo) != jnp.sign(v_mid)
    hi_new = jnp.where(active & left, mid, hi)
    lo_new = jnp.where(active & ~left, mid, lo)
    v_lo_new = jnp.where(active & ~left, v_mid, v_lo)
    mid_new = 0.5 * (lo_new + hi_new)
    xe = mid_new[:, None]
    acc = coeffs[-1]
    for c in coeffs[-2::-1]:
        acc = acc * xe + c
    return lo_new, hi_new, v_lo_new, mid_new, acc


def pid_update(
    err_ratio, dt, prev_inv, prev2_inv,
    *, b1, b2, b3, safety, factor_min, factor_max, dt_min, dt_max,
):
    """The Soederlind digital-filter step update shared by ``PIDController``
    and the fused-step kernel.

    This is THE accept/next-dt program: ``PIDController.__call__`` delegates
    here and the fused megakernel re-implements exactly this expression
    sequence, so the fused and unfused paths decide identically (bitwise).

    err_ratio: (b,) weighted RMS error ratio of this step
    dt:        (b,) step size just attempted (signed)
    prev_inv / prev2_inv: (b,) inverse error ratios of the last two accepts
    b1/b2/b3:  Soederlind exponents (already divided by the controller order)

    Returns ``(accept, dt_next, new_inv, new_inv2)``.
    """
    dtype = dt.dtype
    # Guard: err_ratio == 0 (exact solve) -> use factor_max.
    finite = jnp.isfinite(err_ratio)
    safe_ratio = jnp.where(finite & (err_ratio > 0.0), err_ratio, 1.0)
    inv = 1.0 / safe_ratio

    factor = safety * inv**b1 * prev_inv**b2 * prev2_inv**b3
    factor = jnp.where(err_ratio == 0.0, factor_max, factor)
    # Non-finite error estimate: treat as a hard reject, halve the step.
    factor = jnp.where(finite, factor, 0.5)
    factor = jnp.clip(factor, factor_min, factor_max)

    accept = finite & (err_ratio <= 1.0)
    # On rejection never grow the step.
    factor = jnp.where(accept, factor, jnp.minimum(factor, 1.0))

    mag = jnp.clip(jnp.abs(dt) * factor.astype(dtype), dt_min, dt_max)
    dt_next = jnp.sign(dt) * mag

    # Error history advances only on accepted steps (torchode semantics).
    new_inv = jnp.where(accept, inv, prev_inv)
    new_inv2 = jnp.where(accept, prev_inv, prev2_inv)
    return accept, dt_next, new_inv, new_inv2


def poly_eval(y, coeffs):
    """Elementwise polynomial vector field: sum_d coeffs[d] * y**d (Horner).

    ``coeffs`` is a static tuple, low -> high degree; each entry is a scalar
    (feature-shared) or a length-f tuple.  The ONE evaluation program shared
    by ``PolynomialTerm.vf`` and the fused-step megakernel, so the in-kernel
    stage evaluations are bitwise-identical to the unfused vf calls.
    """
    cs = [jnp.asarray(c, y.dtype) for c in coeffs]
    acc = jnp.broadcast_to(cs[-1], y.shape)
    for c in cs[-2::-1]:
        acc = acc * y + c
    return acc


def fused_step(
    y, K, f1, t, t_new, dt_cur, safe_dt, running, prev_inv, prev2_inv,
    atol, rtol, *, b_sol, b_err, ctrl, want_coeffs, ctrl_mode="pid",
    failed=None,
):
    """One fused explicit-RK step attempt AROUND the vf calls: stage-combine,
    WRMS error norm, controller decision, masked commit of (t, y, f)
    against the ``running`` mask, and the dense-output/event interpolation
    coefficient build -- everything between the last stage evaluation and the
    loop-state rebuild, as ONE op.

    y:        (b, f) current state
    K:        (s, b, f) stacked stage derivatives; K[0] is f(t, y) (FSAL cache)
    f1:       (b, f) derivative at (t + dt, y1) (the FSAL last stage, or the
              trailing evaluation for non-FSAL tableaus)
    t:        (b,) current time;  t_new: (b,) time reached if accepted
    dt_cur:   (b,) the standing step proposal (pre-clamp, fed to the controller)
    safe_dt:  (b,) the signed step the stages actually used
    running / prev_inv / prev2_inv: (b,) loop mask + controller history
    b_sol / b_err: static tableau weight tuples
    ctrl:     static ``(b1, b2, b3, safety, factor_min, factor_max, dt_min,
              dt_max)`` from ``PIDController.filter_params`` (``()`` under
              ``ctrl_mode="fixed"``)
    want_coeffs: build the cubic-Hermite coefficients too (dense/events)
    ctrl_mode: ``"pid"`` runs the Soederlind filter; ``"fixed"`` is the
              fixed-step contract (``FixedController``): accept everything
              that is running, keep the standing dt proposal and leave the
              controller history untouched.  The error ratio is still
              computed (it is 0 for fixed-step tableaus, whose b_err is all
              zeros), matching the unfused path bitwise.

    failed: optional (b,) bool -- instances whose implicit stage solve failed
    this attempt (Newton divergence / iteration-cap exhaustion).  Failed
    instances get ``err_ratio = inf`` BEFORE the controller (so an adaptive
    controller shrinks their step) and are excluded from ``accept``
    unconditionally -- essential under ``ctrl_mode="fixed"``, whose
    always-accept contract would otherwise commit a garbage iterate.

    Returns ``(y1, err_ratio, accept, y_out, f_out, t_out, dt_out, new_inv,
    new_inv2, coeffs)`` with ``coeffs = (c0, c1, c2, c3)`` or ``None``.
    """
    y1, err = fused_update(
        y, K, safe_dt, jnp.asarray(b_sol, K.dtype), jnp.asarray(b_err, K.dtype)
    )
    err_ratio = error_norm(err, y, y1, atol, rtol)
    if failed is not None:
        err_ratio = jnp.where(failed, jnp.inf, err_ratio)
    if ctrl_mode == "fixed":
        accept = jnp.ones(dt_cur.shape, dtype=bool)
        dt_next = dt_cur
        new_inv, new_inv2 = prev_inv, prev2_inv
    else:
        b1, b2, b3, safety, factor_min, factor_max, dt_min, dt_max = ctrl
        accept, dt_next, new_inv, new_inv2 = pid_update(
            err_ratio, dt_cur, prev_inv, prev2_inv,
            b1=b1, b2=b2, b3=b3, safety=safety,
            factor_min=factor_min, factor_max=factor_max,
            dt_min=dt_min, dt_max=dt_max,
        )
    accept = accept & running
    if failed is not None:
        accept = accept & ~failed
    acc_f = accept[:, None]
    y_out = jnp.where(acc_f, y1, y)
    f_out = jnp.where(acc_f, f1, K[0])
    t_out = jnp.where(accept, t_new, t)
    dt_out = jnp.where(running, dt_next, dt_cur)
    coeffs = hermite_coeffs(y, y1, K[0], f1, safe_dt) if want_coeffs else None
    return y1, err_ratio, accept, y_out, f_out, t_out, dt_out, new_inv, new_inv2, coeffs


def fused_step_poly(
    y, f0, t, t_new, dt_cur, safe_dt, running, prev_inv, prev2_inv,
    atol, rtol, *, a, c, b_sol, b_err, poly, ctrl, want_coeffs,
    fsal=True, ctrl_mode="pid",
):
    """The full megakernel for closed-form polynomial vector fields: the
    stage evaluations fuse too, so an ENTIRE explicit-RK step attempt is one
    op with zero vf launches.

    ``a``/``c`` are the static tableau arrays (tuples), ``poly`` the static
    coefficient tuple of the elementwise polynomial vf (see ``poly_eval``).
    For FSAL tableaus f1 is the last stage; for non-FSAL ones the trailing
    evaluation f(t + dt, y1) folds in here too (the polynomial vf is
    closed-form, so it costs one more in-kernel Horner pass, not a launch) --
    it happens on every attempt, accepted or rejected, exactly like the
    unfused ``rk_step``.  Everything else as in ``fused_step``.
    """
    del c  # autonomous polynomial dynamics: stage times never enter
    s = len(b_sol)
    ks = [f0]
    for i in range(1, s):
        yi = stage_accum(y, safe_dt, jnp.stack(ks), jnp.asarray(a[i][:i], y.dtype))
        ks.append(poly_eval(yi, poly))
    K = jnp.stack(ks)
    if fsal:
        f1 = K[-1]
    else:
        y1, _ = fused_update(
            y, K, safe_dt, jnp.asarray(b_sol, K.dtype), jnp.asarray(b_err, K.dtype)
        )
        f1 = poly_eval(y1, poly)
    return fused_step(
        y, K, f1, t, t_new, dt_cur, safe_dt, running, prev_inv, prev2_inv,
        atol, rtol, b_sol=b_sol, b_err=b_err, ctrl=ctrl,
        want_coeffs=want_coeffs, ctrl_mode=ctrl_mode,
    )


def fused_event_detect(v_prev, v_new, fired, accept, *, directions):
    """Fused per-event sign test of the event layer: scipy's zero-crossing
    detection for EVERY registered event in one op, plus the masked carry of
    the condition values (only accepted steps advance them).

    v_prev: (b, E) condition values at the current accepted state
    v_new:  (b, E) condition values at the candidate state
    fired:  (b, E) bool -- crossings already recorded (these never re-fire)
    accept: (b,) bool -- this step's accept mask (already masked by running)
    directions: static tuple of per-event crossing directions (0 / +1 / -1)

    Returns ``(newly, v_keep)``: the (b, E) "newly crossed this step" mask
    and the carried (b, E) condition values.
    """
    crossed = []
    for i, d in enumerate(directions):
        v0, v1 = v_prev[:, i], v_new[:, i]
        up = (v0 <= 0.0) & (v1 >= 0.0)
        down = (v0 >= 0.0) & (v1 <= 0.0)
        if d > 0:
            c = up
        elif d < 0:
            c = down
        else:
            c = up | down
        crossed.append(c & ((v0 != 0.0) | (v1 != 0.0)))
    newly = jnp.stack(crossed, axis=1) & ~fired & accept[:, None]
    v_keep = jnp.where(accept[:, None], v_new, v_prev)
    return newly, v_keep


def fused_event_commit(x, y_ev, newly, y_new, t0, dt, fired, ev_t, ev_y, *, terminal):
    """Fused event-record commit: terminal resolution (the earliest terminal
    crossing wins), the first-crossing bookkeeping update and the stop
    outputs of one step's event processing, as one op.

    x:      (b, E) localized crossing positions in interpolant coordinates
    y_ev:   (b, E, f) interpolated states at the crossings
    newly:  (b, E) bool -- crossings detected this step
    y_new:  (b, f) the accepted candidate state (stop fallback)
    t0, dt: (b,) step start times / signed step sizes
    fired / ev_t / ev_y: the recorded-crossing bookkeeping being advanced
    terminal: static tuple of per-event terminal flags

    Returns ``(fired', ev_t', ev_y', stop, t_stop, y_stop, n_new)``.
    """
    b = x.shape[0]
    inf = jnp.asarray(jnp.inf, t0.dtype)
    x_stop = jnp.full((b,), inf, dtype=t0.dtype)
    y_stop = y_new
    stop = jnp.zeros((b,), dtype=bool)
    for i, term in enumerate(terminal):
        if not term:
            continue
        stop = stop | newly[:, i]
        earlier = newly[:, i] & (x[:, i] < x_stop)
        y_stop = jnp.where(earlier[:, None], y_ev[:, i], y_stop)
        x_stop = jnp.where(earlier, x[:, i], x_stop)
    rec = newly & (x <= x_stop[:, None])

    t_ev = t0[:, None] + x * dt[:, None]
    return (
        fired | rec,
        jnp.where(rec, t_ev, ev_t),
        jnp.where(rec[:, :, None], y_ev, ev_y),
        stop,
        t0 + jnp.where(stop, x_stop, 0.0) * dt,
        y_stop,
        rec.sum(axis=1).astype(jnp.int32),
    )


def interp_eval(coeffs, x, mask, out):
    """Masked Horner evaluation of the dense-output polynomial.

    coeffs: tuple of (b, f) arrays, low -> high degree
    x:      (b, n) normalized evaluation positions
    mask:   (b, n) bool -- which (instance, point) cells to write this step
    out:    (b, n, f) existing output buffer

    Returns updated (b, n, f) buffer: where mask, p(x); elsewhere out.
    """
    xe = x[:, :, None]
    acc = jnp.broadcast_to(coeffs[-1][:, None, :], xe.shape[:2] + coeffs[-1].shape[-1:])
    for c in coeffs[-2::-1]:
        acc = acc * xe + c[:, None, :]
    return jnp.where(mask[:, :, None], acc, out)
