"""Pure-jnp reference oracles for the solver's fused hot-spot ops.

These are the semantics the Pallas kernels must match (tests assert allclose
against these).  They are also the execution path on CPU, where Pallas interpret
mode would be much slower than XLA:CPU fusion.
"""

from __future__ import annotations

import jax.numpy as jnp


def stage_accum(y, dt, K, coeffs):
    """y + dt * sum_j coeffs[j] * K[j].

    y:      (b, f)
    dt:     (b,)
    K:      (j, b, f)  -- stacked stage derivatives
    coeffs: (j,)       -- tableau row a[i, :j]
    """
    acc = jnp.tensordot(coeffs.astype(K.dtype), K, axes=1)
    return y + dt[:, None] * acc


def fused_update(y, K, dt, b_sol, b_err):
    """One fused pass producing the solution update and the embedded error.

    y1  = y + dt * (b_sol . K)
    err =     dt * (b_err . K)

    K: (s, b, f); b_sol, b_err: (s,).  Returns (y1, err), both (b, f).
    """
    y1 = y + dt[:, None] * jnp.tensordot(b_sol.astype(K.dtype), K, axes=1)
    err = dt[:, None] * jnp.tensordot(b_err.astype(K.dtype), K, axes=1)
    return y1, err


def broadcast_tolerances(atol, rtol, dtype):
    """Normalize tolerances onto column-broadcastable arrays.

    Accepted shapes -- the ONE tolerance contract shared by the error norm
    (both backends), the Newton convergence scale and the initial-step
    heuristic: scalar (batch-shared), (b,) per-instance, or full (b, f).
    Returns (atol, rtol) ready to broadcast against a (b, f) state.
    """
    atol = jnp.asarray(atol, dtype=dtype)
    rtol = jnp.asarray(rtol, dtype=dtype)
    if atol.ndim == 1:
        atol = atol[:, None]
    if rtol.ndim == 1:
        rtol = rtol[:, None]
    return atol, rtol


def error_norm(err, y0, y1, atol, rtol):
    """Weighted RMS norm, per instance.

    ||err / (atol + rtol * max(|y0|, |y1|))||_rms  over the feature axis.

    err, y0, y1: (b, f);  atol, rtol: scalar or (b,) or (b, f).
    Returns (b,).
    """
    atol, rtol = broadcast_tolerances(atol, rtol, err.dtype)
    scale = atol + rtol * jnp.maximum(jnp.abs(y0), jnp.abs(y1))
    ratio = err / scale
    return jnp.sqrt(jnp.mean(ratio * ratio, axis=-1))


def rms_norm(x, scale):
    """Scaled RMS over the feature axis: ||x / scale||_rms.

    x, scale: (b, f) (scale may broadcast).  Returns (b,).  Used by the
    automatic initial-step-size heuristic; ``error_norm`` is the in-loop
    variant with the accept/reject scale convention.
    """
    ratio = x / scale
    return jnp.sqrt(jnp.mean(ratio * ratio, axis=-1))


def hermite_coeffs(y0, y1, f0, f1, dt):
    """Cubic-Hermite dense-output coefficients in Horner form.

    p(x) = ((c3 * x + c2) * x + c1) * x + c0,  x = (t - t0)/dt in [0, 1].
    Returns (c0, c1, c2, c3), each (b, f).
    """
    hdt = dt[:, None]
    c0 = y0
    c1 = hdt * f0
    c2 = 3.0 * (y1 - y0) - hdt * (2.0 * f0 + f1)
    c3 = 2.0 * (y0 - y1) + hdt * (f0 + f1)
    return c0, c1, c2, c3


def batched_linsolve(A, rhs):
    """Batched dense linear solve: x s.t. A @ x = rhs, per instance.

    A:   (b, f, f) Newton matrices (I - dt*gamma*J -- well conditioned for
         any stable step size, diagonally dominant in the stiff limit)
    rhs: (b, f)

    Returns (b, f).  The inner hot spot of the masked-Newton layer.
    """
    return jnp.linalg.solve(A, rhs[..., None])[..., 0]


def masked_newton_update(k, delta, active, scale):
    """One fused masked Newton commit: apply the update only where an
    instance's nonlinear solve is still active, and report the scaled RMS
    norm of the update (the per-instance convergence measure).

    k:      (b, f) current stage iterate
    delta:  (b, f) Newton update (solution of the linearized system)
    active: (b,) bool -- instances still iterating
    scale:  (b, f) error scale atol + rtol*|y| (may broadcast)

    Returns (k_new, res_norm): k - delta where active (k elsewhere), and the
    (b,) RMS of delta/scale.
    """
    k_new = jnp.where(active[:, None], k - delta, k)
    ratio = delta / scale
    return k_new, jnp.sqrt(jnp.mean(ratio * ratio, axis=-1))


def masked_bisect_refine(coeffs, lo, hi, v_lo, v_mid, active):
    """One masked bisection refinement on the dense-output interpolant.

    The event localizer brackets a sign change of the condition function in
    interpolant coordinates x in [0, 1].  Given the bracket, the condition
    value at its low end and at its midpoint, this op halves the bracket
    (keeping the sign change inside) and evaluates the interpolant at the NEW
    midpoint -- the caller then evaluates the condition there and iterates.

    coeffs: tuple of (b, f) Horner coefficients, low -> high degree
    lo, hi: (b,) current bracket
    v_lo:   (b,) condition value at lo
    v_mid:  (b,) condition value at (lo + hi)/2
    active: (b,) bool -- instances still refining (others keep their bracket)

    Returns ``(lo', hi', v_lo', mid', y_mid')`` with ``mid' = (lo' + hi')/2``
    and ``y_mid'`` the interpolant there (evaluated for every row; inactive
    rows' brackets are frozen).
    """
    mid = 0.5 * (lo + hi)
    # The crossing is in [lo, mid] iff the condition changes sign there
    # (v_mid == 0 counts: the event is at/before the midpoint).
    left = jnp.sign(v_lo) != jnp.sign(v_mid)
    hi_new = jnp.where(active & left, mid, hi)
    lo_new = jnp.where(active & ~left, mid, lo)
    v_lo_new = jnp.where(active & ~left, v_mid, v_lo)
    mid_new = 0.5 * (lo_new + hi_new)
    xe = mid_new[:, None]
    acc = coeffs[-1]
    for c in coeffs[-2::-1]:
        acc = acc * xe + c
    return lo_new, hi_new, v_lo_new, mid_new, acc


def interp_eval(coeffs, x, mask, out):
    """Masked Horner evaluation of the dense-output polynomial.

    coeffs: tuple of (b, f) arrays, low -> high degree
    x:      (b, n) normalized evaluation positions
    mask:   (b, n) bool -- which (instance, point) cells to write this step
    out:    (b, n, f) existing output buffer

    Returns updated (b, n, f) buffer: where mask, p(x); elsewhere out.
    """
    xe = x[:, :, None]
    acc = jnp.broadcast_to(coeffs[-1][:, None, :], xe.shape[:2] + coeffs[-1].shape[-1:])
    for c in coeffs[-2::-1]:
        acc = acc * xe + c[:, None, :]
    return jnp.where(mask[:, :, None], acc, out)
