"""Model assembly: scan-over-periods layer stacks for all 10 assigned archs.

A config's ``pattern`` lists the block kinds of one period; parameters are
stacked on a leading period axis and the stack executes as ``lax.scan`` over
periods, so HLO size is depth-independent.  Three execution modes share the
same block code:

  - train:   full-sequence forward, logits for next-token loss
  - prefill: full-sequence forward that also materializes per-layer caches
  - decode:  one-token step against the caches (KV / SSM / xLSTM states)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import moe as moe_lib
from . import ssm as ssm_lib
from . import xlstm as xlstm_lib
from .attention import decode_attention, flash_attention
from .common import apply_norm, apply_rope, dense_init, norm_params, split_keys
from .config import ArchConfig


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# --------------------------------------------------------------- block params


def _attn_params(key, cfg, dtype, cross=False):
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = split_keys(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H * hd), dtype=dtype),
        "wk": dense_init(ks[1], (d, KV * hd), dtype=dtype),
        "wv": dense_init(ks[2], (d, KV * hd), dtype=dtype),
        "wo": dense_init(ks[3], (H * hd, d), dtype=dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def _mlp_params(key, cfg, dtype):
    d, ff = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    p = {
        "w_in": dense_init(ks[0], (d, ff), dtype=dtype),
        "w_out": dense_init(ks[1], (ff, d), dtype=dtype),
    }
    if cfg.mlp == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d, ff), dtype=dtype)
    return p


def block_params(kind: str, key, cfg: ArchConfig, dtype):
    ks = split_keys(key, 4)
    p = {}
    if kind in ("attn_mlp", "attn_moe", "attn_bidir_mlp", "attn_cross_mlp"):
        p["attn"] = _attn_params(ks[0], cfg, dtype)
        p["ln1"] = norm_params(cfg, cfg.d_model)
        if kind == "attn_cross_mlp":
            p["xattn"] = _attn_params(ks[3], cfg, dtype, cross=True)
            p["lnx"] = norm_params(cfg, cfg.d_model)
    elif kind in ("mamba_mlp", "mamba_moe"):
        p["mamba"] = ssm_lib.mamba_params(ks[0], cfg, dtype)
        p["ln1"] = norm_params(cfg, cfg.d_model)
    elif kind == "mlstm":
        p["mlstm"] = xlstm_lib.mlstm_params(ks[0], cfg, dtype)
        p["ln1"] = norm_params(cfg, cfg.d_model)
        return p
    elif kind == "slstm":
        p["slstm"] = xlstm_lib.slstm_params(ks[0], cfg, dtype)
        p["ln1"] = norm_params(cfg, cfg.d_model)
        return p
    else:
        raise ValueError(kind)

    if kind.endswith("_moe"):
        p["moe"] = moe_lib.moe_params(ks[1], cfg, dtype)
        p["ln2"] = norm_params(cfg, cfg.d_model)
    elif kind.endswith("_mlp"):
        p["mlp"] = _mlp_params(ks[1], cfg, dtype)
        p["ln2"] = norm_params(cfg, cfg.d_model)
    return p


# --------------------------------------------------------------- block apply


def _qkv(cfg, p, x):
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(*x.shape[:-1], H, hd)
    k = k.reshape(*x.shape[:-1], KV, hd)
    v = v.reshape(*x.shape[:-1], KV, hd)
    return q, k, v


def _mlp(cfg, p, x):
    if cfg.mlp == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_in"])) @ p["w_out"]
    return jax.nn.gelu(x @ p["w_in"]) @ p["w_out"]


def _channel_mix(cfg, kind, p, x):
    """Second half of a block: MLP or MoE over the residual stream."""
    aux = {}
    if kind.endswith("_moe"):
        b, s, d = x.shape
        h = apply_norm(cfg, x, p["ln2"], "")
        y, aux = moe_lib.moe_apply(cfg, p["moe"], h.reshape(b * s, d))
        x = x + y.reshape(b, s, d)
    elif kind.endswith("_mlp"):
        x = x + _mlp(cfg, p["mlp"], apply_norm(cfg, x, p["ln2"], ""))
    return x, aux


def block_apply_seq(cfg, kind, p, x, positions, *, mode, enc_out=None):
    """Full-sequence path (train/prefill). Returns (x, cache_or_None, aux)."""
    cache = None
    aux = {}
    if kind in ("attn_mlp", "attn_moe", "attn_bidir_mlp", "attn_cross_mlp"):
        h = apply_norm(cfg, x, p["ln1"], "")
        q, k, v = _qkv(cfg, p["attn"], h)
        if cfg.rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
        causal = kind != "attn_bidir_mlp"
        o = flash_attention(
            q, k, v, causal=causal, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
        )
        x = x + o.reshape(*x.shape[:-1], -1) @ p["attn"]["wo"]
        if mode == "prefill":
            # caches are stored with a FLAT head dim (KV*hd): it divides evenly
            # by the 16-way model axis for every assigned arch, while raw KV
            # head counts (e.g. starcoder2's 4) do not.
            b_, s_ = x.shape[0], x.shape[1]
            cache = {"k": k.reshape(b_, s_, -1), "v": v.reshape(b_, s_, -1)}
        if kind == "attn_cross_mlp":
            hx = apply_norm(cfg, x, p["lnx"], "")
            qx = hx @ p["xattn"]["wq"]
            kx = enc_out @ p["xattn"]["wk"]
            vx = enc_out @ p["xattn"]["wv"]
            H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
            qx = qx.reshape(*hx.shape[:-1], H, hd)
            kx = kx.reshape(*enc_out.shape[:-1], KV, hd)
            vx = vx.reshape(*enc_out.shape[:-1], KV, hd)
            ox = flash_attention(
                qx, kx, vx, causal=False, q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk
            )
            x = x + ox.reshape(*x.shape[:-1], -1) @ p["xattn"]["wo"]
            if mode == "prefill":
                b_, s_ = x.shape[0], x.shape[1]
                se = enc_out.shape[1]
                cache = {
                    "k": k.reshape(b_, s_, -1),
                    "v": v.reshape(b_, s_, -1),
                    "xk": kx.reshape(b_, se, -1),
                    "xv": vx.reshape(b_, se, -1),
                }
    elif kind in ("mamba_mlp", "mamba_moe"):
        h = apply_norm(cfg, x, p["ln1"], "")
        y = ssm_lib.mamba_forward(cfg, p["mamba"], h)
        x = x + y
        if mode == "prefill":
            # re-derive final state cheaply: decode path will recompute; here we
            # carry the last conv window and rebuild h via a short suffix scan.
            cache = _mamba_state_from_seq(cfg, p["mamba"], h)
    elif kind == "mlstm":
        h = apply_norm(cfg, x, p["ln1"], "")
        x = x + xlstm_lib.mlstm_forward(cfg, p["mlstm"], h)
        if mode == "prefill":
            cache = _mlstm_state_from_seq(cfg, p["mlstm"], h)
    elif kind == "slstm":
        h = apply_norm(cfg, x, p["ln1"], "")
        x = x + xlstm_lib.slstm_forward(cfg, p["slstm"], h)
        if mode == "prefill":
            cache = _slstm_state_from_seq(cfg, p["slstm"], h)
    else:
        raise ValueError(kind)

    x, aux = _channel_mix(cfg, kind, p, x)
    return x, cache, aux


def block_apply_decode(cfg, kind, p, x, pos, state, *, enc_out=None):
    """One-token path. x: (b, d); state: block cache. Returns (x, new_state)."""
    KV, hd = cfg.n_kv_heads, cfg.hd
    if kind in ("attn_mlp", "attn_moe", "attn_cross_mlp"):
        h = apply_norm(cfg, x[:, None, :], p["ln1"], "")[:, 0]
        q, k, v = _qkv(cfg, p["attn"], h)  # (b, H/KV, hd)
        if cfg.rope:
            q = apply_rope(q[:, None], pos[:, None], cfg.rope_theta)[:, 0]
            k = apply_rope(k[:, None], pos[:, None], cfg.rope_theta)[:, 0]
        b = x.shape[0]
        # caches are flat (b, S, KV*hd); write the new row at pos
        k_cache = jax.vmap(lambda c, kk, pp: jax.lax.dynamic_update_slice(c, kk[None], (pp, 0)))(
            state["k"], k.reshape(b, -1), pos
        )
        v_cache = jax.vmap(lambda c, vv, pp: jax.lax.dynamic_update_slice(c, vv[None], (pp, 0)))(
            state["v"], v.reshape(b, -1), pos
        )
        S = k_cache.shape[1]
        o = decode_attention(
            q, k_cache.reshape(b, S, KV, hd), v_cache.reshape(b, S, KV, hd), pos
        )
        x = x + o.reshape(b, -1) @ p["attn"]["wo"]
        new_state = {"k": k_cache, "v": v_cache}
        if kind == "attn_cross_mlp":
            hx = apply_norm(cfg, x[:, None, :], p["lnx"], "")[:, 0]
            H = cfg.n_heads
            qx = (hx @ p["xattn"]["wq"]).reshape(b, H, hd)
            s_enc = state["xk"].shape[1]
            ox = decode_attention(
                qx,
                state["xk"].reshape(b, s_enc, KV, hd),
                state["xv"].reshape(b, s_enc, KV, hd),
                jnp.full((b,), s_enc - 1, jnp.int32),
            )
            x = x + ox.reshape(b, -1) @ p["xattn"]["wo"]
            new_state = {**new_state, "xk": state["xk"], "xv": state["xv"]}
    elif kind in ("mamba_mlp", "mamba_moe"):
        h = apply_norm(cfg, x[:, None, :], p["ln1"], "")[:, 0]
        y, new_state = ssm_lib.mamba_decode(cfg, p["mamba"], h, state)
        x = x + y
    elif kind == "mlstm":
        h = apply_norm(cfg, x[:, None, :], p["ln1"], "")[:, 0]
        y, new_state = xlstm_lib.mlstm_decode(cfg, p["mlstm"], h, state)
        x = x + y
    elif kind == "slstm":
        h = apply_norm(cfg, x[:, None, :], p["ln1"], "")[:, 0]
        y, new_state = xlstm_lib.slstm_decode(cfg, p["slstm"], h, state)
        x = x + y
    else:
        raise ValueError(kind)

    if kind.endswith("_moe"):
        h = apply_norm(cfg, x[:, None, :], p["ln2"], "")[:, 0]
        y, _ = moe_lib.moe_apply(cfg, p["moe"], h, capacity=h.shape[0])
        x = x + y
    elif kind.endswith("_mlp"):
        x = x + _mlp(cfg, p["mlp"], apply_norm(cfg, x[:, None, :], p["ln2"], "")[:, 0])
    return x, new_state


# ------------------------------------------------- prefill state reconstruction


def _mamba_state_from_seq(cfg, p, h_seq):
    b, s, _ = h_seq.shape
    K = cfg.ssm_conv
    xz = h_seq @ p["in_proj"]
    xi, _ = jnp.split(xz, 2, axis=-1)
    conv_win = xi[:, -(K - 1) :, :]
    # final SSM state: rerun the parallel scan and take the last element
    u = ssm_lib._causal_conv(p, xi, K)
    dt, B, C = ssm_lib._dt_b_c(cfg, p, u)
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt[..., None] * A)
    dBu = (dt * u.astype(jnp.float32))[..., None] * B[..., None, :]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, hh = jax.lax.associative_scan(combine, (dA, dBu), axis=1)
    return {"h": hh[:, -1], "conv": conv_win}


def _mlstm_state_from_seq(cfg, p, h_seq):
    # run the chunkwise forward's state recurrence; reuse forward then a final
    # fold would recompute -- instead scan decode over the last chunk only is
    # still O(s); for simplicity run the chunk recurrence directly.
    b, s, _ = h_seq.shape
    st = xlstm_lib.mlstm_init_state(cfg, b)

    def step(st, xt):
        _, st = xlstm_lib.mlstm_decode(cfg, p, xt, st)
        return st, None

    st, _ = jax.lax.scan(step, st, h_seq.transpose(1, 0, 2))
    return st


def _slstm_state_from_seq(cfg, p, h_seq):
    b = h_seq.shape[0]
    st = xlstm_lib.slstm_init_state(cfg, b, h_seq.dtype)

    def step(st, xt):
        st = xlstm_lib._slstm_cell(p, xt.astype(jnp.float32), st)
        return st, None

    st, _ = jax.lax.scan(step, st, h_seq.transpose(1, 0, 2))
    return st
