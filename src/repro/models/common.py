"""Shared building blocks: norms, RoPE, initializers."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layernorm(x, scale, bias, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    out = out + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(cfg, x, p, prefix):
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p[f"{prefix}_scale"])
    return layernorm(x, p[f"{prefix}_scale"], p[f"{prefix}_bias"])


def norm_params(cfg, d):
    if cfg.norm == "rmsnorm":
        return {"_scale": jnp.ones((d,), jnp.float32)}
    return {"_scale": jnp.ones((d,), jnp.float32), "_bias": jnp.zeros((d,), jnp.float32)}


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, hd, 2, dtype=np.float32) / hd))


def apply_rope(x, positions, theta):
    """x: (..., s, n_heads, hd); positions: (..., s) int32."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., s, hd/2)
    sin = jnp.sin(angles)[..., None, :]  # (..., s, 1, hd/2)
    cos = jnp.cos(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def dense_init(key, shape, in_axis=-2, dtype=jnp.float32):
    fan_in = shape[in_axis]
    std = float(1.0 / np.sqrt(fan_in))  # python float: weak type, preserves dtype
    return jax.random.normal(key, shape, dtype) * std


def split_keys(key, n):
    return list(jax.random.split(key, n))
