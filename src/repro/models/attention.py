"""GQA attention: chunked flash-style training/prefill path + cached decode path.

The training path is a blocked online-softmax attention executed as ONE
`lax.scan` over the STATIC list of valid (q-block, kv-block) pairs.  For causal
attention, blocks entirely above the diagonal are never enumerated, so -- unlike
the naive "scan everything and mask" formulation -- no FLOPs or score traffic
are spent on masked-out blocks (~2x attention compute saved at 32k; measured in
EXPERIMENTS.md SSPerf iteration 1).  Per-device live memory is
O(q_chunk * kv_chunk), which is what fits the prefill_32k cells into v5e HBM.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from ..distributed.constraints import constrain, tp_size

NEG_INF = -1e30


def _block_pairs(nq, nk, qc, kc, sk0, causal, q_offset):
    """Static list of (qi, ki) whose score block is not fully masked."""
    pairs = []
    for qi in range(nq):
        q_hi = q_offset + (qi + 1) * qc - 1  # highest query position in block
        for ki in range(nk):
            k_lo = ki * kc
            if k_lo >= sk0:
                continue  # fully-padded kv block
            if causal and k_lo > q_hi:
                continue  # fully above the diagonal
            pairs.append((qi, ki))
    return pairs


def flash_attention(q, k, v, *, causal=True, q_offset=0, q_chunk=512, kv_chunk=1024):
    """q: (b, sq, H, hd); k, v: (b, sk, KV, hd) with H % KV == 0.

    ``q_offset``: absolute position of q[0] relative to k[0] (for chunked
    prefill continuation).  Returns (b, sq, H, hd) in q.dtype.
    """
    b, sq0, H, hd = q.shape
    sk0, KV = k.shape[1], k.shape[2]
    G = H // KV
    qc = min(q_chunk, sq0)
    kc = min(kv_chunk, sk0)
    # pad ragged sequence lengths up to chunk multiples; padded keys are masked
    pq, pk = (-sq0) % qc, (-sk0) % kc
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    sq, sk = sq0 + pq, sk0 + pk
    nq, nk = sq // qc, sk // kc

    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    qr = q.reshape(b, nq, qc, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)  # (nq,b,qc,KV,G,hd)
    kr = k.reshape(b, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)  # (nk,b,kc,KV,hd)
    vr = v.reshape(b, nk, kc, KV, hd).transpose(1, 0, 2, 3, 4)
    # GQA sharding strategy (see EXPERIMENTS.md SSPerf iteration 2):
    #   * KV divisible by the model axis (MHA-ish): shard HEADS -- scores local.
    #   * KV smaller (GQA, e.g. 4 kv heads on a 16-way axis): unconstrained
    #     GSPMD shards the score CONTRACTION (hd) and all-reduces a full score
    #     block per (q,k) pair (measured: 1.3 TB/device on starcoder2-7b
    #     prefill_32k).  Instead shard q's within-block rows (qc) on the model
    #     axis and replicate the small kv blocks -- scores entirely local.
    tp = tp_size()
    head_sharded = tp is not None and KV % tp == 0
    seq_sharded = tp is not None and not head_sharded and qc % tp == 0
    if head_sharded:
        qr = constrain(qr, None, "dp", None, "tp", None, None)
        kr = constrain(kr, None, "dp", None, "tp", None)
        vr = constrain(vr, None, "dp", None, "tp", None)
    elif seq_sharded:
        qr = constrain(qr, None, "dp", "tp", None, None, None)
        kr = constrain(kr, None, "dp", None, None, None)
        vr = constrain(vr, None, "dp", None, None, None)

    pairs = _block_pairs(nq, nk, qc, kc, sk0, causal, q_offset)
    qi_arr = jnp.asarray(np.array([p[0] for p in pairs], np.int32))
    ki_arr = jnp.asarray(np.array([p[1] for p in pairs], np.int32))
    # a pair starts a new q-block iff its qi differs from the previous pair's
    first_arr = jnp.asarray(
        np.array([i == 0 or pairs[i][0] != pairs[i - 1][0] for i in range(len(pairs))]))

    q_pos0 = jnp.arange(qc, dtype=jnp.int32)
    k_pos0 = jnp.arange(kc, dtype=jnp.int32)

    m0 = jnp.full((b, KV, G, qc), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, KV, G, qc), jnp.float32)
    a0 = jnp.zeros((b, KV, G, qc, hd), jnp.float32)
    out0 = jnp.zeros((nq, b, qc, H, hd), q.dtype)

    def pair_step(carry, xs):
        m, l, acc, out = carry
        qi, ki, first = xs
        # reset the online-softmax state at the start of each q-block
        m = jnp.where(first, m0, m)
        l = jnp.where(first, l0, l)
        acc = jnp.where(first, a0, acc)

        qb = jax.lax.dynamic_index_in_dim(qr, qi, 0, keepdims=False)
        kb = jax.lax.dynamic_index_in_dim(kr, ki, 0, keepdims=False)
        vb = jax.lax.dynamic_index_in_dim(vr, ki, 0, keepdims=False)
        qb32 = qb.astype(jnp.float32) * scale

        s = jnp.einsum("bqKGh,bkKh->bKGqk", qb32, kb.astype(jnp.float32))
        if head_sharded:
            s = constrain(s, "dp", "tp", None, None, None)
        elif seq_sharded:
            s = constrain(s, "dp", None, None, "tp", None)
        q_pos = q_offset + qi * qc + q_pos0  # (qc,)
        k_pos = ki * kc + k_pos0
        if causal:
            mask = k_pos[None, :] > q_pos[:, None]
        else:
            mask = jnp.zeros((qc, kc), bool)
        mask = mask | (k_pos >= sk0)[None, :]  # padded keys
        s = jnp.where(mask[None, None, None], NEG_INF, s)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bKGqk,bkKh->bKGqh", p, vb.astype(jnp.float32))
        m = m_new

        # normalize and write this q-block's running output; the LAST pair of
        # the block performs the final (correct) write
        o = acc / jnp.maximum(l[..., None], 1e-30)  # (b,KV,G,qc,hd)
        o = o.transpose(0, 3, 1, 2, 4).reshape(b, qc, H, hd).astype(q.dtype)
        out = jax.lax.dynamic_update_index_in_dim(out, o, qi, 0)
        return (m, l, acc, out), None

    (_, _, _, out), _ = jax.lax.scan(pair_step, (m0, l0, a0, out0),
                                     (qi_arr, ki_arr, first_arr))
    if head_sharded:
        out = constrain(out, None, "dp", None, "tp", None)
    elif seq_sharded:
        out = constrain(out, None, "dp", "tp", None, None)
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, H, hd)[:, :sq0]


def decode_attention(q, k_cache, v_cache, pos):
    """Single-token attention against a (possibly padded) KV cache.

    q: (b, H, hd); k_cache, v_cache: (b, S, KV, hd); pos: (b,) number of valid
    cache entries (the new token's position).  Returns (b, H, hd).
    """
    b, H, hd = q.shape
    S, KV = k_cache.shape[1], k_cache.shape[2]
    G = H // KV
    qr = q.reshape(b, KV, G, hd).astype(jnp.float32) / jnp.sqrt(float(hd))
    s = jnp.einsum("bKGh,bsKh->bKGs", qr, k_cache.astype(jnp.float32))
    valid = jnp.arange(S)[None, :] <= pos[:, None]  # (b, S)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bKGs,bsKh->bKGh", p, v_cache.astype(jnp.float32))
    return out.reshape(b, H, hd).astype(q.dtype)
