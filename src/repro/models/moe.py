"""Mixture-of-Experts layer: shared experts + routed top-k with sort-based
capacity dispatch.

Dispatch avoids the O(T*E) one-hot tensors of einsum-style MoE (which would be
~1.5 TB for kimi-k2's 1M tokens x 384 experts): token->expert assignments are
sorted by expert id, each token gets a position-within-expert, and tokens are
scattered into an (E, C, d) buffer that is expert-sharded on the model axis
(expert parallelism).  Tokens beyond capacity C are dropped (weight 0), the
standard capacity-factor policy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..distributed.constraints import current_mesh, logical_axes
from .common import dense_init, split_keys


def moe_params(key, cfg, dtype):
    m = cfg.moe
    d, e, h = cfg.d_model, m.n_experts, m.d_expert
    ks = split_keys(key, 6)
    p = {
        "router": dense_init(ks[0], (d, e), dtype=jnp.float32),  # router in f32
        "w_in": dense_init(ks[1], (e, d, h), dtype=dtype),
        "w_gate": dense_init(ks[2], (e, d, h), dtype=dtype),
        "w_out": dense_init(ks[3], (e, h, d), dtype=dtype),
    }
    if m.n_shared > 0:
        hs = m.n_shared * h
        p["shared_in"] = dense_init(ks[4], (d, hs), dtype=dtype)
        p["shared_gate"] = dense_init(ks[5], (d, hs), dtype=dtype)
        p["shared_out"] = dense_init(ks[4], (hs, d), dtype=dtype)
    return p


def moe_apply(cfg, p, x, capacity=None):
    """x: (T, d) tokens; returns (T, d) plus aux losses dict.

    ``capacity`` overrides the capacity-factor policy; decode passes T so a
    single-token step can never drop (an expert receives at most T tokens).

    Under an active launcher mesh (activation_sharding context) and a
    divisible expert count, dispatch goes through the shard_map
    expert-parallel path (_moe_apply_shardmap): per-device local routing +
    ONE psum of the combined output -- ideal EP traffic, instead of GSPMD's
    mask+all-reduce implementation of cross-shard gathers (SSPerf iteration 6)."""
    mesh = current_mesh()
    tp_name = "model"
    if (
        capacity is None
        and mesh is not None
        and tp_name in getattr(mesh, "axis_names", ())
        and cfg.moe.n_experts % mesh.shape[tp_name] == 0
    ):
        dp_ax, _ = logical_axes()
        dp_ax = tuple(a for a in (dp_ax or ()) if a in mesh.axis_names)
        import numpy as _np

        dp_size = int(_np.prod([mesh.shape[a] for a in dp_ax])) if dp_ax else 1
        if x.shape[0] % max(dp_size, 1) == 0 and x.shape[0] // max(dp_size, 1) >= 1:
            return _moe_apply_shardmap(cfg, p, x, mesh, dp_ax, tp_name)
    return _moe_apply_gspmd(cfg, p, x, capacity)


def _moe_apply_gspmd(cfg, p, x, capacity=None):
    m = cfg.moe
    T, d = x.shape
    E, k = m.n_experts, m.top_k
    C = capacity if capacity is not None else max(1, int(m.capacity_factor * k * T / E))

    # router matmul in x's dtype (softmax in f32): an f32 branch of x here
    # would promote x's ENTIRE backward cotangent to f32, doubling every MoE
    # collective (measured on kimi-k2; SSPerf iteration 5)
    logits = (x @ p["router"].astype(x.dtype)).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, k)  # (T, k)
    topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)  # renormalize

    # --- sort-based position-within-expert -------------------------------
    # All wide (d-dim) data movement below is GATHER-shaped; the only scatters
    # are 1-D int32.  (A 2-D scatter into the (E*C, d) buffer lowers to a
    # materialized u32[E*C, d] index tensor -- measured at 300 GB/layer for
    # kimi-k2 -- and the combine scatter-add is unnecessary because
    # flat_t == repeat(arange(T), k), i.e. combine is a reshape.)
    flat_e = topi.reshape(-1)  # (T*k,), entry j belongs to token j // k
    order = jnp.argsort(flat_e, stable=True)  # (T*k,)
    sorted_e = flat_e[order]
    seg_start = jnp.searchsorted(sorted_e, jnp.arange(E), side="left")  # (E,)
    pos_sorted = (jnp.arange(T * k) - seg_start[sorted_e]).astype(jnp.int32)
    keep_sorted = pos_sorted < C
    slot_sorted = jnp.where(keep_sorted, sorted_e * C + pos_sorted, E * C)

    # invert the placement: buffer slot -> sorted index (1-D scatter), then
    # fill the expert buffer with a gather
    inv = jnp.zeros((E * C + 1,), jnp.int32).at[slot_sorted].set(
        jnp.arange(T * k, dtype=jnp.int32), mode="drop"
    )
    counts = jnp.diff(jnp.concatenate([seg_start, jnp.array([T * k])]))  # (E,)
    valid = jnp.arange(C)[None, :] < jnp.minimum(counts, C)[:, None]  # (E, C)
    src_tok = order[inv[: E * C]] // k  # (E*C,) source token per buffer slot
    # NOTE: constraining xe to an expert-parallel layout here was tried and
    # REGRESSED 3-4x (SSPerf iteration 5 follow-up, refuted): GSPMD resolves
    # the forced resharding of the dispatch gather via full rematerialization.
    # The proper fix is the shard_map path above (_moe_apply_shardmap), which
    # is used whenever a launcher mesh is active.
    xe = x[src_tok].reshape(E, C, d) * valid[..., None].astype(x.dtype)

    # --- expert computation (expert axis shards on the model mesh axis) ---
    h = jnp.einsum("ecd,edh->ech", xe, p["w_in"])
    g = jnp.einsum("ecd,edh->ech", xe, p["w_gate"])
    ye = jnp.einsum("ech,ehd->ecd", jax.nn.silu(g) * h, p["w_out"])  # (E, C, d)

    # --- combine: gather expert rows back, weighted sum over k (a reshape,
    # NOT a scatter-add, thanks to the repeat layout of flat_e) -------------
    slot_flat = jnp.zeros((T * k,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
    kept = (slot_flat < E * C)
    rows = ye.reshape(E * C, d)[jnp.minimum(slot_flat, E * C - 1)]  # (T*k, d)
    w = (topw.reshape(-1) * kept).astype(x.dtype)
    out = jnp.sum(rows.reshape(T, k, d) * w.reshape(T, k, 1), axis=1)

    # --- shared experts ----------------------------------------------------
    if m.n_shared > 0:
        hs = x @ p["shared_in"]
        gs = x @ p["shared_gate"]
        out = out + (jax.nn.silu(gs) * hs) @ p["shared_out"]

    # load-balance (Switch) aux loss
    me = probs.mean(0)  # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(topw.reshape(-1)) / T
    aux = {"moe_balance": E * jnp.sum(me * ce)}
    return out, aux


def _moe_apply_shardmap(cfg, p, x, mesh, dp_ax, tp_name):
    """Expert-parallel dispatch under jax.shard_map.

    Layout: tokens sharded over the data axes, replicated over the model axis;
    experts sharded over the model axis.  Every device routes ITS tokens,
    serves the subset destined for ITS experts, and the partial combined
    outputs are summed with ONE psum over the model axis -- per-device wire
    traffic ~= 2 * T_loc * d, the EP lower bound.  Capacity is per
    (data-shard, expert), a standard locality-friendly drop policy.
    """
    import numpy as _np

    m = cfg.moe
    T, d = x.shape
    E, k = m.n_experts, m.top_k
    tp = mesh.shape[tp_name]
    E_loc = E // tp
    dp_size = int(_np.prod([mesh.shape[a] for a in dp_ax])) if dp_ax else 1
    T_loc = T // dp_size
    C = max(1, int(m.capacity_factor * k * T_loc / E))

    def local_fn(x_loc, router, w_in, w_gate, w_out):
        # x_loc: (T_loc, d); w_*: (E_loc, ...) local expert slices
        midx = jax.lax.axis_index(tp_name)
        logits = (x_loc @ router.astype(x_loc.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topw, topi = jax.lax.top_k(probs, k)
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

        flat_e = topi.reshape(-1)  # (T_loc*k,) global expert ids
        le = flat_e - midx * E_loc
        is_local = (le >= 0) & (le < E_loc)
        le = jnp.where(is_local, le, E_loc).astype(jnp.int32)  # E_loc = drop bucket

        order = jnp.argsort(le, stable=True)
        sorted_e = le[order]
        seg_start = jnp.searchsorted(sorted_e, jnp.arange(E_loc), side="left")
        pos_sorted = (jnp.arange(T_loc * k) - seg_start[jnp.minimum(sorted_e, E_loc - 1)]).astype(jnp.int32)
        keep = (pos_sorted < C) & (sorted_e < E_loc)
        slot_sorted = jnp.where(keep, sorted_e * C + pos_sorted, E_loc * C)

        inv = jnp.zeros((E_loc * C + 1,), jnp.int32).at[slot_sorted].set(
            jnp.arange(T_loc * k, dtype=jnp.int32), mode="drop")
        counts = jnp.diff(jnp.concatenate([seg_start, jnp.array([T_loc * k])]))
        valid = jnp.arange(C)[None, :] < jnp.minimum(counts, C)[:, None]
        src_tok = order[inv[: E_loc * C]] // k
        xe = x_loc[src_tok].reshape(E_loc, C, d) * valid[..., None].astype(x_loc.dtype)

        h = jnp.einsum("ecd,edh->ech", xe, w_in)
        g = jnp.einsum("ecd,edh->ech", xe, w_gate)
        ye = jnp.einsum("ech,ehd->ecd", jax.nn.silu(g) * h, w_out)

        slot_flat = jnp.zeros((T_loc * k,), jnp.int32).at[order].set(
            slot_sorted.astype(jnp.int32))
        kept = slot_flat < E_loc * C
        rows = ye.reshape(E_loc * C, d)[jnp.minimum(slot_flat, E_loc * C - 1)]
        w = (topw.reshape(-1) * kept).astype(x_loc.dtype)
        part = jnp.sum(rows.reshape(T_loc, k, d) * w.reshape(T_loc, k, 1), axis=1)
        out = jax.lax.psum(part, tp_name)

        # load-balance aux: identical on every model shard (router replicated)
        me = probs.mean(0)
        ce = jnp.zeros((E,), jnp.float32).at[flat_e].add(topw.reshape(-1)) / T_loc
        aux = E * jnp.sum(me * ce)
        if dp_ax:
            aux = jax.lax.pmean(aux, dp_ax)
        return out, aux

    dp_spec = dp_ax if dp_ax else None
    out, aux_val = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(dp_spec, None),
            P(None, None),
            P(tp_name, None, None),
            P(tp_name, None, None),
            P(tp_name, None, None),
        ),
        out_specs=(P(dp_spec, None), P()),
        check_vma=False,
    )(x, p["router"], p["w_in"], p["w_gate"], p["w_out"])

    if m.n_shared > 0:
        hs = x @ p["shared_in"]
        gs = x @ p["shared_gate"]
        out = out + (jax.nn.silu(gs) * hs) @ p["shared_out"]
    return out, {"moe_balance": aux_val}
