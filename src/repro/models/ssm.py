"""Mamba selective-SSM block (jamba's sequence mixer).

Training/prefill uses a parallel associative scan over time (the TPU-native
replacement for the CUDA selective-scan kernel): the recurrence
h_t = exp(dt_t * A) h_{t-1} + dt_t * B_t x_t  is a first-order linear
recurrence with diagonal transition, which `lax.associative_scan` evaluates in
O(log s) depth.  d_inner shards on the model axis, so the (b, s, d_inner, N)
scan elements stay within per-device HBM.

Decode carries (h, conv window) state and costs O(1) per token -- this is what
makes jamba a `subquadratic` arch for the long_500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys


def mamba_params(key, cfg, dtype):
    d, di, N, R, K = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.dt_rank_, cfg.ssm_conv
    ks = split_keys(key, 7)

    return {
        "in_proj": dense_init(ks[0], (d, 2 * di), dtype=dtype),
        "conv_w": dense_init(ks[1], (K, di), dtype=dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], (di, R + 2 * N), dtype=dtype),
        "dt_proj": dense_init(ks[3], (R, di), dtype=dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(jnp.arange(1, N + 1, dtype=jnp.float32), (di, N)).copy()),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], (di, d), dtype=dtype),
    }


def _ssm_inputs(cfg, p, x):
    """Shared front half: projections, conv, dt/B/C. x: (b, s, d)."""
    N, R = cfg.ssm_state, cfg.dt_rank_
    xz = x @ p["in_proj"]  # (b, s, 2*di)
    xi, z = jnp.split(xz, 2, axis=-1)
    return xi, z


def _dt_b_c(cfg, p, u):
    N, R = cfg.ssm_state, cfg.dt_rank_
    dbc = u @ p["x_proj"]  # (b, s, R+2N)
    dt = jax.nn.softplus(
        dbc[..., :R] @ p["dt_proj"] + p["dt_bias"].astype(dbc.dtype)
    ).astype(jnp.float32)  # (b, s, di)
    B = dbc[..., R : R + N].astype(jnp.float32)  # (b, s, N)
    C = dbc[..., R + N :].astype(jnp.float32)
    return dt, B, C


def _causal_conv(p, u, K):
    """u: (b, s, di); depthwise causal conv, width K."""
    pad = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i : i + u.shape[1], :] * p["conv_w"][i] for i in range(K))
    return jax.nn.silu(out + p["conv_b"])


def mamba_forward(cfg, p, x):
    """Parallel (training/prefill) path. x: (b, s, d) -> (b, s, d)."""
    A = -jnp.exp(p["A_log"])  # (di, N)
    xi, z = _ssm_inputs(cfg, p, x)
    u = _causal_conv(p, xi, cfg.ssm_conv)  # (b, s, di)
    dt, B, C = _dt_b_c(cfg, p, u)

    uf = u.astype(jnp.float32)
    # discretize: a_t = exp(dt*A) (b,s,di,N); b_t = dt*B*u
    dA = jnp.exp(dt[..., None] * A)  # (b, s, di, N)
    dBu = (dt * uf)[..., None] * B[..., None, :]  # (b, s, di, N)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    _, h = jax.lax.associative_scan(combine, (dA, dBu), axis=1)  # (b, s, di, N)
    y = jnp.einsum("bsdn,bsn->bsd", h, C) + uf * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    return y @ p["out_proj"]


def mamba_init_state(cfg, batch, dtype):
    di, N, K = cfg.d_inner, cfg.ssm_state, cfg.ssm_conv
    return {
        "h": jnp.zeros((batch, di, N), jnp.float32),
        "conv": jnp.zeros((batch, K - 1, di), dtype),
    }


def mamba_decode(cfg, p, x, state):
    """One-token step. x: (b, d) -> (b, d); state carries (h, conv window)."""
    K, N = cfg.ssm_conv, cfg.ssm_state
    A = -jnp.exp(p["A_log"])
    xz = x @ p["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)  # (b, di)

    win = jnp.concatenate([state["conv"], xi[:, None, :]], axis=1)  # (b, K, di)
    u = jax.nn.silu(jnp.einsum("bkd,kd->bd", win, p["conv_w"]) + p["conv_b"])

    dbc = u @ p["x_proj"]
    R = cfg.dt_rank_
    dt = jax.nn.softplus(dbc[..., :R] @ p["dt_proj"] + p["dt_bias"].astype(dbc.dtype)).astype(
        jnp.float32
    )
    B = dbc[..., R : R + N].astype(jnp.float32)
    C = dbc[..., R + N :].astype(jnp.float32)

    uf = u.astype(jnp.float32)
    dA = jnp.exp(dt[..., None] * A)  # (b, di, N)
    h = state["h"] * dA + (dt * uf)[..., None] * B[:, None, :]
    y = jnp.einsum("bdn,bn->bd", h, C) + uf * p["D"]
    y = y.astype(x.dtype) * jax.nn.silu(z)
    new_state = {"h": h, "conv": win[:, 1:, :]}
    return y @ p["out_proj"], new_state
