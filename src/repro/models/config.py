"""Architecture configuration for the assigned model pool.

A model is described by a *block pattern* -- the sequence of block kinds in one
period -- repeated ``n_layers / len(pattern)`` times.  The layer stack is
executed as a ``lax.scan`` over periods with parameters stacked on a leading
period axis, which keeps the HLO size independent of depth (essential for
compiling 40-61 layer models with 512 host devices).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    n_shared: int  # shared (always-on) experts
    d_expert: int  # hidden width of each expert
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    # block kinds for one period; see models/transformer.py for kinds
    pattern: tuple[str, ...] = ("attn_mlp",)
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    rope: bool = True
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    mlp: str = "swiglu"  # swiglu | gelu
    moe: Optional[MoECfg] = None
    # SSM (mamba) block geometry
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    dt_rank: Optional[int] = None
    # xLSTM geometry
    xlstm_proj: int = 2
    # encoder-decoder (whisper): n_layers counts EACH stack
    enc_dec: bool = False
    # vlm: number of image-embedding tokens provided by the (stub) frontend
    n_img_tokens: int = 0
    # audio: frontend provides precomputed frame embeddings (stub)
    audio_frontend: bool = False
    # continuous-depth mode: integrate the block stack as a neural ODE with the
    # repro.core parallel solver (research option; used on reduced configs)
    ode_depth: bool = False
    ode_steps: int = 8
    # compute dtype for activations/weights in compiled programs
    dtype: str = "bfloat16"
    # attention chunking (flash-style scan) block sizes
    q_chunk: int = 512
    kv_chunk: int = 1024
    # does the arch support sub-quadratic long-context decode?
    subquadratic: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        assert self.n_layers % len(self.pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.pattern)}"
        )
        return self.n_layers // len(self.pattern)

    @property
    def d_inner(self) -> int:  # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def dt_rank_(self) -> int:
        return self.dt_rank if self.dt_rank is not None else max(1, self.d_model // 16)


# Input-shape cells assigned to every LM arch (seq_len, global_batch, kind).
SHAPES = {
    "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": dict(seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": dict(seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": dict(seq_len=524288, global_batch=1, kind="decode"),
}
