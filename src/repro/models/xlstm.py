"""xLSTM blocks: mLSTM (matrix memory, chunkwise-parallel) and sLSTM (scalar
memory, sequential recurrence).

mLSTM training runs in chunkwise-recurrent form (the TPU-native version of the
TFLA/kernel formulation): within a chunk the contribution is a decay-weighted
quadratic form (MXU matmuls); across chunks a small (hd x hd) matrix state is
carried by a `lax.scan`.  All exponentials are stabilized with the running
log-magnitude m, as in the xLSTM paper.

sLSTM is an inherently sequential recurrence (gates depend on h_{t-1}); it runs
as a `lax.scan` over time.  Both support O(1)-state decode, which is what makes
xlstm-350m a `subquadratic` arch eligible for the long_500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, split_keys

NEG = -1e30


# ----------------------------------------------------------------- mLSTM


def mlstm_params(key, cfg, dtype):
    d = cfg.d_model
    H = cfg.n_heads
    dp = cfg.xlstm_proj * d  # projected width
    ks = split_keys(key, 8)
    return {
        "up": dense_init(ks[0], (d, dp), dtype=dtype),
        "wq": dense_init(ks[1], (dp, dp), dtype=dtype),
        "wk": dense_init(ks[2], (dp, dp), dtype=dtype),
        "wv": dense_init(ks[3], (dp, dp), dtype=dtype),
        "wi": dense_init(ks[4], (dp, H), dtype=jnp.float32),
        "wf": dense_init(ks[5], (dp, H), dtype=jnp.float32),
        "wo": dense_init(ks[6], (dp, dp), dtype=dtype),
        "down": dense_init(ks[7], (dp, d), dtype=dtype),
    }


def _mlstm_qkvif(cfg, p, x):
    H = cfg.n_heads
    up = x @ p["up"]  # (..., dp)
    dp = up.shape[-1]
    hd = dp // H
    q = (up @ p["wq"]).reshape(*up.shape[:-1], H, hd)
    k = (up @ p["wk"]).reshape(*up.shape[:-1], H, hd) / jnp.sqrt(float(hd))
    v = (up @ p["wv"]).reshape(*up.shape[:-1], H, hd)
    li = (up.astype(jnp.float32) @ p["wi"])  # log input gate preact (..., H)
    lf = jax.nn.log_sigmoid(up.astype(jnp.float32) @ p["wf"])  # log forget (..., H)
    return up, q, k, v, li, lf


def mlstm_forward(cfg, p, x, chunk=256):
    """x: (b, s, d) -> (b, s, d), chunkwise-parallel."""
    b, s, d = x.shape
    H = cfg.n_heads
    L = min(chunk, s)
    assert s % L == 0
    nC = s // L

    up, q, k, v, li, lf = _mlstm_qkvif(cfg, p, x)
    hd = q.shape[-1]

    # reshape into chunks: (nC, b, H, L, ...)
    def chunked(t, feat):
        return t.reshape(b, nC, L, H, *feat).transpose(1, 0, 3, 2, *range(4, 4 + len(feat)))

    qc = chunked(q, (hd,)).astype(jnp.float32)
    kc = chunked(k, (hd,)).astype(jnp.float32)
    vc = chunked(v, (hd,)).astype(jnp.float32)
    lic = li.reshape(b, nC, L, H).transpose(1, 0, 3, 2)  # (nC, b, H, L)
    lfc = lf.reshape(b, nC, L, H).transpose(1, 0, 3, 2)

    tri = jnp.tril(jnp.ones((L, L), bool))
    C0 = jnp.zeros((b, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((b, H, hd), jnp.float32)
    m0 = jnp.full((b, H), NEG, jnp.float32)

    def chunk_step(carry, xs):
        C, n, m = carry  # C: (b,H,hd,hd); n: (b,H,hd); m: (b,H)
        qj, kj, vj, lij, lfj = xs
        cum = jnp.cumsum(lfj, axis=-1)  # (b,H,L) inclusive decay from chunk start
        # g[t, j] = cum_t - cum_j + li_j   (decay of contribution j at time t)
        g = cum[..., :, None] - cum[..., None, :] + lij[..., None, :]
        g = jnp.where(tri, g, NEG)
        m_inter = cum + m[..., None]  # (b,H,L): log-magnitude of inter-chunk path
        m_t = jnp.maximum(jnp.max(g, axis=-1), m_inter)  # (b,H,L)

        S = jnp.exp(g - m_t[..., None])  # (b,H,L,L)
        qk = jnp.einsum("bhte,bhje->bhtj", qj, kj)
        num = jnp.einsum("bhtj,bhjv->bhtv", S * qk, vj)
        num = num + jnp.exp(m_inter - m_t)[..., None] * jnp.einsum(
            "bhte,bhev->bhtv", qj, C
        )
        den_vec = jnp.einsum("bhtj,bhje->bhte", S, kj) + jnp.exp(m_inter - m_t)[
            ..., None
        ] * n[..., None, :]
        den = jnp.abs(jnp.einsum("bhte,bhte->bht", qj, den_vec))
        den = jnp.maximum(den, jnp.exp(-m_t))
        h = num / den[..., None]  # (b,H,L,hd)

        # ---- state update to chunk end ----
        cum_L = cum[..., -1]  # (b,H)
        gk = cum_L[..., None] - cum + lij  # (b,H,L) decay of j to chunk end
        m_new = jnp.maximum(cum_L + m, jnp.max(gk, axis=-1))
        w = jnp.exp(gk - m_new[..., None])  # (b,H,L)
        C_new = jnp.exp(cum_L + m - m_new)[..., None, None] * C + jnp.einsum(
            "bhj,bhje,bhjv->bhev", w, kj, vj
        )
        n_new = jnp.exp(cum_L + m - m_new)[..., None] * n + jnp.einsum(
            "bhj,bhje->bhe", w, kj
        )
        return (C_new, n_new, m_new), h

    (_, _, _), hs = jax.lax.scan(chunk_step, (C0, n0, m0), (qc, kc, vc, lic, lfc))
    # hs: (nC, b, H, L, hd) -> (b, s, dp)
    dp = H * hd
    h = hs.transpose(1, 0, 3, 2, 4).reshape(b, s, dp).astype(x.dtype)
    out = h * jax.nn.silu(up @ p["wo"])
    return out @ p["down"]


def mlstm_init_state(cfg, batch):
    H = cfg.n_heads
    hd = cfg.xlstm_proj * cfg.d_model // H
    return {
        "C": jnp.zeros((batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, H, hd), jnp.float32),
        "m": jnp.full((batch, H), NEG, jnp.float32),
    }


def mlstm_decode(cfg, p, x, state):
    """x: (b, d) one token; O(1) state update."""
    up, q, k, v, li, lf = _mlstm_qkvif(cfg, p, x)  # leaves (b, H, hd) / (b, H)
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    fw = jnp.exp(lf + m - m_new)[..., None]
    iw = jnp.exp(li - m_new)[..., None]
    qf, kf, vf = q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32)
    C_new = fw[..., None] * C + iw[..., None] * jnp.einsum("bhe,bhv->bhev", kf, vf)
    n_new = fw * n + iw * kf
    num = jnp.einsum("bhe,bhev->bhv", qf, C_new)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhe,bhe->bh", qf, n_new)), jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(x.shape[0], -1).astype(x.dtype)
    out = h * jax.nn.silu(up @ p["wo"])
    return out @ p["down"], {"C": C_new, "n": n_new, "m": m_new}


# ----------------------------------------------------------------- sLSTM


def slstm_params(key, cfg, dtype):
    d = cfg.d_model
    ks = split_keys(key, 9)
    p = {"r_" + g: dense_init(ks[i], (d, d), dtype=dtype) for i, g in enumerate("zifo")}
    p.update({"w_" + g: dense_init(ks[4 + i], (d, d), dtype=dtype) for i, g in enumerate("zifo")})
    p["out"] = dense_init(ks[8], (d, d), dtype=dtype)
    return p


def slstm_init_state(cfg, batch, dtype):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), NEG, jnp.float32)}


def _slstm_cell(p, xt, st):
    """xt: (b, d) f32 pre-projected gate inputs; st: state dict."""
    h = st["h"]
    zt = jnp.tanh(xt @ p["w_z"].astype(jnp.float32) + h @ p["r_z"].astype(jnp.float32))
    it = xt @ p["w_i"].astype(jnp.float32) + h @ p["r_i"].astype(jnp.float32)
    ft = xt @ p["w_f"].astype(jnp.float32) + h @ p["r_f"].astype(jnp.float32)
    ot = jax.nn.sigmoid(xt @ p["w_o"].astype(jnp.float32) + h @ p["r_o"].astype(jnp.float32))
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + st["m"], it)
    iw = jnp.exp(it - m_new)
    fw = jnp.exp(lf + st["m"] - m_new)
    c = fw * st["c"] + iw * zt
    n = jnp.maximum(fw * st["n"] + iw, jnp.exp(-m_new))
    h_new = ot * c / n
    return {"c": c, "n": n, "h": h_new, "m": m_new}


def slstm_forward(cfg, p, x):
    """x: (b, s, d) -> (b, s, d); sequential scan over time."""
    b, s, d = x.shape
    xf = x.astype(jnp.float32)
    st0 = slstm_init_state(cfg, b, x.dtype)

    def step(st, xt):
        st = _slstm_cell(p, xt, st)
        return st, st["h"]

    _, hs = jax.lax.scan(step, st0, xf.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)
    return h @ p["out"]


def slstm_decode(cfg, p, x, state):
    st = _slstm_cell(p, x.astype(jnp.float32), state)
    return st["h"].astype(x.dtype) @ p["out"], st
