"""Top-level LM: embedding, scan-over-periods stack, tied unembedding.

Public entry points (all pure functions of (cfg, params, ...)):

  init_params(cfg, key)                 -> params pytree
  forward(cfg, params, batch)           -> (logits, aux)      [train]
  prefill(cfg, params, batch)           -> (last_logits, cache)
  decode_step(cfg, params, token, pos, cache [, batch]) -> (logits, cache)

``batch`` is a dict: tokens (b, s) int32, plus modality stubs --
img_embeds (b, n_img, d) for vlm, audio_embeds (b, s_enc, d) for audio.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ssm as ssm_lib
from . import xlstm as xlstm_lib
from ..distributed.constraints import constrain
from .common import dense_init, norm_params, apply_norm
from .config import ArchConfig
from .transformer import block_apply_decode, block_apply_seq, block_params


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------------------- params


def init_params(cfg: ArchConfig, key) -> dict:
    dtype = _dtype(cfg)
    kE, kB, kEnc, kF = jax.random.split(key, 4)
    params = {
        "embed": dense_init(kE, (cfg.vocab, cfg.d_model), in_axis=-1, dtype=dtype),
        "final_norm": norm_params(cfg, cfg.d_model),
    }

    def stack_blocks(key):
        keys = jax.random.split(key, cfg.n_periods)

        def one_period(k):
            pk = jax.random.split(k, len(cfg.pattern))
            return {
                f"b{i}": block_params(kind, pk[i], cfg, dtype)
                for i, kind in enumerate(cfg.pattern)
            }

        return jax.vmap(one_period)(jnp.stack(keys))

    params["blocks"] = stack_blocks(kB)
    if cfg.enc_dec:
        # encoder stack is bidirectional attention with the same geometry
        enc_cfg = cfg
        keys = jax.random.split(kEnc, cfg.n_periods)

        def one_enc(k):
            return {"b0": block_params("attn_bidir_mlp", k, enc_cfg, dtype)}

        params["enc_blocks"] = jax.vmap(one_enc)(jnp.stack(keys))
        params["enc_final_norm"] = norm_params(cfg, cfg.d_model)
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ------------------------------------------------------------------- helpers


def _embed_tokens(cfg, params, batch):
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    if cfg.n_img_tokens > 0 and "img_embeds" in batch:
        n = cfg.n_img_tokens
        img = batch["img_embeds"].astype(x.dtype)
        x = jnp.concatenate([img, x[:, n:, :]], axis=1)
    return x


def _run_stack(cfg, params_blocks, x, positions, *, mode, enc_out=None, remat=False):
    """scan over periods; returns (x, caches, aux_sum).

    ``remat=True`` checkpoints each PERIOD: only period-boundary residuals are
    saved; everything inside a period is recomputed in the backward pass.  This
    is the per-layer policy (whole-forward checkpointing would materialize all
    layers' recomputed intermediates at once -- measured at ~3 TB/device for
    stablelm train_4k)."""
    aux0 = {"moe_balance": jnp.zeros((), jnp.float32)} if cfg.moe is not None else {}

    def period_fn(carry, pparams):
        x, aux_acc = carry
        # anchor the residual stream: batch on dp, d_model replicated (see
        # distributed/constraints.py -- keeps FSDP weight shardings out of
        # the activations)
        x = constrain(x, "dp", None, None)
        caches = {}
        for i, kind in enumerate(cfg.pattern):
            x, cache, aux = block_apply_seq(
                cfg, kind, pparams[f"b{i}"], x, positions, mode=mode, enc_out=enc_out
            )
            if cache is not None:
                caches[f"b{i}"] = cache
            for k, v in aux.items():
                aux_acc = {**aux_acc, k: aux_acc[k] + v}
        return (x, aux_acc), caches

    if remat:
        period_fn = jax.checkpoint(period_fn)
    (x, aux), caches = jax.lax.scan(period_fn, (x, aux0), params_blocks)
    return x, caches, aux


def _run_enc_stack(cfg, params, audio_embeds):
    x = audio_embeds.astype(_dtype(cfg))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None, :]

    def period_fn(x, pparams):
        x, _, _ = block_apply_seq(cfg, "attn_bidir_mlp", pparams["b0"], x, positions, mode="train")
        return x, None

    x, _ = jax.lax.scan(period_fn, x, params["enc_blocks"])
    return apply_norm(cfg, x, params["enc_final_norm"], "")


# ------------------------------------------------------------------- train


def forward(cfg: ArchConfig, params, batch, *, remat: bool = False):
    """Training forward: returns (logits (b, s, vocab), aux losses dict)."""
    if cfg.ode_depth:
        from .node import forward_ode

        return forward_ode(cfg, params, batch)
    x = _embed_tokens(cfg, params, batch)
    x = constrain(x, "dp", None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc_out = None
    if cfg.enc_dec:
        enc_out = _run_enc_stack(cfg, params, batch["audio_embeds"])
    x, _, aux = _run_stack(
        cfg, params["blocks"], x, positions, mode="train", enc_out=enc_out, remat=remat
    )
    x = apply_norm(cfg, x, params["final_norm"], "")
    logits = x @ params["embed"].T
    logits = constrain(logits, "dp", None, "tp")
    return logits, aux


# ------------------------------------------------------------------- serving


def prefill(cfg: ArchConfig, params, batch):
    """Full-sequence forward that materializes caches; returns (last_logits, cache)."""
    x = _embed_tokens(cfg, params, batch)
    x = constrain(x, "dp", None, None)
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    enc_out = None
    if cfg.enc_dec:
        enc_out = _run_enc_stack(cfg, params, batch["audio_embeds"])
    x, caches, _ = _run_stack(cfg, params["blocks"], x, positions, mode="prefill", enc_out=enc_out)
    x = apply_norm(cfg, x[:, -1:, :], params["final_norm"], "")[:, 0]
    logits = x @ params["embed"].T
    logits = constrain(logits, "dp", "tp")
    return logits, caches


def init_cache(cfg: ArchConfig, batch_size: int, cache_len: int, enc_len: int | None = None):
    """Zero caches for decode-from-scratch (and for dry-run input specs)."""
    dtype = _dtype(cfg)
    KV, hd = cfg.n_kv_heads, cfg.hd
    caches = {}
    Dkv = KV * hd  # flat head dim: evenly shardable on the model axis
    for i, kind in enumerate(cfg.pattern):
        if kind in ("attn_mlp", "attn_moe"):
            c = {
                "k": jnp.zeros((cfg.n_periods, batch_size, cache_len, Dkv), dtype),
                "v": jnp.zeros((cfg.n_periods, batch_size, cache_len, Dkv), dtype),
            }
        elif kind == "attn_cross_mlp":
            el = enc_len or cache_len
            c = {
                "k": jnp.zeros((cfg.n_periods, batch_size, cache_len, Dkv), dtype),
                "v": jnp.zeros((cfg.n_periods, batch_size, cache_len, Dkv), dtype),
                "xk": jnp.zeros((cfg.n_periods, batch_size, el, Dkv), dtype),
                "xv": jnp.zeros((cfg.n_periods, batch_size, el, Dkv), dtype),
            }
        elif kind in ("mamba_mlp", "mamba_moe"):
            st = ssm_lib.mamba_init_state(cfg, batch_size, dtype)
            c = jax.tree.map(lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), st)
        elif kind == "mlstm":
            st = xlstm_lib.mlstm_init_state(cfg, batch_size)
            c = jax.tree.map(lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), st)
        elif kind == "slstm":
            st = xlstm_lib.slstm_init_state(cfg, batch_size, dtype)
            c = jax.tree.map(lambda a: jnp.zeros((cfg.n_periods,) + a.shape, a.dtype), st)
        else:
            raise ValueError(kind)
        caches[f"b{i}"] = c
    return caches


def pad_cache(cfg: ArchConfig, cache, cache_len: int):
    """Grow attention KV caches (from prefill, length s) to ``cache_len`` so
    decode can continue past the prefill length.  SSM/xLSTM states are O(1)
    and pass through unchanged."""

    def pad(path_key, c):
        out = dict(c)
        for name in ("k", "v"):
            if name in c:
                arr = c[name]
                extra = cache_len - arr.shape[2]
                if extra > 0:
                    pad_widths = [(0, 0)] * arr.ndim
                    pad_widths[2] = (0, extra)
                    out[name] = jnp.pad(arr, pad_widths)
        return out

    return {k: pad(k, v) if isinstance(v, dict) and ("k" in v or "v" in v) else v for k, v in cache.items()}


def decode_step(cfg: ArchConfig, params, token, pos, cache):
    """One decode step.  token: (b,) int32; pos: (b,) positions; cache: stacked
    per-period states.  Returns (logits (b, vocab), new cache)."""
    x = params["embed"][token]  # (b, d)
    x = constrain(x, "dp", None)

    def period_fn(x, scanned):
        pparams, pcache = scanned
        x = constrain(x, "dp", None)
        new_cache = {}
        for i, kind in enumerate(cfg.pattern):
            key = f"b{i}"
            x, st = block_apply_decode(cfg, kind, pparams[key], x, pos, pcache[key])
            new_cache[key] = st
        return x, new_cache

    x, new_cache = jax.lax.scan(period_fn, x, (params["blocks"], cache))
    x = apply_norm(cfg, x[:, None, :], params["final_norm"], "")[:, 0]
    logits = x @ params["embed"].T
    logits = constrain(logits, "dp", "tp")
    return logits, new_cache
