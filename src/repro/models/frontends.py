"""Modality frontend STUBS (per the assignment: [vlm]/[audio] entries specify
the transformer backbone only; ``input_specs()`` provides precomputed
frame/patch embeddings).

These helpers produce deterministic fake embeddings for smoke tests and the
shape/dtype stand-ins used by the dry-run.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fake_img_embeds(cfg, batch_size: int, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.random.normal(
        key, (batch_size, cfg.n_img_tokens, cfg.d_model), jnp.dtype(cfg.dtype)
    ) * 0.02


def fake_audio_embeds(cfg, batch_size: int, n_frames: int, key=None):
    key = key if key is not None else jax.random.PRNGKey(1)
    return jax.random.normal(
        key, (batch_size, n_frames, cfg.d_model), jnp.dtype(cfg.dtype)
    ) * 0.02
