"""Continuous-depth ("neural ODE") execution of a transformer block stack,
driven by the repro.core batch-parallel solver -- the integration point between
the paper's technique and the LM substrate.

dx/dt = block(x, t), t in [0, 1], weight-tied across depth (n_periods must be
1).  The ODE "batch" is the set of token vectors, so every token adapts its own
step size -- the per-instance independence of torchode at token granularity.
Used on reduced configs (smoke tests, examples); see DESIGN.md
SS5 Arch-applicability.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core import solve_ivp_scan
from .common import apply_norm
from .transformer import block_apply_seq


def forward_ode(cfg, params, batch):
    from .lm import _embed_tokens  # local import to avoid cycle

    assert cfg.n_periods == 1, "ode_depth requires a weight-tied (single-period) stack"
    x = _embed_tokens(cfg, params, batch)
    b, s, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    pparams = jax.tree.map(lambda a: a[0], params["blocks"])  # drop period axis

    def dyn(t, y, _args):
        # y: (b, s*d) -- each sequence is one ODE instance
        h = y.reshape(b, s, d).astype(jnp.dtype(cfg.dtype))
        out = h
        for i, kind in enumerate(cfg.pattern):
            out, _, _ = block_apply_seq(
                cfg, kind, pparams[f"b{i}"], out, positions, mode="train"
            )
        return (out - h).reshape(b, s * d).astype(y.dtype)

    y0 = x.reshape(b, s * d).astype(jnp.float32)
    sol = solve_ivp_scan(
        dyn,
        y0,
        None,
        t_start=0.0,
        t_end=1.0,
        method="bosh3",
        rtol=1e-2,
        atol=1e-3,
        max_steps=cfg.ode_steps,
    )
    x = sol.ys.reshape(b, s, d).astype(jnp.dtype(cfg.dtype))
    x = apply_norm(cfg, x, params["final_norm"], "")
    logits = x @ params["embed"].T
    return logits, {"ode_steps": sol.stats["n_steps"].mean()}
