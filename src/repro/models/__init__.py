from .config import SHAPES, ArchConfig, MoECfg
from .lm import (
    decode_step,
    forward,
    init_cache,
    init_params,
    pad_cache,
    param_count,
    prefill,
)

__all__ = [
    "SHAPES",
    "ArchConfig",
    "MoECfg",
    "decode_step",
    "forward",
    "init_cache",
    "init_params",
    "pad_cache",
    "param_count",
    "prefill",
]
