"""AdamW with global-norm clipping and cosine schedule.

Implemented directly on pytrees (no optax dependency in this environment).
Moments are stored in float32 regardless of parameter dtype; the sharding
rules apply the same PartitionSpec to moments as to their parameter, so
optimizer state is fully sharded (ZeRO-style when FSDP is on).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def clip_by_global_norm(grads, max_norm):
    leaves = jax.tree_util.tree_leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = b1 * m + (1 - b1) * gf
        v_new = b2 * v + (1 - b2) * gf * gf
        mh = m_new / bc1
        vh = v_new / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat = jax.tree.map(upd, params, grads, state["m"], state["v"])
    p_new = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda t: isinstance(t, tuple))
    m_new = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda t: isinstance(t, tuple))
    v_new = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda t: isinstance(t, tuple))
    return p_new, {"m": m_new, "v": v_new, "step": step}, {"lr": lr}
