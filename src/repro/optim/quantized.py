"""8-bit AdamW moments (Dettmers-style blockwise dynamic quantization).

At kimi-k2 scale the f32 Adam moments are 8 TB -- the single largest term in
the training-memory budget (measured 76 GiB/device on the 16x16 mesh).  Storing
m and v as int8 with per-256-block f32 scales cuts moment memory 3.6x; the
update dequantizes, applies f32 Adam math, and requantizes.  Convergence
tolerance of 8-bit moments is established in the literature (8-bit Adam);
tests/test_optim.py checks parity against f32 AdamW on a quadratic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .adamw import AdamWConfig, cosine_lr

BLOCK = 256


def quantize_blockwise(x):
    """Blockwise-symmetric int8 along the LAST dim (padded to BLOCK).

    Blocking the last dim (not a global flatten) keeps the quantized buffers'
    leading dims identical to the parameter's, so the FSDP/TP sharding rules
    apply unchanged and the elementwise Adam update never reshards.
    Returns q int8 (*lead, ceil(n/B)*B) and scales f32 (*lead, ceil(n/B))."""
    pad = (-x.shape[-1]) % BLOCK
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(*xp.shape[:-1], -1, BLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1) / 127.0, 1e-20)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127).astype(jnp.int8)
    return q.reshape(xp.shape), scale


def dequantize_blockwise(q, scale, shape):
    blocks = q.reshape(*q.shape[:-1], -1, BLOCK).astype(jnp.float32) * scale[..., None]
    return blocks.reshape(*q.shape[:-1], -1)[..., : shape[-1]]


def qadamw_init(params):
    def one(p):
        z = jnp.zeros(p.shape, jnp.float32)
        q, s = quantize_blockwise(z)
        return {"q": q, "s": s}

    return {
        "m": jax.tree.map(one, params),
        "v": jax.tree.map(one, params),
        "step": jnp.zeros((), jnp.int32),
    }


def qadamw_update(cfg: AdamWConfig, params, grads, state):
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mq, vq):
        gf = g.astype(jnp.float32)
        m = dequantize_blockwise(mq["q"], mq["s"], p.shape)
        v = dequantize_blockwise(vq["q"], vq["s"], p.shape)
        m = b1 * m + (1 - b1) * gf
        v = b2 * v + (1 - b2) * gf * gf
        delta = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        p_new = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        mq2, ms2 = quantize_blockwise(m)
        vq2, vs2 = quantize_blockwise(v)
        return p_new, {"q": mq2, "s": ms2}, {"q": vq2, "s": vs2}

    # flatten against the PARAM treedef: each moment entry is a {"q","s"} dict
    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    outs = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    p_new = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    m_new = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    v_new = jax.tree_util.tree_unflatten(treedef, [o[2] for o in outs])
    return p_new, {"m": m_new, "v": v_new, "step": step}, {"lr": lr}
