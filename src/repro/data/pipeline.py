"""Deterministic synthetic token pipeline.

Design goals of a production input pipeline, reproduced at laptop scale:
  - deterministic given (seed, step): restart/elastic-rescale resumes on the
    exact batch boundary with no data loss or duplication
  - shardable: each data-parallel rank materializes ONLY its shard
    (host-side `jax.make_array_from_callback` in the launcher)
  - prefetchable: batches are pure functions of the step index, so any number
    can be generated ahead

The generator is a Markov-ish mixture so the LM loss actually decreases during
the example runs (pure uniform tokens would have constant loss ln V).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticTokens:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_modes: int = 32

    def batch(self, step: int, *, lo: int = 0, hi: int | None = None):
        """Rows [lo, hi) of the global batch for ``step`` (host numpy)."""
        hi = self.global_batch if hi is None else hi
        rows = []
        for r in range(lo, hi):
            rng = np.random.default_rng(
                np.random.SeedSequence([self.seed, step, r])
            )
            # each row follows a random linear-congruential walk over a small
            # mode set -> learnable structure
            mode = rng.integers(self.n_modes)
            a = 1 + 2 * rng.integers(1, 64)
            c = rng.integers(self.vocab)
            x = np.empty(self.seq_len + 1, np.int64)
            x[0] = mode
            for i in range(1, self.seq_len + 1):
                x[i] = (a * x[i - 1] + c) % self.vocab
            noise = rng.random(self.seq_len + 1) < 0.05
            x[noise] = rng.integers(self.vocab, size=noise.sum())
            rows.append(x)
        arr = np.stack(rows).astype(np.int32)
        return {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


def make_batches(ds: SyntheticTokens, start_step: int, n_steps: int):
    for s in range(start_step, start_step + n_steps):
        yield s, ds.batch(s)
