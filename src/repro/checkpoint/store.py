"""Fault-tolerant checkpointing.

Guarantees (the ones that matter at 1000+ nodes):
  - atomicity: a checkpoint directory becomes visible only via rename() after
    every shard file is fully written + fsynced -- a crash mid-write can never
    produce a "latest" checkpoint that is unreadable
  - resharding on restore: arrays are saved with their global shape; restore
    accepts ANY target sharding (elastic re-scale to a different mesh)
  - async: the save runs on a background thread against host copies so the
    train loop continues (bounded queue of 1 -- backpressure instead of OOM)
  - self-describing: a JSON manifest records step, pytree structure and shapes

Format: one .npz per pytree leaf group + manifest.json, in step-tagged dirs:
  <dir>/step_000123/  (tmp dir renamed into place)
"""

from __future__ import annotations

import json
import os
import queue
import re
import shutil
import tempfile
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _paths(tree):
    return [
        "/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        for path, _ in jax.tree_util.tree_leaves_with_path(tree)
    ]


def save(directory: str, step: int, tree) -> str:
    """Synchronous atomic save of a pytree of (possibly sharded) jax arrays."""
    os.makedirs(directory, exist_ok=True)
    leaves, _ = _flatten(tree)
    names = _paths(tree)
    host = [np.asarray(l) for l in leaves]  # gathers shards to host
    dtypes = [str(a.dtype) for a in host]
    # npz cannot store ml_dtypes (bfloat16, fp8): persist as a raw uint view;
    # the manifest's dtype string restores the logical type on load
    host = [a.view(np.uint16) if a.dtype.name == "bfloat16" else a for a in host]

    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory)
    try:
        arrs = {f"leaf_{i}": a for i, a in enumerate(host)}
        with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
            np.savez(f, **arrs)
            f.flush()
            os.fsync(f.fileno())
        manifest = {
            "step": step,
            "names": names,
            "shapes": [list(a.shape) for a in host],
            "dtypes": dtypes,
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(directory, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        return final
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", d))
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, like_tree, shardings=None):
    """Restore into the structure of ``like_tree``; optionally device-put with
    ``shardings`` (a matching pytree) -- this is how elastic re-scaling
    re-shards a checkpoint onto a different mesh."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    import ml_dtypes

    leaves = []
    for i, dt in enumerate(manifest["dtypes"]):
        a = data[f"leaf_{i}"]
        if dt == "bfloat16":
            a = a.view(ml_dtypes.bfloat16)
        leaves.append(a)
    _, treedef = _flatten(like_tree)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree


class CheckpointManager:
    """Async checkpointing with a bounded background queue and retention."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._q: queue.Queue = queue.Queue(maxsize=1)
        self._worker = threading.Thread(target=self._run, daemon=True)
        self._worker.start()
        self._errors: list = []

    def _run(self):
        while True:
            item = self._q.get()
            try:
                if item is None:
                    return
                step, host_tree = item
                try:
                    save(self.directory, step, host_tree)
                    self._gc()
                except Exception as e:  # noqa: BLE001
                    self._errors.append(e)
            finally:
                self._q.task_done()

    def _gc(self):
        steps = sorted(
            int(m.group(1))
            for d in os.listdir(self.directory)
            if (m := re.fullmatch(r"step_(\d+)", d))
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"), ignore_errors=True)

    def save_async(self, step: int, tree):
        # copy to host NOW (cheap on CPU, device->host DMA on TPU) so the
        # training loop can donate/overwrite device buffers
        host_tree = jax.tree.map(np.asarray, tree)
        self._q.put((step, host_tree))  # blocks if a save is in flight

    def wait(self):
        self._q.join()

    def close(self):
        self._q.put(None)
        self._worker.join(timeout=30)
        if self._errors:
            raise self._errors[0]
