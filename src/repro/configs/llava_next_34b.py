"""LLaVA-NeXT-34B [hf:llava-hf]: dense decoder backbone + anyres vision frontend
(STUB: input_specs provides precomputed patch embeddings for 576 image tokens)."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab=64000,
    norm="rmsnorm",
    mlp="swiglu",
    rope=True,
    n_img_tokens=576,
)

REDUCED = ArchConfig(
    name="llava-next-34b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=256,
    norm="rmsnorm",
    mlp="swiglu",
    rope=True,
    n_img_tokens=8,
    q_chunk=16,
    kv_chunk=16,
    dtype="float32",
)
