"""StarCoder2-15B [arXiv:2402.19173]: dense, GQA kv=4, RoPE, LayerNorm, GeLU MLP."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    norm="layernorm",
    mlp="gelu",
    qkv_bias=True,
    rope=True,
)

REDUCED = ArchConfig(
    name="starcoder2-15b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=256,
    norm="layernorm",
    mlp="gelu",
    qkv_bias=True,
    rope=True,
    q_chunk=16,
    kv_chunk=16,
    dtype="float32",
)
