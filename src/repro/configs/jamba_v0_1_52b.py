"""Jamba-v0.1-52B [arXiv:2403.19887]: Mamba+attention 1:7 interleave, 16e top-2 MoE
on every other layer.  Period of 8 layers: attention at position 4, MoE at odd
positions -- the published jamba block layout."""

from ..models.config import ArchConfig, MoECfg

_PATTERN = (
    "mamba_mlp",
    "mamba_moe",
    "mamba_mlp",
    "mamba_moe",
    "attn_mlp",
    "mamba_moe",
    "mamba_mlp",
    "mamba_moe",
)

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    pattern=_PATTERN,
    moe=MoECfg(n_experts=16, top_k=2, n_shared=0, d_expert=14336),
    norm="rmsnorm",
    mlp="swiglu",
    rope=False,  # jamba uses no positional encoding (mamba provides position)
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    subquadratic=True,
)

REDUCED = ArchConfig(
    name="jamba-reduced",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=256,
    pattern=_PATTERN,
    moe=MoECfg(n_experts=4, top_k=2, n_shared=0, d_expert=128, capacity_factor=8.0),
    norm="rmsnorm",
    mlp="swiglu",
    rope=False,
    ssm_state=8,
    ssm_conv=4,
    ssm_expand=2,
    subquadratic=True,
    q_chunk=16,
    kv_chunk=16,
    dtype="float32",
)
