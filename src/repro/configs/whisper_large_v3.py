"""Whisper-large-v3 [arXiv:2212.04356]: encoder-decoder, MHA, GeLU, LayerNorm.
Conv audio frontend is a STUB -- input_specs provides precomputed frame
embeddings.  n_layers counts each stack (32 enc + 32 dec)."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    pattern=("attn_cross_mlp",),
    norm="layernorm",
    mlp="gelu",
    rope=False,  # whisper uses learned/sinusoidal pos-emb; stub embeds include it
    enc_dec=True,
    audio_frontend=True,
)

REDUCED = ArchConfig(
    name="whisper-large-v3-reduced",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=256,
    pattern=("attn_cross_mlp",),
    norm="layernorm",
    mlp="gelu",
    rope=False,
    enc_dec=True,
    audio_frontend=True,
    q_chunk=16,
    kv_chunk=16,
    dtype="float32",
)
