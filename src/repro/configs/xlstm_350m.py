"""xLSTM-350M [arXiv:2405.04517]: mLSTM + sLSTM blocks (3:1 interleave), no FFN
(the xLSTM blocks carry their own up/down projections)."""

from ..models.config import ArchConfig

_PATTERN = ("mlstm", "mlstm", "mlstm", "slstm")

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=_PATTERN,
    norm="rmsnorm",
    rope=False,
    xlstm_proj=2,
    subquadratic=True,
)

REDUCED = ArchConfig(
    name="xlstm-350m-reduced",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=2,
    n_kv_heads=2,
    d_ff=0,
    vocab=256,
    pattern=_PATTERN,
    norm="rmsnorm",
    rope=False,
    xlstm_proj=2,
    subquadratic=True,
    q_chunk=16,
    kv_chunk=16,
    dtype="float32",
)
