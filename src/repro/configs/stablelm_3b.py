"""StableLM-3B [hf:stabilityai/stablelm-2]: dense MHA (kv=heads), SwiGLU, LayerNorm."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b",
    family="dense",
    n_layers=32,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=6912,
    vocab=50304,
    norm="layernorm",
    mlp="swiglu",
    rope=True,
)

REDUCED = ArchConfig(
    name="stablelm-3b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=160,
    vocab=256,
    norm="layernorm",
    mlp="swiglu",
    rope=True,
    q_chunk=16,
    kv_chunk=16,
    dtype="float32",
)
