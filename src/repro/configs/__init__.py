"""Architecture registry: one module per assigned arch, each exporting
``CONFIG`` (the exact published geometry) and ``REDUCED`` (a same-family
small config for CPU smoke tests)."""

from __future__ import annotations

import importlib

ARCHS = [
    "starcoder2_15b",
    "stablelm_3b",
    "qwen2_5_14b",
    "starcoder2_7b",
    "deepseek_moe_16b",
    "kimi_k2_1t_a32b",
    "jamba_v0_1_52b",
    "llava_next_34b",
    "xlstm_350m",
    "whisper_large_v3",
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({"qwen2.5-14b": "qwen2_5_14b", "jamba-v0.1-52b": "jamba_v0_1_52b"})


def get_config(name: str, reduced: bool = False):
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    mod = importlib.import_module(f".{mod_name}", __package__)
    return mod.REDUCED if reduced else mod.CONFIG


def all_archs():
    return list(ARCHS)
