"""DeepSeekMoE-16B [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64 routed top-6."""

from ..models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,  # per-expert hidden width (fine-grained experts)
    vocab=102400,
    pattern=("attn_moe",),
    moe=MoECfg(n_experts=64, top_k=6, n_shared=2, d_expert=1408),
    norm="rmsnorm",
    mlp="swiglu",
    rope=True,
)

REDUCED = ArchConfig(
    name="deepseek-moe-16b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=48,
    vocab=256,
    pattern=("attn_moe",),
    moe=MoECfg(n_experts=8, top_k=2, n_shared=2, d_expert=48, capacity_factor=8.0),
    norm="rmsnorm",
    mlp="swiglu",
    rope=True,
    q_chunk=16,
    kv_chunk=16,
    dtype="float32",
)
