"""Qwen2.5-14B [hf:Qwen]: dense GQA kv=8, QKV bias, SwiGLU, RMSNorm, rope theta 1e6."""

from ..models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab=152064,
    qkv_bias=True,
    rope=True,
    rope_theta=1e6,
    norm="rmsnorm",
    mlp="swiglu",
)

REDUCED = ArchConfig(
    name="qwen2.5-14b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=160,
    vocab=256,
    qkv_bias=True,
    rope=True,
    rope_theta=1e6,
    norm="rmsnorm",
    mlp="swiglu",
    q_chunk=16,
    kv_chunk=16,
    dtype="float32",
)
