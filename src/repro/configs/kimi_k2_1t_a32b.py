"""Kimi K2 1T-A32B [arXiv:2501.kimi2, paper-table]: 384-expert top-8 MoE, 1 shared."""

from ..models.config import ArchConfig, MoECfg

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    d_ff=2048,  # per-expert hidden width
    vocab=163840,
    head_dim=112,
    pattern=("attn_moe",),
    moe=MoECfg(n_experts=384, top_k=8, n_shared=1, d_expert=2048),
    norm="rmsnorm",
    mlp="swiglu",
    rope=True,
)

REDUCED = ArchConfig(
    name="kimi-k2-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=32,
    vocab=256,
    head_dim=16,
    pattern=("attn_moe",),
    moe=MoECfg(n_experts=16, top_k=4, n_shared=1, d_expert=32, capacity_factor=8.0),
    norm="rmsnorm",
    mlp="swiglu",
    rope=True,
    q_chunk=16,
    kv_chunk=16,
    dtype="float32",
)
