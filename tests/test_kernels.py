"""Per-kernel allclose validation: Pallas (interpret mode) vs the pure-jnp
oracles in kernels/ref.py, with shape/dtype sweeps and hypothesis properties."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, pallas_impl as pi, ref


def rng_arrays(seed, *shapes, dtype=np.float32):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.standard_normal(s), dtype) for s in shapes]


SHAPES = [(1, 1), (3, 5), (8, 128), (17, 300), (2, 1025), (9, 64)]
STAGES = [2, 4, 7]


class TestFusedUpdate:
    @pytest.mark.parametrize("b,f", SHAPES)
    @pytest.mark.parametrize("s", STAGES)
    def test_matches_ref(self, b, f, s):
        y, K = rng_arrays(b * f + s, (b, f), (s, b, f))
        dt = jnp.abs(rng_arrays(1, (b,))[0]) + 0.01
        b_sol = np.random.default_rng(s).standard_normal(s)
        b_err = np.random.default_rng(s + 1).standard_normal(s)
        r_y, r_e = ref.fused_update(y, K, dt, jnp.asarray(b_sol, jnp.float32),
                                    jnp.asarray(b_err, jnp.float32))
        p_y, p_e = pi.fused_update(y, K, dt, b_sol, b_err, interpret=True)
        np.testing.assert_allclose(r_y, p_y, rtol=3e-5, atol=3e-5)
        np.testing.assert_allclose(r_e, p_e, rtol=3e-5, atol=3e-5)

    def test_zero_coefficients_skipped(self):
        y, K = rng_arrays(0, (4, 16), (7, 4, 16))
        dt = jnp.ones((4,))
        b_sol = np.array([1.0, 0, 0, 0, 0, 0, 0])
        b_err = np.zeros(7)
        p_y, p_e = pi.fused_update(y, K, dt, b_sol, b_err, interpret=True)
        np.testing.assert_allclose(p_y, y + K[0], rtol=1e-6)
        np.testing.assert_allclose(p_e, 0.0, atol=1e-7)


class TestStageAccum:
    @pytest.mark.parametrize("b,f", SHAPES)
    def test_matches_ref(self, b, f):
        s = 4
        y, K = rng_arrays(b + f, (b, f), (s, b, f))
        dt = jnp.abs(rng_arrays(2, (b,))[0]) + 0.01
        coeffs = np.random.default_rng(7).standard_normal(s)
        r = ref.stage_accum(y, dt, K, jnp.asarray(coeffs, jnp.float32))
        p = pi.stage_accum(y, dt, K, coeffs, interpret=True)
        np.testing.assert_allclose(r, p, rtol=3e-5, atol=3e-5)


class TestErrorNorm:
    @pytest.mark.parametrize("b,f", SHAPES)
    def test_matches_ref(self, b, f):
        err, y0, y1 = rng_arrays(b * 31 + f, (b, f), (b, f), (b, f))
        r = ref.error_norm(err, y0, y1, 1e-6, 1e-3)
        p = pi.error_norm(err, y0, y1, 1e-6, 1e-3, interpret=True)
        np.testing.assert_allclose(r, p, rtol=1e-4, atol=1e-6)

    def test_per_instance_tolerances(self):
        err, y0, y1 = rng_arrays(3, (4, 37), (4, 37), (4, 37))
        atol = jnp.asarray([1e-8, 1e-6, 1e-4, 1e-2])
        rtol = jnp.asarray([1e-6, 1e-5, 1e-3, 1e-2])
        r = ref.error_norm(err, y0, y1, atol, rtol)
        p = pi.error_norm(err, y0, y1, atol, rtol, interpret=True)
        np.testing.assert_allclose(r, p, rtol=1e-4)

    def test_zero_atol_feature_padding(self):
        """padding must stay exact even with atol == 0 (regression)."""
        err, y0, y1 = rng_arrays(5, (2, 130), (2, 130), (2, 130))
        r = ref.error_norm(err, y0, y1, 0.0, 1e-3)
        p = pi.error_norm(err, y0, y1, 0.0, 1e-3, interpret=True)
        np.testing.assert_allclose(r, p, rtol=1e-4)


class TestInterp:
    @pytest.mark.parametrize("b,n,f", [(1, 1, 1), (3, 7, 5), (8, 128, 128), (5, 200, 2)])
    def test_matches_ref(self, b, n, f):
        rng = np.random.default_rng(b * n + f)
        coeffs = tuple(jnp.asarray(rng.standard_normal((b, f)), jnp.float32) for _ in range(4))
        x = jnp.asarray(rng.uniform(0, 1, (b, n)), jnp.float32)
        mask = jnp.asarray(rng.uniform(size=(b, n)) > 0.5)
        out = jnp.asarray(rng.standard_normal((b, n, f)), jnp.float32)
        r = ref.interp_eval(coeffs, x, mask, out)
        p = pi.interp_eval(coeffs, x, mask, out, interpret=True)
        np.testing.assert_allclose(r, p, rtol=3e-5, atol=3e-5)

    def test_horner_is_a_polynomial(self):
        """ref oracle itself: interp at x equals direct polynomial eval."""
        b, n, f = 2, 9, 3
        rng = np.random.default_rng(0)
        cs = [rng.standard_normal((b, f)).astype(np.float32) for _ in range(4)]
        x = rng.uniform(0, 1, (b, n)).astype(np.float32)
        mask = np.ones((b, n), bool)
        out = np.zeros((b, n, f), np.float32)
        r = np.asarray(ref.interp_eval(tuple(map(jnp.asarray, cs)), jnp.asarray(x),
                                       jnp.asarray(mask), jnp.asarray(out)))
        direct = sum(c[:, None, :] * (x[:, :, None] ** k) for k, c in enumerate(cs))
        np.testing.assert_allclose(r, direct, rtol=1e-4, atol=1e-5)


class TestProperties:
    @settings(max_examples=25, deadline=None)
    @given(b=st.integers(1, 12), f=st.integers(1, 200), s=st.integers(1, 7),
           seed=st.integers(0, 2**30))
    def test_fused_update_property(self, b, f, s, seed):
        rng = np.random.default_rng(seed)
        y = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        K = jnp.asarray(rng.standard_normal((s, b, f)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.01, 2, (b,)), jnp.float32)
        b_sol = rng.standard_normal(s)
        b_err = rng.standard_normal(s)
        r = ref.fused_update(y, K, dt, jnp.asarray(b_sol, jnp.float32),
                             jnp.asarray(b_err, jnp.float32))
        p = pi.fused_update(y, K, dt, b_sol, b_err, interpret=True)
        np.testing.assert_allclose(r[0], p[0], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(r[1], p[1], rtol=1e-4, atol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(b=st.integers(1, 8), f=st.integers(1, 300), seed=st.integers(0, 2**30))
    def test_error_norm_property(self, b, f, seed):
        rng = np.random.default_rng(seed)
        err = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        y0 = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        y1 = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        r = ref.error_norm(err, y0, y1, 1e-6, 1e-3)
        p = pi.error_norm(err, y0, y1, 1e-6, 1e-3, interpret=True)
        np.testing.assert_allclose(r, p, rtol=2e-4, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(b=st.integers(1, 10), f=st.integers(129, 400), s=st.integers(2, 5),
           seed=st.integers(0, 2**30))
    def test_fused_step_tiled_reduction_property(self, b, f, s, seed):
        """Mixed accept/reject batches through the feature-tiled two-pass WRMS
        reduction (f > 128 engages it) agree with the single-pass ref op."""
        rng = np.random.default_rng(seed)
        y = jnp.asarray(rng.uniform(0.5, 1.5, (b, f)), jnp.float32)
        K = jnp.asarray(rng.standard_normal((s, b, f)), jnp.float32)
        t = jnp.asarray(rng.uniform(0.0, 1.0, (b,)), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.05, 0.2, (b,)), jnp.float32)
        running = jnp.asarray(rng.uniform(size=b) > 0.25)
        pi1 = jnp.asarray(rng.uniform(0.5, 2.0, (b,)), jnp.float32)
        pi2 = jnp.asarray(rng.uniform(0.5, 2.0, (b,)), jnp.float32)
        kw = dict(b_sol=tuple(rng.standard_normal(s).tolist()),
                  b_err=tuple((0.1 * rng.standard_normal(s)).tolist()),
                  ctrl=(0.14, -0.08, 0.02, 0.9, 0.2, 10.0, 0.0, float("inf")),
                  want_coeffs=False)
        # Calibrate atol off a probe ratio so accept/reject actually mixes.
        probe = np.asarray(ref.fused_step(y, K, K[-1], t, t + dt, dt, dt,
                                          running, pi1, pi2, 0.05, 1e-3, **kw)[1])
        atol = float(0.05 * np.median(probe)) if probe.any() else 0.05
        args = (y, K, K[-1], t, t + dt, dt, dt, running, pi1, pi2, atol, 1e-3)
        r = ref.fused_step(*args, **kw)
        p = pi.fused_step(*args, interpret=True, **kw)
        np.testing.assert_allclose(np.asarray(r[1]), np.asarray(p[1]),
                                   rtol=1e-4, atol=1e-6)
        # Decisions may differ only on the knife edge of ratio == 1; committed
        # outputs are compared where the decisions agree.
        clear = np.abs(np.asarray(r[1]) - 1.0) > 1e-3
        np.testing.assert_array_equal(np.asarray(r[2])[clear],
                                      np.asarray(p[2])[clear])
        agree = np.asarray(r[2]) == np.asarray(p[2])
        for i in (0, 3, 4, 5, 6, 7, 8):
            np.testing.assert_allclose(np.asarray(r[i])[agree],
                                       np.asarray(p[i])[agree],
                                       rtol=2e-4, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**30))
    def test_error_norm_scale_invariance(self, seed):
        """rtol-only norm is invariant to rescaling (err, y) jointly."""
        rng = np.random.default_rng(seed)
        err = jnp.asarray(rng.standard_normal((3, 40)), jnp.float32)
        y0 = jnp.asarray(rng.standard_normal((3, 40)) + 2.0, jnp.float32)
        r1 = ref.error_norm(err, y0, y0, 0.0, 1e-3)
        r2 = ref.error_norm(err * 10, y0 * 10, y0 * 10, 0.0, 1e-3)
        np.testing.assert_allclose(r1, r2, rtol=1e-4)


class TestFusedEventOps:
    """The event layer's kernelized sign test and commit vs the ref oracle."""

    def _detect_inputs(self, seed, b, E):
        rng = np.random.default_rng(seed)
        v_prev = jnp.asarray(rng.standard_normal((b, E)), jnp.float32)
        v_new = jnp.asarray(rng.standard_normal((b, E)), jnp.float32)
        fired = jnp.asarray(rng.uniform(size=(b, E)) > 0.7)
        accept = jnp.asarray(rng.uniform(size=b) > 0.3)
        return rng, v_prev, v_new, fired, accept

    @pytest.mark.parametrize("b,E", [(1, 1), (6, 3), (17, 2)])
    @pytest.mark.parametrize("direction", [-1.0, 0.0, 1.0])
    def test_detect_matches_ref(self, b, E, direction):
        _, v_prev, v_new, fired, accept = self._detect_inputs(b * E, b, E)
        directions = tuple(direction if i % 2 == 0 else 0.0 for i in range(E))
        r = ref.fused_event_detect(v_prev, v_new, fired, accept,
                                   directions=directions)
        p = pi.fused_event_detect(v_prev, v_new, fired, accept,
                                  directions=directions, interpret=True)
        np.testing.assert_array_equal(np.asarray(r[0]), np.asarray(p[0]))
        np.testing.assert_array_equal(np.asarray(r[1]), np.asarray(p[1]))

    @pytest.mark.parametrize("b,E,f", [(1, 1, 4), (6, 3, 40), (5, 2, 300)])
    def test_commit_matches_ref(self, b, E, f):
        # f=300 exercises the feature-tiled grid with its idempotent
        # per-tile rewrites of the E-column outputs.
        rng, v_prev, v_new, fired, accept = self._detect_inputs(b + E + f, b, E)
        newly, _ = ref.fused_event_detect(v_prev, v_new, fired, accept,
                                          directions=(0.0,) * E)
        x = jnp.asarray(rng.uniform(0.0, 1.0, (b, E)), jnp.float32)
        y_ev = jnp.asarray(rng.standard_normal((b, E, f)), jnp.float32)
        y_new = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        t0 = jnp.asarray(rng.uniform(0.0, 1.0, b), jnp.float32)
        dt = jnp.asarray(rng.uniform(0.05, 0.2, b), jnp.float32)
        ev_t = jnp.full((b, E), jnp.nan, jnp.float32)
        ev_y = jnp.zeros((b, E, f), jnp.float32)
        terminal = tuple(bool(i % 2 == 0) for i in range(E))
        args = (x, y_ev, newly, y_new, t0, dt, fired, ev_t, ev_y)
        r = ref.fused_event_commit(*args, terminal=terminal)
        p = pi.fused_event_commit(*args, terminal=terminal, interpret=True)
        for name, rr, pp in zip(
            ("fired", "ev_t", "ev_y", "stop", "t_stop", "y_stop", "n_new"), r, p
        ):
            np.testing.assert_array_equal(np.asarray(rr), np.asarray(pp),
                                          err_msg=name)


class TestBackendDispatch:
    def test_solver_runs_on_interpret_backend(self):
        from repro.core import solve_ivp

        old = ops.backend()
        ops.set_backend("interpret")
        try:
            sol = solve_ivp(lambda t, y, a: -y, jnp.ones((2, 3)),
                            jnp.linspace(0, 1, 5), atol=1e-6, rtol=1e-6)
            exp = np.broadcast_to(np.exp(-np.asarray(sol.ts))[..., None], sol.ys.shape)
            np.testing.assert_allclose(np.asarray(sol.ys), exp, rtol=1e-4, atol=1e-5)
        finally:
            ops.set_backend(old)
