"""The CI benchmark-regression gate (benchmarks/compare.py) must actually
gate: an injected 2x regression fails, noise inside the threshold passes,
and malformed/missing inputs fail loudly rather than reading as green."""

import json

import pytest

from benchmarks.compare import compare_files, compare_rows, main


def _payload(rows):
    return {"bench": "test", "unit": "us",
            "rows": [{"suite": s, "name": n, "value": v, "derived": ""}
                     for (s, n), v in rows.items()]}


def _write(tmp_path, name, rows):
    p = tmp_path / name
    p.write_text(json.dumps(_payload(rows)))
    return str(p)


BASE = {
    ("vdp", "b16/loop_time"): 100.0,
    ("dispatch", "compiled/solves_per_sec"): 1000.0,
    ("vdp", "joint_vs_parallel_step_ratio"): 5.0,  # informational
    ("vdp", "_suite_wall_s"): 30.0,  # bookkeeping
}


class TestRules:
    def test_within_threshold_passes(self):
        fresh = {
            ("vdp", "b16/loop_time"): 120.0,  # +20% < 25%
            ("dispatch", "compiled/solves_per_sec"): 850.0,  # -15%
            ("vdp", "joint_vs_parallel_step_ratio"): 500.0,  # ungated
        }
        failures, n_gated = compare_rows(BASE, fresh, 0.25)
        assert failures == []
        assert n_gated == 2

    def test_injected_2x_regression_fails(self):
        fresh = {
            ("vdp", "b16/loop_time"): 200.0,  # 2x slower
            ("dispatch", "compiled/solves_per_sec"): 1000.0,
        }
        failures, _ = compare_rows(BASE, fresh, 0.25)
        assert len(failures) == 1
        assert "loop_time" in failures[0] and "100.0% slowdown" in failures[0]

    def test_throughput_halved_fails(self):
        fresh = {
            ("vdp", "b16/loop_time"): 100.0,
            ("dispatch", "compiled/solves_per_sec"): 500.0,  # 2x fewer
        }
        failures, _ = compare_rows(BASE, fresh, 0.25)
        assert len(failures) == 1
        assert "solves_per_sec" in failures[0]

    def test_direction_awareness(self):
        """Getting *faster* must never fail, in either row family."""
        fresh = {
            ("vdp", "b16/loop_time"): 1.0,
            ("dispatch", "compiled/solves_per_sec"): 1e6,
        }
        failures, _ = compare_rows(BASE, fresh, 0.25)
        assert failures == []

    def test_missing_gated_row_fails(self):
        fresh = {("vdp", "b16/loop_time"): 100.0}
        failures, _ = compare_rows(BASE, fresh, 0.25)
        assert any("missing" in f for f in failures)

    def test_nonpositive_value_fails(self):
        failures, _ = compare_rows(
            {("s", "x_time"): 10.0}, {("s", "x_time"): 0.0}, 0.25)
        assert any("non-positive" in f for f in failures)


class TestFilesAndCli:
    def test_file_pair_roundtrip(self, tmp_path):
        base = _write(tmp_path, "base.json", BASE)
        good = _write(tmp_path, "good.json", BASE)
        assert compare_files(base, good, 0.25) == []

    def test_cli_exit_codes(self, tmp_path):
        base = _write(tmp_path, "base.json", BASE)
        good = _write(tmp_path, "good.json", BASE)
        bad = _write(tmp_path, "bad.json",
                     {**BASE, ("vdp", "b16/loop_time"): 200.0})
        assert main([base, good]) == 0
        assert main([base, bad]) == 1
        # threshold is adjustable: 2x passes a 150% gate
        assert main([base, bad, "--threshold", "1.5"]) == 0

    def test_cli_update_rewrites_baseline(self, tmp_path):
        base = _write(tmp_path, "base.json", BASE)
        bad = _write(tmp_path, "bad.json",
                     {**BASE, ("vdp", "b16/loop_time"): 200.0})
        assert main([base, bad]) == 1
        assert main([base, bad, "--update"]) == 0
        assert main([base, bad]) == 0

    def test_unreadable_and_unrelated_files_fail(self, tmp_path):
        base = _write(tmp_path, "base.json", BASE)
        missing = str(tmp_path / "nope.json")
        assert compare_files(base, missing, 0.25) != []
        # two files with no gated rows in common must not silently pass
        other = _write(tmp_path, "other.json",
                       {("x", "some_count"): 1.0})
        fails = compare_files(other, other, 0.25)
        assert any("no gated rows" in f for f in fails)

    def test_odd_pair_count_rejected(self, tmp_path):
        base = _write(tmp_path, "base.json", BASE)
        with pytest.raises(SystemExit):
            main([base])


class TestRunnerJsonDefaults:
    def test_suite_named_defaults_do_not_collide(self):
        from benchmarks.run import _DEFAULT_JSON, _SUITE_CHOICES

        assert set(_DEFAULT_JSON) == set(_SUITE_CHOICES)
        # the historical headline name is kept for all/table3...
        assert _DEFAULT_JSON["all"] == _DEFAULT_JSON["table3"] == "BENCH_solver.json"
        # ...and every other suite gets its own artifact
        others = {s: p for s, p in _DEFAULT_JSON.items()
                  if s not in ("all", "table3")}
        assert all(p == f"BENCH_{s}.json" for s, p in others.items())
        assert len(set(others.values())) == len(others)
        assert "serving" in _SUITE_CHOICES


class TestCalibrationNormalization:
    def _payload_cal(self, rows, cal):
        p = _payload(rows)
        if cal is not None:
            p["calibration_us"] = cal
        return p

    def _write_cal(self, tmp_path, name, rows, cal):
        p = tmp_path / name
        p.write_text(json.dumps(self._payload_cal(rows, cal)))
        return str(p)

    def test_scale_divides_times_and_multiplies_throughput(self):
        # A machine 2x slower across the board: raw values regress 2x, but
        # scale=2 normalizes both row families back to parity.
        fresh = {
            ("vdp", "b16/loop_time"): 200.0,
            ("dispatch", "compiled/solves_per_sec"): 500.0,
        }
        failures, n = compare_rows(BASE, fresh, 0.25, scale=2.0)
        assert failures == [] and n == 2
        # ...and a REAL regression still fails through the normalization.
        fresh[("vdp", "b16/loop_time")] = 600.0  # 3x beyond machine speed
        failures, _ = compare_rows(BASE, fresh, 0.25, scale=2.0)
        assert len(failures) == 1 and "loop_time" in failures[0]

    def test_default_scale_is_raw_comparison(self):
        fresh = {
            ("vdp", "b16/loop_time"): 200.0,
            ("dispatch", "compiled/solves_per_sec"): 1000.0,
        }
        failures, _ = compare_rows(BASE, fresh, 0.25)  # positional back-compat
        assert len(failures) == 1

    def test_calibration_scale_extraction(self):
        from benchmarks.compare import calibration_scale

        scale, warn = calibration_scale({"calibration_us": 100.0},
                                        {"calibration_us": 250.0})
        assert scale == 2.5 and warn is None
        # missing / malformed / absurd ratios refuse to normalize (scale 1)
        for base, fresh in (({}, {"calibration_us": 1.0}),
                            ({"calibration_us": "x"}, {"calibration_us": 1.0}),
                            ({"calibration_us": 1.0}, {"calibration_us": 1e4})):
            scale, warn = calibration_scale(base, fresh)
            assert scale == 1.0 and warn is not None

    def test_normalized_file_gate(self, tmp_path):
        base = self._write_cal(tmp_path, "base.json", BASE, 100.0)
        slow = self._write_cal(
            tmp_path, "slow.json",
            {("vdp", "b16/loop_time"): 200.0,
             ("dispatch", "compiled/solves_per_sec"): 500.0,
             ("vdp", "joint_vs_parallel_step_ratio"): 5.0},
            200.0)
        assert compare_files(base, slow, 0.25) != []          # raw: fails
        assert compare_files(base, slow, 0.25, normalize=True) == []
        assert main([base, slow, "--normalize"]) == 0
        assert main([base, slow]) == 1

    def test_runner_payload_carries_calibration(self):
        from benchmarks.common import calibration_us

        cal = calibration_us(repeats=1)
        assert cal > 0.0
