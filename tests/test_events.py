"""Event subsystem tests: the masked_bisect_refine kernel contract
(ref vs Pallas interpret), per-instance detection/localization semantics,
driver plumbing and the Solution/statistics surface.

Golden comparisons against scipy live in test_events_golden.py; hypothesis
permutation properties in test_solver_properties.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AutoDiffAdjoint,
    BacksolveAdjoint,
    Event,
    Status,
    make_solver,
    solve_ivp,
    solve_ivp_scan,
)
from repro.kernels import pallas_impl as pi, ref

G = 9.81


def ball(t, y, args):
    """Free fall: y = (height, velocity)."""
    return jnp.stack((y[..., 1], jnp.full_like(y[..., 1], -G)), axis=-1)


def hit_time(h0, v0):
    return (v0 + np.sqrt(v0**2 + 2.0 * G * h0)) / G


GROUND = Event(lambda t, y, args: y[0], terminal=True, direction=-1.0)


# ---------------------------------------------------------------- kernel op


class TestMaskedBisectRefine:
    SHAPES = [(1, 1), (3, 5), (8, 128), (17, 300), (2, 1025), (9, 64)]

    @pytest.mark.parametrize("b,f", SHAPES)
    def test_matches_ref(self, b, f):
        rng = np.random.default_rng(b * f + 1)
        coeffs = tuple(jnp.asarray(rng.standard_normal((b, f)), jnp.float32) for _ in range(4))
        lo = jnp.asarray(rng.uniform(0.0, 0.4, (b,)), jnp.float32)
        hi = jnp.asarray(rng.uniform(0.6, 1.0, (b,)), jnp.float32)
        v_lo = jnp.asarray(rng.standard_normal((b,)), jnp.float32)
        v_mid = jnp.asarray(rng.standard_normal((b,)), jnp.float32)
        active = jnp.asarray(rng.uniform(size=(b,)) > 0.4)
        r = ref.masked_bisect_refine(coeffs, lo, hi, v_lo, v_mid, active)
        p = pi.masked_bisect_refine(coeffs, lo, hi, v_lo, v_mid, active, interpret=True)
        for rr, pp in zip(r, p):
            np.testing.assert_allclose(np.asarray(rr), np.asarray(pp), rtol=1e-6, atol=1e-6)

    def test_inactive_rows_keep_bracket(self):
        coeffs = tuple(jnp.ones((2, 3)) for _ in range(4))
        lo = jnp.asarray([0.0, 0.25])
        hi = jnp.asarray([1.0, 0.75])
        v = jnp.asarray([-1.0, -1.0])
        lo2, hi2, _, mid2, _ = ref.masked_bisect_refine(
            coeffs, lo, hi, v, jnp.asarray([1.0, 1.0]), jnp.asarray([True, False])
        )
        # active row: sign change at mid -> bracket halves to [0, 0.5]
        np.testing.assert_allclose(np.asarray(lo2), [0.0, 0.25])
        np.testing.assert_allclose(np.asarray(hi2), [0.5, 0.75])
        np.testing.assert_allclose(np.asarray(mid2), [0.25, 0.5])

    def test_iterated_bisection_finds_polynomial_root(self):
        """Driving the op in the localizer's loop converges to the root of the
        cubic itself (the condition IS the first state feature here)."""
        # p(x) = x - 0.3125 (c1 = 1, c0 = -0.3125): root exactly representable
        b, f = 4, 3
        c0 = jnp.full((b, f), -0.3125)
        c1 = jnp.ones((b, f))
        zeros = jnp.zeros((b, f))
        coeffs = (c0, c1, zeros, zeros)
        lo, hi = jnp.zeros((b,)), jnp.ones((b,))
        v_lo = jnp.full((b,), -0.3125)
        active = jnp.asarray([True, True, True, False])
        carry = ref.masked_bisect_refine(coeffs, lo, hi, v_lo, v_lo, jnp.zeros((b,), bool))
        for _ in range(30):
            lo, hi, v_lo, mid, y_mid = carry
            carry = ref.masked_bisect_refine(coeffs, lo, hi, v_lo, y_mid[:, 0], active)
        mid = np.asarray(carry[3])
        np.testing.assert_allclose(mid[:3], 0.3125, atol=1e-6)
        np.testing.assert_allclose(mid[3], 0.5)  # inactive row never moved


# ------------------------------------------------------------ solve surface


class TestTerminalEvents:
    def test_mixed_batch_localization_accuracy(self):
        """Acceptance: event times within 10*rtol of analytic per instance in
        a mixed batch (different drop heights/velocities, one non-firing)."""
        rtol = 1e-6
        h0 = np.array([10.0, 5.0, 20.0, 500.0])
        v0 = np.array([0.0, 2.0, -1.0, 0.0])
        y0 = jnp.asarray(np.stack([h0, v0], 1), jnp.float32)
        sol = solve_ivp(ball, y0, None, t_start=0.0, t_end=5.0, events=GROUND,
                        rtol=rtol, atol=1e-9)
        status = np.asarray(sol.status)
        assert list(status) == [Status.EVENT.value] * 3 + [Status.SUCCESS.value]
        t_ev = np.asarray(sol.event_t)[:, 0]
        expect = hit_time(h0, v0)
        np.testing.assert_allclose(t_ev[:3], expect[:3], rtol=10 * rtol)
        assert np.isnan(t_ev[3]) and not bool(np.asarray(sol.event_mask)[3, 0])
        # the instance rests AT the event: ts is the event time, height ~ 0
        np.testing.assert_allclose(np.asarray(sol.ts)[:3], t_ev[:3])
        np.testing.assert_allclose(np.asarray(sol.event_y)[:3, 0, 0], 0.0, atol=1e-5)
        np.testing.assert_allclose(np.asarray(sol.ys)[:3, 0], 0.0, atol=1e-5)

    def test_zero_extra_vf_evaluations(self):
        """Localization runs on interpolant coefficients only: a NON-terminal
        event (same trajectory, same steps) leaves n_f_evals untouched."""
        y0 = jnp.asarray([[10.0, 0.0]], jnp.float32)
        marker = Event(lambda t, y, args: y[0] - 5.0, terminal=False)
        kw = dict(t_start=0.0, t_end=1.2, rtol=1e-6, atol=1e-9)
        plain = solve_ivp(ball, y0, None, **kw)
        with_ev = solve_ivp(ball, y0, None, events=marker, **kw)
        assert np.asarray(with_ev.stats["n_events"])[0] == 1
        np.testing.assert_array_equal(np.asarray(plain.stats["n_f_evals"]),
                                      np.asarray(with_ev.stats["n_f_evals"]))
        np.testing.assert_array_equal(np.asarray(plain.stats["n_steps"]),
                                      np.asarray(with_ev.stats["n_steps"]))

    def test_dense_output_truncated_past_event(self):
        y0 = jnp.asarray([[10.0, 0.0], [200.0, 0.0]], jnp.float32)
        t_eval = jnp.linspace(0.0, 3.0, 31)
        sol = solve_ivp(ball, y0, t_eval, events=GROUND, rtol=1e-6, atol=1e-9)
        t_hit = hit_time(10.0, 0.0)
        n_pre = int((np.asarray(t_eval) <= t_hit).sum())
        ninit = np.asarray(sol.stats["n_initialized"])
        assert ninit[0] == n_pre and ninit[1] == 31
        ys = np.asarray(sol.ys)
        assert np.all(ys[0, n_pre:] == 0.0)  # truncated tail untouched
        te = np.asarray(t_eval[:n_pre])
        np.testing.assert_allclose(ys[0, :n_pre, 0], 10.0 - 0.5 * G * te**2, atol=1e-4)

    def test_terminal_beats_success_on_final_step(self):
        """An event inside the very step that reaches t_end still wins."""
        y0 = jnp.asarray([[10.0, 0.0]], jnp.float32)
        t_hit = hit_time(10.0, 0.0)
        sol = solve_ivp(ball, y0, None, t_start=0.0, t_end=t_hit + 1e-3,
                        events=GROUND, rtol=1e-6, atol=1e-9)
        assert np.asarray(sol.status)[0] == Status.EVENT.value
        np.testing.assert_allclose(np.asarray(sol.event_t)[0, 0], t_hit, rtol=1e-5)

    def test_backward_time_event(self):
        """Integrating the fall backwards from the ground state recovers the
        time the ball passed half height on the way down."""
        t_hit = hit_time(10.0, 0.0)
        y_end = jnp.asarray([[0.0, -G * t_hit]], jnp.float32)
        half = Event(lambda t, y, args: y[0] - 5.0, terminal=True)
        sol = solve_ivp(ball, y_end, None, t_start=t_hit, t_end=-1.0,
                        events=half, rtol=1e-6, atol=1e-9)
        assert np.asarray(sol.status)[0] == Status.EVENT.value
        # h(t) = 10 - G t^2 / 2 crosses 5 at t = sqrt(10/G)
        np.testing.assert_allclose(np.asarray(sol.event_t)[0, 0],
                                   np.sqrt(10.0 / G), rtol=1e-4)


class TestEventSemantics:
    def test_direction_filtering(self):
        """y[0] = sin(t + 0.5) falls through zero at pi - 0.5 and rises at
        2pi - 0.5 (the phase offset keeps the condition nonzero at t_start,
        which would otherwise fire immediately -- scipy semantics)."""
        def rot(t, y, args):
            return jnp.stack((y[..., 1], -y[..., 0]), axis=-1)

        y0 = jnp.asarray([[np.sin(0.5), np.cos(0.5)]], jnp.float32)
        kw = dict(t_start=0.0, t_end=8.0, rtol=1e-7, atol=1e-9)
        for direction, expect in [(-1.0, np.pi - 0.5), (1.0, 2.0 * np.pi - 0.5),
                                  (0.0, np.pi - 0.5)]:
            ev = Event(lambda t, y, args: y[0], terminal=True, direction=direction)
            sol = solve_ivp(rot, y0, None, events=ev, **kw)
            np.testing.assert_allclose(np.asarray(sol.event_t)[0, 0], expect, rtol=1e-4)

    def test_non_terminal_records_first_crossing_and_continues(self):
        y0 = jnp.asarray([[10.0, 0.0]], jnp.float32)
        ev = Event(lambda t, y, args: y[1] + 5.0, terminal=False, direction=-1.0)
        sol = solve_ivp(ball, y0, None, t_start=0.0, t_end=1.0, events=ev,
                        rtol=1e-6, atol=1e-9)
        assert np.asarray(sol.status)[0] == Status.SUCCESS.value
        np.testing.assert_allclose(np.asarray(sol.ts)[0], 1.0)
        np.testing.assert_allclose(np.asarray(sol.event_t)[0, 0], 5.0 / G, rtol=1e-5)

    def test_crossings_after_terminal_event_are_discarded(self):
        """A non-terminal crossing localized AFTER the earliest terminal event
        time lies beyond the instance's trajectory and must not be recorded."""
        y0 = jnp.asarray([[10.0, 0.0]], jnp.float32)
        # velocity crosses -15 at t ~ 1.53 > ground hit ~ 1.43; with loose
        # tolerances both sign changes can land inside one accepted step
        late = Event(lambda t, y, args: y[1] + 15.0, terminal=False, direction=-1.0)
        sol = solve_ivp(ball, y0, None, t_start=0.0, t_end=5.0,
                        events=[GROUND, late], rtol=1e-3, atol=1e-6)
        mask = np.asarray(sol.event_mask)[0]
        assert bool(mask[0]) and not bool(mask[1])
        assert np.asarray(sol.stats["n_events"])[0] == 1

    def test_multiple_terminal_events_earliest_wins(self):
        fast = Event(lambda t, y, args: y[1] + 5.0, terminal=True, direction=-1.0)
        sol = solve_ivp(ball, jnp.asarray([[10.0, 0.0]], jnp.float32), None,
                        t_start=0.0, t_end=5.0, events=[GROUND, fast],
                        rtol=1e-6, atol=1e-9)
        # velocity hits -5 at t = 5/G ~ 0.51, long before the ground at 1.43
        np.testing.assert_allclose(np.asarray(sol.ts)[0], 5.0 / G, rtol=1e-5)
        mask = np.asarray(sol.event_mask)[0]
        assert not bool(mask[0]) and bool(mask[1])

    def test_batched_and_no_args_conditions(self):
        evb = Event(lambda t, y: y[:, 0], terminal=True, direction=-1.0,
                    batched=True, with_args=False)
        sol = solve_ivp(ball, jnp.asarray([[10.0, 0.0]], jnp.float32), None,
                        t_start=0.0, t_end=5.0, events=evb, rtol=1e-6, atol=1e-9)
        np.testing.assert_allclose(np.asarray(sol.event_t)[0, 0],
                                   hit_time(10.0, 0.0), rtol=1e-5)

    def test_condition_args_flow_through(self):
        threshold = 4.0
        ev = Event(lambda t, y, args: y[0] - args, terminal=True, direction=-1.0)
        sol = solve_ivp(ball, jnp.asarray([[10.0, 0.0]], jnp.float32), None,
                        t_start=0.0, t_end=5.0, events=ev, args=threshold,
                        rtol=1e-6, atol=1e-9)
        np.testing.assert_allclose(np.asarray(sol.event_y)[0, 0, 0], threshold,
                                   atol=1e-4)


class TestEventDrivers:
    def test_scan_driver_matches_while_driver(self):
        y0 = jnp.asarray([[10.0, 0.0], [5.0, 2.0]], jnp.float32)
        kw = dict(t_start=0.0, t_end=5.0, events=GROUND, rtol=1e-6, atol=1e-9)
        a = solve_ivp(ball, y0, None, **kw)
        s = solve_ivp_scan(ball, y0, None, max_steps=64, **kw)
        np.testing.assert_allclose(np.asarray(a.event_t), np.asarray(s.event_t),
                                   rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(a.status), np.asarray(s.status))

    def test_pytree_state_conditions_see_the_tree(self):
        def dyn(t, y, args):  # per-instance PyTree dynamics
            return {"h": y["v"], "v": jnp.full_like(y["v"], -G)}

        y0 = {"h": jnp.asarray([[10.0]], jnp.float32),
              "v": jnp.asarray([[0.0]], jnp.float32)}
        ev = Event(lambda t, y, args: y["h"][0], terminal=True, direction=-1.0)
        drv = AutoDiffAdjoint("tsit5", rtol=1e-6, atol=1e-9, events=ev)
        sol = drv.solve(dyn, y0, None, t_start=0.0, t_end=5.0)
        np.testing.assert_allclose(np.asarray(sol.event_t)[0, 0],
                                   hit_time(10.0, 0.0), rtol=1e-5)
        # event_y unravels to the caller's structure with an (b, E, ...) leaf
        assert sol.event_y["h"].shape == (1, 1, 1)
        np.testing.assert_allclose(np.asarray(sol.event_y["h"])[0, 0, 0], 0.0,
                                   atol=1e-5)

    def test_pytree_batched_condition_rejected(self):
        ev = Event(lambda t, y, args: y, batched=True)
        drv = AutoDiffAdjoint("tsit5", events=ev)
        y0 = {"h": jnp.ones((1, 1))}
        with pytest.raises(ValueError, match="batched event conditions"):
            drv.solve(lambda t, y, args: y, y0, None, t_start=0.0, t_end=1.0)

    def test_backsolve_adjoint_rejects_events(self):
        with pytest.raises(ValueError, match="does not support events"):
            BacksolveAdjoint("tsit5", events=GROUND)

    def test_make_solver_triple_threads_events(self):
        init, body, finish = make_solver(ball, method="dopri5", rtol=1e-6,
                                         atol=1e-9, events=GROUND)
        state, consts = init(jnp.asarray([[10.0, 0.0]], jnp.float32), None,
                             0.0, 5.0, None, None)
        state = jax.lax.while_loop(
            lambda s: jnp.any(s.running) & (s.it < 1000),
            lambda s: body(s, consts, None),
            state,
        )
        sol = finish(state, consts)
        assert np.asarray(sol.status)[0] == Status.EVENT.value
        np.testing.assert_allclose(np.asarray(sol.event_t)[0, 0],
                                   hit_time(10.0, 0.0), rtol=1e-5)

    def test_event_termination_counts_as_success(self):
        """scipy convention: stopping at a terminal event is the intended
        outcome, so Solution.success includes Status.EVENT."""
        y0 = jnp.asarray([[10.0, 0.0], [200.0, 0.0]], jnp.float32)
        sol = solve_ivp(ball, y0, None, t_start=0.0, t_end=3.0, events=GROUND,
                        rtol=1e-6, atol=1e-9)
        assert list(np.asarray(sol.status)) == [Status.EVENT.value,
                                                Status.SUCCESS.value]
        assert np.all(np.asarray(sol.success))

    def test_solution_event_fields_default_none(self):
        sol = solve_ivp(ball, jnp.asarray([[10.0, 0.0]], jnp.float32), None,
                        t_start=0.0, t_end=0.5)
        assert sol.event_t is None and sol.event_y is None and sol.event_mask is None
        assert "n_events" not in sol.stats


class TestFinishReportsReachedTime:
    """Regression for Solution.ts when t_eval is None: the per-instance time
    actually reached, not a blanket t_end."""

    def test_early_stop_reports_last_accepted_time(self):
        def blowup(t, y, args):  # finite-time blowup at t = 1/y0
            return y * y

        y0 = jnp.asarray([[1.0], [0.1]], jnp.float32)
        sol = solve_ivp(blowup, y0, None, t_start=0.0, t_end=2.0, max_steps=5000)
        status = np.asarray(sol.status)
        ts = np.asarray(sol.ts)
        # instance 0 explodes at t = 1 and must stop strictly before t_end
        assert status[0] in (Status.INFINITE.value, Status.REACHED_DT_MIN.value)
        assert 0.0 < ts[0] <= 1.01
        # instance 1 is fine through t_end
        assert status[1] == Status.SUCCESS.value and ts[1] == 2.0

    def test_event_stop_reports_event_time(self):
        sol = solve_ivp(ball, jnp.asarray([[10.0, 0.0]], jnp.float32), None,
                        t_start=0.0, t_end=5.0, events=GROUND, rtol=1e-6, atol=1e-9)
        np.testing.assert_allclose(np.asarray(sol.ts)[0],
                                   np.asarray(sol.event_t)[0, 0])

    def test_max_steps_reports_partial_progress(self):
        def vdp(t, y, mu):
            x, xd = y[..., 0], y[..., 1]
            return jnp.stack((xd, mu * (1 - x**2) * xd - x), axis=-1)

        sol = solve_ivp(vdp, jnp.asarray([[2.0, 0.0]], jnp.float32), None,
                        t_start=0.0, t_end=100.0, args=50.0, max_steps=10)
        assert np.asarray(sol.status)[0] == Status.REACHED_MAX_STEPS.value
        assert 0.0 < float(np.asarray(sol.ts)[0]) < 100.0
