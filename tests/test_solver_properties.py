"""Hypothesis property tests on solver invariants (system-level)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import Status, solve_ivp


def decay(t, y, a):
    return -a * y


class TestLinearInvariants:
    @settings(max_examples=15, deadline=None)
    @given(a=st.floats(0.1, 3.0), t_end=st.floats(0.3, 3.0), seed=st.integers(0, 2**30))
    def test_matches_analytic_solution(self, a, t_end, seed):
        rng = np.random.default_rng(seed)
        y0 = jnp.asarray(rng.uniform(-2, 2, (3, 2)), jnp.float32)
        sol = solve_ivp(decay, y0, None, t_start=0.0, t_end=t_end, args=a,
                        atol=1e-8, rtol=1e-8, max_steps=20_000)
        exp = np.asarray(y0) * np.exp(-a * t_end)
        np.testing.assert_allclose(np.asarray(sol.ys), exp, rtol=1e-4, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(scale=st.floats(0.1, 10.0), seed=st.integers(0, 2**30))
    def test_linearity_of_linear_ode(self, scale, seed):
        """For linear dynamics, solve(c*y0) == c*solve(y0) (same step pattern:
        rtol-driven controller is scale-invariant for atol=0)."""
        rng = np.random.default_rng(seed)
        y0 = jnp.asarray(rng.uniform(0.5, 2, (2, 3)), jnp.float32)
        kw = dict(t_start=0.0, t_end=1.0, args=0.7, atol=0.0, rtol=1e-6)
        s1 = solve_ivp(decay, y0, None, **kw)
        s2 = solve_ivp(decay, y0 * scale, None, **kw)
        np.testing.assert_allclose(np.asarray(s2.ys), np.asarray(s1.ys) * scale,
                                   rtol=1e-4)
        np.testing.assert_array_equal(np.asarray(s1.stats["n_steps"]),
                                      np.asarray(s2.stats["n_steps"]))


class TestBatchInvariants:
    @settings(max_examples=10, deadline=None)
    @given(perm_seed=st.integers(0, 2**30))
    def test_permutation_equivariance(self, perm_seed):
        """Solving a permuted batch returns permuted solutions & stats --
        instances truly do not interact."""
        rng = np.random.default_rng(0)
        y0 = jnp.asarray(rng.uniform(-1, 1, (6, 2)), jnp.float32)

        def vdp(t, y, mu):
            x, xd = y[..., 0], y[..., 1]
            return jnp.stack((xd, mu * (1 - x**2) * xd - x), axis=-1)

        perm = np.random.default_rng(perm_seed).permutation(6)
        s1 = solve_ivp(vdp, y0, None, t_start=0.0, t_end=3.0, args=4.0)
        s2 = solve_ivp(vdp, y0[perm], None, t_start=0.0, t_end=3.0, args=4.0)
        np.testing.assert_allclose(np.asarray(s2.ys), np.asarray(s1.ys)[perm],
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(s2.stats["n_steps"]),
                                      np.asarray(s1.stats["n_steps"])[perm])

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 40), seed=st.integers(0, 2**30))
    def test_dense_output_count_and_monotone_time(self, n, seed):
        rng = np.random.default_rng(seed)
        t_eval = jnp.asarray(np.sort(rng.uniform(0, 2, n)), jnp.float32)
        y0 = jnp.ones((2, 1))
        sol = solve_ivp(decay, y0, t_eval, args=1.0, t_start=0.0, t_end=2.0)
        assert np.all(np.asarray(sol.stats["n_initialized"]) == n)
        # solution along a decay is monotone decreasing in eval time
        ys = np.asarray(sol.ys)[:, :, 0]
        assert np.all(np.diff(ys, axis=1) <= 1e-5)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**30))
    def test_status_success_iff_reached_end(self, seed):
        rng = np.random.default_rng(seed)
        y0 = jnp.asarray(rng.uniform(-1, 1, (3, 2)), jnp.float32)
        sol = solve_ivp(decay, y0, None, t_start=0.0, t_end=1.0, args=1.0)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
