"""Hypothesis property tests on solver invariants (system-level)."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import Event, Status, solve_ivp


def decay(t, y, a):
    return -a * y


def vdp_mu(t, y, mu):
    """Van der Pol with a per-instance (b,) stiffness argument."""
    x, xd = y[..., 0], y[..., 1]
    return jnp.stack((xd, mu * (1 - x**2) * xd - x), axis=-1)


class TestLinearInvariants:
    @settings(max_examples=15, deadline=None)
    @given(a=st.floats(0.1, 3.0), t_end=st.floats(0.3, 3.0), seed=st.integers(0, 2**30))
    def test_matches_analytic_solution(self, a, t_end, seed):
        rng = np.random.default_rng(seed)
        y0 = jnp.asarray(rng.uniform(-2, 2, (3, 2)), jnp.float32)
        sol = solve_ivp(decay, y0, None, t_start=0.0, t_end=t_end, args=a,
                        atol=1e-8, rtol=1e-8, max_steps=20_000)
        exp = np.asarray(y0) * np.exp(-a * t_end)
        np.testing.assert_allclose(np.asarray(sol.ys), exp, rtol=1e-4, atol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(scale=st.floats(0.1, 10.0), seed=st.integers(0, 2**30))
    def test_linearity_of_linear_ode(self, scale, seed):
        """For linear dynamics, solve(c*y0) == c*solve(y0) (same step pattern:
        rtol-driven controller is scale-invariant for atol=0)."""
        rng = np.random.default_rng(seed)
        y0 = jnp.asarray(rng.uniform(0.5, 2, (2, 3)), jnp.float32)
        kw = dict(t_start=0.0, t_end=1.0, args=0.7, atol=0.0, rtol=1e-6)
        s1 = solve_ivp(decay, y0, None, **kw)
        s2 = solve_ivp(decay, y0 * scale, None, **kw)
        np.testing.assert_allclose(np.asarray(s2.ys), np.asarray(s1.ys) * scale,
                                   rtol=1e-4)
        np.testing.assert_array_equal(np.asarray(s1.stats["n_steps"]),
                                      np.asarray(s2.stats["n_steps"]))


class TestBatchInvariants:
    @settings(max_examples=10, deadline=None)
    @given(perm_seed=st.integers(0, 2**30))
    def test_permutation_equivariance(self, perm_seed):
        """Solving a permuted batch returns permuted solutions & stats --
        instances truly do not interact."""
        rng = np.random.default_rng(0)
        y0 = jnp.asarray(rng.uniform(-1, 1, (6, 2)), jnp.float32)

        def vdp(t, y, mu):
            x, xd = y[..., 0], y[..., 1]
            return jnp.stack((xd, mu * (1 - x**2) * xd - x), axis=-1)

        perm = np.random.default_rng(perm_seed).permutation(6)
        s1 = solve_ivp(vdp, y0, None, t_start=0.0, t_end=3.0, args=4.0)
        s2 = solve_ivp(vdp, y0[perm], None, t_start=0.0, t_end=3.0, args=4.0)
        np.testing.assert_allclose(np.asarray(s2.ys), np.asarray(s1.ys)[perm],
                                   rtol=2e-5, atol=2e-5)
        np.testing.assert_array_equal(np.asarray(s2.stats["n_steps"]),
                                      np.asarray(s1.stats["n_steps"])[perm])

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(2, 40), seed=st.integers(0, 2**30))
    def test_dense_output_count_and_monotone_time(self, n, seed):
        rng = np.random.default_rng(seed)
        t_eval = jnp.asarray(np.sort(rng.uniform(0, 2, n)), jnp.float32)
        y0 = jnp.ones((2, 1))
        sol = solve_ivp(decay, y0, t_eval, args=1.0, t_start=0.0, t_end=2.0)
        assert np.all(np.asarray(sol.stats["n_initialized"]) == n)
        # solution along a decay is monotone decreasing in eval time
        ys = np.asarray(sol.ys)[:, :, 0]
        assert np.all(np.diff(ys, axis=1) <= 1e-5)

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**30))
    def test_status_success_iff_reached_end(self, seed):
        rng = np.random.default_rng(seed)
        y0 = jnp.asarray(rng.uniform(-1, 1, (3, 2)), jnp.float32)
        sol = solve_ivp(decay, y0, None, t_start=0.0, t_end=1.0, args=1.0)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)


class TestBatchMatchesSoloSolves:
    """The paper's headline property, adversarially: batching, shuffling and
    padding a batch must not change any instance's solution."""

    @settings(max_examples=5, deadline=None)
    @given(perm_seed=st.integers(0, 2**30), mu_lo=st.floats(0.1, 2.0))
    def test_shuffled_mixed_stiffness_batch_matches_solo(self, perm_seed, mu_lo):
        """A shuffled batch mixing stiff and non-stiff VdP instances (solved
        implicitly) reproduces each instance's solo solve: per-instance
        Jacobians, Newton masks and controller state never leak across
        the batch."""
        rng = np.random.default_rng(perm_seed)
        mu = np.array([mu_lo, 5.0, 50.0, 200.0])[rng.permutation(4)]
        y0 = np.tile(np.array([[2.0, 0.0]]), (4, 1)) + rng.uniform(-0.1, 0.1, (4, 2))
        kw = dict(t_start=0.0, t_end=3.0, method="kvaerno5", rtol=1e-5,
                  atol=1e-7, max_steps=5000)
        batch = solve_ivp(vdp_mu, jnp.asarray(y0, jnp.float32), None,
                          args=jnp.asarray(mu, jnp.float32), **kw)
        assert np.all(np.asarray(batch.status) == Status.SUCCESS.value)
        for i in range(4):
            solo = solve_ivp(vdp_mu, jnp.asarray(y0[i:i + 1], jnp.float32), None,
                             args=jnp.asarray(mu[i:i + 1], jnp.float32), **kw)
            np.testing.assert_allclose(np.asarray(batch.ys)[i], np.asarray(solo.ys)[0],
                                       rtol=1e-4, atol=1e-5)
            assert int(np.asarray(batch.stats["n_steps"])[i]) == int(
                np.asarray(solo.stats["n_steps"])[0]
            )

    @settings(max_examples=5, deadline=None)
    @given(seed=st.integers(0, 2**30), pad=st.integers(1, 4))
    def test_padding_the_batch_leaves_instances_unchanged(self, seed, pad):
        rng = np.random.default_rng(seed)
        y0 = rng.uniform(0.5, 2.0, (3, 2))
        y_pad = np.concatenate([y0, rng.uniform(0.5, 2.0, (pad, 2))])
        kw = dict(t_start=0.0, t_end=2.0, args=0.8, rtol=1e-6, atol=1e-8)
        a = solve_ivp(decay, jnp.asarray(y0, jnp.float32), None, **kw)
        b = solve_ivp(decay, jnp.asarray(y_pad, jnp.float32), None, **kw)
        np.testing.assert_allclose(np.asarray(b.ys)[:3], np.asarray(a.ys),
                                   rtol=1e-6, atol=1e-7)
        np.testing.assert_array_equal(np.asarray(b.stats["n_steps"])[:3],
                                      np.asarray(a.stats["n_steps"]))


class TestEventInvariants:
    @settings(max_examples=8, deadline=None)
    @given(perm_seed=st.integers(0, 2**30))
    def test_event_times_permutation_invariant(self, perm_seed):
        """Localized event times follow a batch permutation exactly: event
        detection and bisection never mix instances."""
        g = 9.81

        def ball(t, y, args):
            return jnp.stack((y[..., 1], jnp.full_like(y[..., 1], -g)), axis=-1)

        rng = np.random.default_rng(0)
        h0 = rng.uniform(2.0, 30.0, 6)
        v0 = rng.uniform(-2.0, 3.0, 6)
        y0 = jnp.asarray(np.stack([h0, v0], 1), jnp.float32)
        ev = Event(lambda t, y, args: y[0], terminal=True, direction=-1.0)
        perm = np.random.default_rng(perm_seed).permutation(6)
        kw = dict(t_start=0.0, t_end=10.0, events=ev, rtol=1e-6, atol=1e-9)
        s1 = solve_ivp(ball, y0, None, **kw)
        s2 = solve_ivp(ball, y0[perm], None, **kw)
        assert np.all(np.asarray(s1.status) == Status.EVENT.value)
        np.testing.assert_allclose(np.asarray(s2.event_t),
                                   np.asarray(s1.event_t)[perm], rtol=1e-6)
        np.testing.assert_array_equal(np.asarray(s2.event_mask),
                                      np.asarray(s1.event_mask)[perm])
        # and every localized time matches the analytic impact time
        analytic = (v0 + np.sqrt(v0**2 + 2 * g * h0)) / g
        np.testing.assert_allclose(np.asarray(s1.event_t)[:, 0], analytic,
                                   rtol=1e-5)
