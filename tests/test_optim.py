"""Optimizer tests: AdamW math, schedule, and 8-bit moment parity."""

import jax
import jax.numpy as jnp

from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, cosine_lr
from repro.optim.quantized import (
    dequantize_blockwise,
    qadamw_init,
    qadamw_update,
    quantize_blockwise,
)


def quad_loss(params):
    return jnp.sum((params["w"] - 3.0) ** 2) + jnp.sum((params["b"] + 1.0) ** 2)


def make_params():
    return {"w": jnp.zeros((4, 300)), "b": jnp.zeros((7,))}


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0, total_steps=10_000)
        params = make_params()
        state = adamw_init(params)
        for _ in range(300):
            g = jax.grad(quad_loss)(params)
            params, state, _ = adamw_update(cfg, params, g, state)
        assert float(quad_loss(params)) < 0.05

    def test_cosine_schedule_endpoints(self):
        cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(cosine_lr(cfg, jnp.asarray(0))) < 0.11
        assert abs(float(cosine_lr(cfg, jnp.asarray(10))) - 1.0) < 1e-6
        assert float(cosine_lr(cfg, jnp.asarray(100))) < 1e-6


class TestQuantized:
    def test_blockwise_roundtrip(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 1000))
        q, s = quantize_blockwise(x)
        assert q.dtype == jnp.int8
        y = dequantize_blockwise(q, s, x.shape)
        assert float(jnp.max(jnp.abs(x - y))) < float(jnp.max(jnp.abs(x))) / 100

    def test_8bit_tracks_f32_adamw(self):
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0, total_steps=10_000)
        p32, p8 = make_params(), make_params()
        s32, s8 = adamw_init(p32), qadamw_init(p8)
        for _ in range(150):
            g32 = jax.grad(quad_loss)(p32)
            g8 = jax.grad(quad_loss)(p8)
            p32, s32, _ = adamw_update(cfg, p32, g32, s32)
            p8, s8, _ = qadamw_update(cfg, p8, g8, s8)
        l32, l8 = float(quad_loss(p32)), float(quad_loss(p8))
        assert l8 < 0.05, f"8-bit AdamW failed to converge: {l8}"
        assert abs(l8 - l32) < 0.05

    def test_moment_memory_ratio(self):
        p = {"w": jnp.zeros((1024, 1024))}
        f32_bytes = sum(l.size * 4 for l in jax.tree_util.tree_leaves(adamw_init(p)["m"]))
        q = qadamw_init(p)["m"]
        q_bytes = sum(
            l.size * l.dtype.itemsize for l in jax.tree_util.tree_leaves(q)
        )
        assert q_bytes < f32_bytes / 3.0
