"""Behavioural tests of the batch-parallel ODE solver (the paper's core)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Status,
    integral_controller,
    pid_controller,
    solve_ivp,
    solve_ivp_scan,
)


def exp_decay(t, y, args):
    return -y


def vdp(t, y, mu):
    x, xd = y[..., 0], y[..., 1]
    return jnp.stack((xd, mu * (1 - x**2) * xd - x), axis=-1)


class TestAccuracy:
    @pytest.mark.parametrize("method,tol,err", [
        ("heun", 1e-6, 1e-3), ("bosh3", 1e-8, 1e-4),
        ("dopri5", 1e-8, 1e-4), ("tsit5", 1e-8, 1e-4),
    ])
    def test_exponential_decay(self, method, tol, err):
        y0 = jnp.array([[1.0], [2.0], [0.5]])
        t_eval = jnp.linspace(0.0, 2.0, 21)
        sol = solve_ivp(exp_decay, y0, t_eval, method=method, atol=tol, rtol=tol,
                        max_steps=50_000)
        expected = np.asarray(y0)[:, None, :] * np.exp(-np.asarray(t_eval))[None, :, None]
        assert np.abs(np.asarray(sol.ys) - expected).max() < err
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)

    @pytest.mark.parametrize("method,dt,err", [
        ("euler", 1e-3, 1e-3), ("midpoint", 1e-2, 1e-4), ("rk4", 5e-2, 1e-6),
    ])
    def test_fixed_step_methods(self, method, dt, err):
        y0 = jnp.ones((2, 1))
        sol = solve_ivp(exp_decay, y0, None, t_start=0.0, t_end=1.0, method=method,
                        dt0=dt, max_steps=1100)
        assert np.abs(np.asarray(sol.ys)[:, 0] - np.exp(-1)).max() < err

    def test_harmonic_oscillator_energy(self):
        def f(t, y, args):
            return jnp.stack((y[..., 1], -y[..., 0]), axis=-1)

        y0 = jnp.array([[1.0, 0.0]])
        sol = solve_ivp(f, y0, jnp.linspace(0, 2 * np.pi, 10), atol=1e-9, rtol=1e-9)
        energy = np.asarray(sol.ys[..., 0]) ** 2 + np.asarray(sol.ys[..., 1]) ** 2
        np.testing.assert_allclose(energy, 1.0, atol=1e-5)


class TestParallelIndependence:
    """The paper's central claim: per-instance state, no cross-talk."""

    def test_step_counts_differ_across_batch(self):
        y0 = jnp.stack([jnp.array([2.0, 0.0]) + 0.3 * i for i in range(5)])
        sol = solve_ivp(vdp, y0, jnp.linspace(0, 10, 20), args=10.0)
        steps = np.asarray(sol.stats["n_steps"])
        assert len(set(steps.tolist())) > 1, "instances should step independently"

    def test_batching_does_not_change_solution(self):
        """Solving alone == solving batched with a stiff companion (torchode's
        guarantee; joint solvers violate this)."""
        y_easy = jnp.array([[1.0, 0.0]])
        t_eval = jnp.linspace(0, 5, 10)
        alone = solve_ivp(vdp, y_easy, t_eval, args=1.0)
        stiff_pair = jnp.concatenate([y_easy, jnp.array([[2.0, 0.0]])])

        def mixed(t, y, _):
            mu = jnp.array([1.0, 25.0])[:, None] * jnp.ones_like(y[..., :1])
            x, xd = y[..., 0], y[..., 1]
            return jnp.stack((xd, mu[..., 0] * (1 - x**2) * xd - x), axis=-1)

        together = solve_ivp(mixed, stiff_pair, t_eval)
        np.testing.assert_allclose(
            np.asarray(alone.ys[0]), np.asarray(together.ys[0]), rtol=1e-3, atol=1e-4
        )
        assert np.asarray(alone.stats["n_steps"])[0] == np.asarray(together.stats["n_steps"])[0]

    def test_per_instance_ranges_and_direction(self):
        y0 = jnp.ones((3, 1))
        t_start = jnp.array([0.0, 0.0, 1.0])
        t_end = jnp.array([1.0, 2.0, -1.0])
        sol = solve_ivp(exp_decay, y0, None, t_start=t_start, t_end=t_end,
                        atol=1e-9, rtol=1e-9)
        exp = np.exp(-(np.asarray(t_end) - np.asarray(t_start)))
        np.testing.assert_allclose(np.asarray(sol.ys)[:, 0], exp, rtol=1e-5)

    def test_windowed_dense_output_matches_full(self):
        """dense_window (beyond-paper optimization) is bit-compatible with the
        evaluate-all-masked path."""
        y0 = jnp.stack([jnp.array([2.0, 0.0]) + 0.2 * i for i in range(4)])
        t_eval = jnp.linspace(0.0, 8.0, 100)
        full = solve_ivp(vdp, y0, t_eval, args=5.0, atol=1e-7, rtol=1e-7)
        for w in (4, 16):
            win = solve_ivp(vdp, y0, t_eval, args=5.0, atol=1e-7, rtol=1e-7,
                            dense_window=w)
            np.testing.assert_allclose(np.asarray(win.ys), np.asarray(full.ys),
                                       rtol=1e-4, atol=1e-5)
            assert np.all(np.asarray(win.stats["n_initialized"]) == 100)

    def test_per_instance_t_eval(self):
        y0 = jnp.ones((2, 1))
        t_eval = jnp.stack([jnp.linspace(0, 1, 5), jnp.linspace(0, 3, 5)])
        sol = solve_ivp(exp_decay, y0, t_eval, atol=1e-9, rtol=1e-9)
        np.testing.assert_allclose(
            np.asarray(sol.ys)[..., 0], np.exp(-np.asarray(t_eval)), rtol=1e-4
        )

    def test_per_instance_tolerances(self):
        y0 = jnp.ones((2, 1))
        atol = jnp.array([1e-3, 1e-9])
        rtol = jnp.array([1e-3, 1e-9])
        sol = solve_ivp(exp_decay, y0, None, t_start=0.0, t_end=1.0, atol=atol, rtol=rtol)
        steps = np.asarray(sol.stats["n_steps"])
        assert steps[1] > steps[0], "tighter tolerance must take more steps"

    def test_mixed_tolerances_match_solo_solves(self):
        """(b,)-shaped atol/rtol thread through error_norm and the controller:
        a mixed-tolerance batch makes exactly the per-instance step decisions
        of separate single-instance solves (regression for the per-instance
        tolerance path)."""
        y0 = jnp.array([[1.0, 2.0], [1.0, 2.0], [1.0, 2.0]])
        atol = jnp.array([1e-3, 1e-6, 1e-9])
        rtol = jnp.array([1e-2, 1e-5, 1e-8])
        mixed = solve_ivp(vdp, y0, None, t_start=0.0, t_end=4.0, args=3.0,
                          atol=atol, rtol=rtol, max_steps=4000)
        assert np.all(np.asarray(mixed.status) == Status.SUCCESS.value)
        for i in range(3):
            solo = solve_ivp(vdp, y0[i : i + 1], None, t_start=0.0, t_end=4.0, args=3.0,
                             atol=atol[i : i + 1], rtol=rtol[i : i + 1], max_steps=4000)
            assert int(np.asarray(mixed.stats["n_steps"])[i]) == int(
                np.asarray(solo.stats["n_steps"])[0]
            )
            np.testing.assert_allclose(
                np.asarray(mixed.ys)[i], np.asarray(solo.ys)[0], rtol=1e-6, atol=1e-6
            )

    def test_mixed_tolerances_implicit(self):
        """Per-instance tolerances also steer the implicit path (and its
        Newton convergence scale)."""
        y0 = jnp.ones((2, 1))
        atol = jnp.array([1e-3, 1e-7])
        rtol = jnp.array([1e-2, 1e-6])
        sol = solve_ivp(exp_decay, y0, None, t_start=0.0, t_end=1.0,
                        method="kvaerno5", atol=atol, rtol=rtol, max_steps=2000)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
        steps = np.asarray(sol.stats["n_steps"])
        assert steps[1] > steps[0]
        # the tight instance actually achieves its accuracy
        assert abs(float(sol.ys[1, 0]) - np.exp(-1.0)) < 1e-4


class TestStats:
    def test_listing1_semantics(self):
        """n_f_evals equal across batch; n_steps/accepted per-instance."""
        y0 = jax.random.normal(jax.random.PRNGKey(0), (5, 2))
        sol = solve_ivp(vdp, y0, jnp.linspace(0.0, 10.0, 50), method="tsit5", args=10.0)
        stats = {k: np.asarray(v) for k, v in sol.stats.items()}
        assert np.all(stats["n_f_evals"] == stats["n_f_evals"][0])
        assert np.all(stats["n_accepted"] <= stats["n_steps"])
        assert np.all(stats["n_initialized"] == 50)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)

    def test_max_steps_status(self):
        sol = solve_ivp(vdp, jnp.array([[2.0, 0.0]]), None, t_start=0.0, t_end=100.0,
                        args=50.0, max_steps=10)
        assert np.asarray(sol.status)[0] == Status.REACHED_MAX_STEPS.value

    def test_infinite_dynamics_stops(self):
        def bad(t, y, args):
            return y * jnp.inf

        sol = solve_ivp(bad, jnp.ones((1, 1)), None, t_start=0.0, t_end=1.0, max_steps=200)
        assert np.asarray(sol.status)[0] in (
            Status.INFINITE.value,
            Status.REACHED_DT_MIN.value,
            Status.REACHED_MAX_STEPS.value,
        )


class TestControllers:
    def test_pid_vs_integral_steps_on_stiff_vdp(self):
        """Appendix C: PID saves steps at high mu."""
        y0 = jnp.array([[2.0, 0.0]])
        kw = dict(t_start=0.0, t_end=20.0, args=40.0, max_steps=20000, atol=1e-6, rtol=1e-6)
        s_i = solve_ivp(vdp, y0, None, controller=integral_controller(), **kw)
        s_pid = solve_ivp(vdp, y0, None, controller=pid_controller(), **kw)
        n_i = int(np.asarray(s_i.stats["n_steps"])[0])
        n_pid = int(np.asarray(s_pid.stats["n_steps"])[0])
        # PID should not be drastically worse; at high stiffness usually better
        assert n_pid < 1.2 * n_i

    def test_controller_grows_step_on_smooth_problem(self):
        sol = solve_ivp(exp_decay, jnp.ones((1, 1)), None, t_start=0.0, t_end=10.0,
                        atol=1e-6, rtol=1e-3)
        assert int(np.asarray(sol.stats["n_steps"])[0]) < 60

    def test_stateful_fixed_controller_subclass_state_threads(self):
        """Every controller's returned state is threaded uniformly by the
        loop (regression: an isinstance(FixedController) special case used to
        freeze the state of FixedController subclasses, so a stateful
        third-party controller was silently stuck at its initial state)."""
        from repro.core import FixedController
        from repro.core.controller import ControllerState

        class RejectFirst(FixedController):
            """Rejects only the very first attempt, counting attempts in its
            own state.  With frozen state it would reject forever."""

            def __call__(self, err_ratio, dt, state, k):
                first = state.prev_inv_ratio == 0.0
                new = ControllerState(state.prev_inv_ratio + 1.0, state.prev2_inv_ratio)
                return ~first, dt, new

            def init(self, batch, dtype):
                zero = jnp.zeros((batch,), dtype=dtype)
                return ControllerState(zero, zero)

        sol = solve_ivp(exp_decay, jnp.ones((2, 1)), None, t_start=0.0, t_end=1.0,
                        method="rk4", dt0=0.05, controller=RejectFirst(), max_steps=100)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
        n_steps = np.asarray(sol.stats["n_steps"])
        n_accepted = np.asarray(sol.stats["n_accepted"])
        assert np.all(n_steps == n_accepted + 1)  # exactly the one rejection


@pytest.mark.reverse_diff
class TestDifferentiability:
    def test_scan_gradient_matches_analytic(self):
        def loss(a):
            s = solve_ivp_scan(lambda t, y, a_: -a_ * y, jnp.ones((2, 1)), None,
                               t_start=0.0, t_end=1.0, args=a, max_steps=64,
                               rtol=1e-6, atol=1e-8)
            return jnp.sum(s.ys)

        g = jax.grad(loss)(1.5)
        assert abs(float(g) - (-2 * np.exp(-1.5))) < 1e-4

    def test_scan_checkpointing(self):
        def loss(a):
            s = solve_ivp_scan(lambda t, y, a_: -a_ * y, jnp.ones((1, 1)), None,
                               t_start=0.0, t_end=1.0, args=a, max_steps=64,
                               checkpoint_every=16)
            return jnp.sum(s.ys)

        g1 = jax.grad(loss)(1.5)
        def loss2(a):
            s = solve_ivp_scan(lambda t, y, a_: -a_ * y, jnp.ones((1, 1)), None,
                               t_start=0.0, t_end=1.0, args=a, max_steps=64)
            return jnp.sum(s.ys)
        g2 = jax.grad(loss2)(1.5)
        np.testing.assert_allclose(float(g1), float(g2), rtol=1e-5)


class TestJit:
    def test_whole_solver_jits_without_host_sync(self):
        f = jax.jit(lambda y0: solve_ivp(vdp, y0, jnp.linspace(0, 5, 10), args=5.0).ys)
        out = f(jnp.array([[2.0, 0.0]] * 4))
        assert out.shape == (4, 10, 2)
        # second call hits the cache
        out2 = f(jnp.array([[1.0, 0.5]] * 4))
        assert np.all(np.isfinite(np.asarray(out2)))
