"""Pallas flash-attention forward kernel vs the quadratic jnp oracle
(interpret mode -- the TPU-target kernel's correctness gate)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attn import flash_attention_fwd, ref

CASES = [
    # b, s, H, KV, hd, causal, qc, kc
    (1, 32, 2, 2, 8, True, 16, 16),
    (2, 64, 4, 2, 16, True, 16, 32),
    (1, 64, 4, 4, 16, False, 32, 16),
    (2, 128, 8, 2, 32, True, 32, 64),
    (1, 128, 4, 1, 16, True, 64, 32),  # MQA
]


@pytest.mark.parametrize("b,s,H,KV,hd,causal,qc,kc", CASES)
def test_matches_oracle(b, s, H, KV, hd, causal, qc, kc):
    rng = np.random.default_rng(b * s + H)
    q = jnp.asarray(rng.standard_normal((b, s, H, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, KV, hd)), jnp.float32)
    o1 = flash_attention_fwd(q, k, v, causal=causal, q_chunk=qc, kv_chunk=kc,
                             interpret=True)
    o2 = ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5, atol=2e-5)


def test_bf16_inputs_f32_accum():
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, 64, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.bfloat16)
    o1 = flash_attention_fwd(q, k, v, q_chunk=32, kv_chunk=32, interpret=True)
    o2 = ref(q, k, v)
    assert o1.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o1, np.float32), np.asarray(o2, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_matches_model_flash_path():
    """The kernel and the scan-based jnp flash (models/attention.py) agree."""
    from repro.models.attention import flash_attention

    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((2, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 64, 2, 16)), jnp.float32)
    o1 = flash_attention_fwd(q, k, v, q_chunk=16, kv_chunk=16, interpret=True)
    o2 = flash_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=2e-5, atol=2e-5)
