"""The implicit (SDIRK) stepper hierarchy + batched masked-Newton subsystem.

Covers: non-stiff correctness of every implicit tableau, the stiff acceptance
criteria (Robertson + Van der Pol mu=1000 vs float64 BDF references, step-count
ratio vs dopri5), the ``vf_jac`` hook, per-instance Newton masking/statistics,
Jacobian reuse, and the divergence -> controller-reject path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AbstractStepper,
    AutoDiffAdjoint,
    BacksolveAdjoint,
    DiagonallyImplicitRK,
    ExplicitRK,
    NewtonConfig,
    ODETerm,
    Status,
    Stepper,
    newton_solve,
    solve_ivp,
)

IMPLICIT_METHODS = ["implicit_euler", "trbdf2", "kvaerno3", "kvaerno5"]


def exp_decay(t, y, args):
    return -y


def vdp(t, y, mu):
    x, xd = y[..., 0], y[..., 1]
    return jnp.stack((xd, mu * (1 - x**2) * xd - x), axis=-1)


def robertson(t, y, args):
    y1, y2, _ = y[..., 0], y[..., 1], y[..., 2]
    r1 = -0.04 * y1 + 1e4 * y[..., 1] * y[..., 2]
    r3 = 3e7 * y2 * y2
    return jnp.stack((r1, -r1 - r3, r3), axis=-1)


def scipy_reference(f, y0, t_end):
    scipy_integrate = pytest.importorskip("scipy.integrate")
    out = []
    for row in np.asarray(y0):
        sol = scipy_integrate.solve_ivp(
            f, (0.0, t_end), row, method="BDF", rtol=1e-10, atol=1e-13
        )
        assert sol.success
        out.append(sol.y[:, -1])
    return np.stack(out)


class TestHierarchy:
    def test_coerce_dispatches_on_tableau(self):
        assert isinstance(AbstractStepper.coerce("dopri5"), ExplicitRK)
        assert isinstance(AbstractStepper.coerce("kvaerno5"), DiagonallyImplicitRK)
        assert isinstance(AbstractStepper.coerce(None), ExplicitRK)
        s = DiagonallyImplicitRK("trbdf2")
        assert AbstractStepper.coerce(s) is s

    def test_stepper_alias_is_explicit(self):
        assert Stepper is ExplicitRK
        assert isinstance(Stepper("tsit5"), AbstractStepper)

    def test_explicit_rejects_implicit_tableau(self):
        with pytest.raises(ValueError, match="implicit"):
            ExplicitRK("kvaerno5")
        with pytest.raises(ValueError, match="explicit"):
            DiagonallyImplicitRK("dopri5")

    @pytest.mark.parametrize("method", IMPLICIT_METHODS)
    def test_tableau_consistency(self, method):
        from repro.core import get_tableau

        tab = get_tableau(method)
        assert tab.implicit
        assert tab.stiffly_accurate
        assert tab.diagonal > 0
        np.testing.assert_allclose(tab.a.sum(axis=1), tab.c, atol=1e-12)
        np.testing.assert_allclose(tab.b_sol.sum(), 1.0, atol=1e-12)


class TestNonStiffCorrectness:
    @pytest.mark.parametrize("method", ["trbdf2", "kvaerno3", "kvaerno5"])
    def test_exp_decay(self, method):
        sol = solve_ivp(exp_decay, jnp.ones((3, 2)), None, t_start=0.0, t_end=1.0,
                        method=method, atol=1e-7, rtol=1e-6, max_steps=2000)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
        np.testing.assert_allclose(np.asarray(sol.ys), np.exp(-1.0), rtol=1e-4)

    def test_implicit_euler_fixed_step(self):
        sol = solve_ivp(exp_decay, jnp.ones((2, 1)), None, t_start=0.0, t_end=1.0,
                        method="implicit_euler", dt0=1e-3, max_steps=1100)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
        # backward Euler is first order: error ~ dt
        np.testing.assert_allclose(np.asarray(sol.ys), np.exp(-1.0), rtol=2e-3)

    def test_dense_output(self):
        t_eval = jnp.linspace(0.0, 2.0, 17)
        sol = solve_ivp(exp_decay, jnp.ones((2, 3)), t_eval, method="kvaerno5",
                        atol=1e-7, rtol=1e-6)
        exp = np.broadcast_to(np.exp(-np.asarray(sol.ts))[..., None], sol.ys.shape)
        np.testing.assert_allclose(np.asarray(sol.ys), exp, rtol=1e-4, atol=1e-5)

    def test_component_api_driver(self):
        solver = AutoDiffAdjoint(DiagonallyImplicitRK("kvaerno3"), rtol=1e-6, atol=1e-7)
        sol = solver.solve(exp_decay, jnp.ones((2, 2)), None, t_start=0.0, t_end=1.0)
        np.testing.assert_allclose(np.asarray(sol.ys), np.exp(-1.0), rtol=1e-4)


class TestStiffAcceptance:
    """The PR's acceptance criteria: accuracy vs float64 references and the
    >= 10x accepted-step advantage over dopri5 at matched tolerances."""

    def test_vdp_mu1000(self):
        mu = 1000.0
        y0 = jnp.array([[2.0, 0.0], [1.5, 0.5]])
        ref = scipy_reference(
            lambda t, y: [y[1], mu * (1 - y[0] ** 2) * y[1] - y[0]], y0, 20.0
        )
        kw = dict(t_start=0.0, t_end=20.0, args=mu, atol=1e-6, rtol=1e-5)
        imp = solve_ivp(vdp, y0, None, method="kvaerno5", max_steps=20_000, **kw)
        assert np.all(np.asarray(imp.status) == Status.SUCCESS.value)
        rel = np.abs(np.asarray(imp.ys) - ref) / (1e-8 + np.abs(ref))
        assert rel.max() < 1e-4

        exp = solve_ivp(vdp, y0, None, method="dopri5", max_steps=100_000, **kw)
        assert np.all(np.asarray(exp.status) == Status.SUCCESS.value)
        ratio = np.asarray(exp.stats["n_accepted"]) / np.asarray(imp.stats["n_accepted"])
        assert ratio.min() >= 10.0

        # per-instance Newton statistics are populated
        n_newton = np.asarray(imp.stats["n_newton_iters"])
        assert n_newton.shape == (2,) and np.all(n_newton > 0)
        assert np.all(np.asarray(imp.stats["n_jac_evals"]) > 0)

    def test_robertson(self):
        y0 = jnp.array([[1.0, 0.0, 0.0]])
        ref = scipy_reference(
            lambda t, y: [
                -0.04 * y[0] + 1e4 * y[1] * y[2],
                0.04 * y[0] - 1e4 * y[1] * y[2] - 3e7 * y[1] ** 2,
                3e7 * y[1] ** 2,
            ],
            y0,
            100.0,
        )
        kw = dict(t_start=0.0, t_end=100.0, atol=1e-10, rtol=1e-5)
        imp = solve_ivp(robertson, y0, None, method="kvaerno5", max_steps=20_000, **kw)
        assert np.all(np.asarray(imp.status) == Status.SUCCESS.value)
        # relative accuracy incl. the ~1e-5-sized intermediate species
        rel = np.abs(np.asarray(imp.ys) - ref) / (1e-7 + np.abs(ref))
        assert rel.max() < 1e-4

        # dopri5 at the same tolerance grinds at the stability limit: cap its
        # budget and compare accepted steps (it does not even finish by 10x
        # the implicit count).
        imp_acc = int(np.asarray(imp.stats["n_accepted"])[0])
        exp = solve_ivp(robertson, y0, None, method="dopri5",
                        max_steps=min(40 * imp_acc, 20_000), **kw)
        exp_acc = int(np.asarray(exp.stats["n_accepted"])[0])
        assert exp_acc >= 10 * imp_acc  # even a capped run shows the gap


class TestNewtonSubsystem:
    def test_newton_solve_linear_exact(self):
        """For an affine map one Newton step with the exact Jacobian lands."""
        b, f = 4, 3
        rng = np.random.default_rng(0)
        W = jnp.asarray(0.3 * rng.standard_normal((f, f)), jnp.float32)
        bias = jnp.asarray(rng.standard_normal((f,)), jnp.float32)

        def eval_fn(k):
            return k @ W.T + bias

        M = jnp.broadcast_to(jnp.eye(f) - W, (b, f, f))
        res = newton_solve(eval_fn, jnp.zeros((b, f)), M, jnp.ones((b, f)),
                           config=NewtonConfig(tol=1e-5, max_iters=5))
        assert np.all(np.asarray(res.converged))
        fixed = np.asarray(res.k)
        np.testing.assert_allclose(fixed, np.asarray(eval_fn(res.k)), atol=1e-4)
        # converged on the second iterate (first lands, second certifies)
        assert np.all(np.asarray(res.n_iters) <= 2)

    def test_newton_divergence_flagged(self):
        def eval_fn(k):
            return 1e6 * k**2 + 100.0

        M = jnp.broadcast_to(jnp.eye(2), (3, 2, 2))
        res = newton_solve(eval_fn, jnp.ones((3, 2)), M, jnp.ones((3, 2)),
                           config=NewtonConfig(tol=1e-3, max_iters=6))
        assert np.all(np.asarray(res.diverged))
        assert not np.any(np.asarray(res.converged))

    def test_per_instance_masking(self):
        """Two very different instances in one batch (oscillatory mu=1 vs
        stiff mu=1000): each runs its own step sizes AND its own Newton
        iteration counts -- the convergence masks keep the inner solves
        independent per instance."""
        mu = jnp.array([1.0, 1000.0])
        y0 = jnp.array([[2.0, 0.0], [2.0, 0.0]])
        sol = solve_ivp(vdp, y0, None, t_start=0.0, t_end=10.0, args=mu,
                        method="kvaerno5", atol=1e-6, rtol=1e-5, max_steps=20_000)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
        n_newton = np.asarray(sol.stats["n_newton_iters"])
        n_steps = np.asarray(sol.stats["n_steps"])
        assert np.all(n_newton > 0)
        assert n_newton[0] != n_newton[1]  # per-instance, not batch-shared
        assert n_steps[0] != n_steps[1]

    def test_jacobian_reuse(self):
        """On a smooth problem the chord Jacobian is reused across many
        steps: far fewer Jacobian evaluations than accepted steps."""
        sol = solve_ivp(vdp, jnp.array([[2.0, 0.0]]), None, t_start=0.0, t_end=20.0,
                        args=1000.0, method="kvaerno5", atol=1e-6, rtol=1e-5,
                        max_steps=20_000)
        n_jac = int(np.asarray(sol.stats["n_jac_evals"])[0])
        n_steps = int(np.asarray(sol.stats["n_steps"])[0])
        assert 0 < n_jac < n_steps

    def test_fixed_step_newton_failure_is_not_success(self):
        """A failed nonlinear solve must never be committed, even by the
        always-accept FixedController: a fixed-step implicit solve whose
        Newton iteration cannot converge ends in REACHED_MAX_STEPS, not a
        silently wrong SUCCESS (regression)."""
        # One starved Newton iteration at a hopeless tolerance can never
        # certify convergence on a nonlinear problem.
        stepper = DiagonallyImplicitRK(
            "implicit_euler", newton=NewtonConfig(tol=1e-12, max_iters=1))
        solver = AutoDiffAdjoint(stepper, max_steps=50)
        sol = solver.solve(lambda t, y, a: -(y**3), jnp.full((2, 1), 2.0), None,
                           t_start=0.0, t_end=1.0, dt0=0.25)
        assert np.all(np.asarray(sol.status) == Status.REACHED_MAX_STEPS.value)
        assert np.all(np.asarray(sol.stats["n_accepted"]) == 0)
        # the state was never polluted by a garbage iterate
        np.testing.assert_allclose(np.asarray(sol.ys), 2.0)

    def test_backsolve_adjoint_keeps_newton_knobs(self):
        """make_adjoint_solve must thread the stepper object itself (not just
        its tableau), so Newton configuration survives into the forward and
        backward solves (regression)."""
        from repro.core.adjoint import make_adjoint_solve

        # Starved Newton at an impossible tolerance fails every step: if the
        # knobs survive, the forward solve visibly fails to advance.
        starved = DiagonallyImplicitRK(
            "kvaerno3", newton=NewtonConfig(tol=1e-14, max_iters=1))
        solve = make_adjoint_solve(lambda t, y, p: -(y**3), method=starved,
                                   max_steps=30)
        y_starved = np.asarray(solve(jnp.full((1, 1), 2.0), 0.0, 1.0, None))
        np.testing.assert_allclose(y_starved, 2.0)  # no step ever accepted

        healthy = DiagonallyImplicitRK("kvaerno3")
        solve_ok = make_adjoint_solve(lambda t, y, p: -(y**3), method=healthy,
                                      max_steps=200, rtol=1e-6, atol=1e-8)
        y_ok = np.asarray(solve_ok(jnp.full((1, 1), 2.0), 0.0, 1.0, None))
        # y' = -y^3, y(0)=2  ->  y(1) = 2/3
        np.testing.assert_allclose(y_ok, 2.0 / 3.0, rtol=1e-4)

    def test_divergence_rejects_and_recovers(self):
        """A starved Newton budget fails on the large steps the controller
        proposes along the stiff slow manifold; each failure is reported
        through the ordinary controller reject path (visible as rejected
        steps) and the solver still finishes correctly on retried steps."""
        stepper = DiagonallyImplicitRK("kvaerno5", newton=NewtonConfig(max_iters=2))
        solver = AutoDiffAdjoint(stepper, rtol=1e-5, atol=1e-6, max_steps=20_000)
        sol = solver.solve(vdp, jnp.array([[2.0, 0.0]]), None,
                           t_start=0.0, t_end=20.0, args=1000.0)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
        n_steps = np.asarray(sol.stats["n_steps"])
        n_accepted = np.asarray(sol.stats["n_accepted"])
        assert np.all(n_steps > n_accepted)  # rejects happened


class TestNewtonConfigAPI:
    """The consolidated ``NewtonConfig`` surface: ``newton=`` is the one
    configuration path, legacy kwargs are deprecated aliases, and
    ``newton_solve`` is config-first."""

    def test_legacy_kwargs_warn_and_alias(self):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            legacy = DiagonallyImplicitRK("kvaerno3", newton_tol=1e-4,
                                          max_newton_iters=11, slow_iters=3)
        modern = DiagonallyImplicitRK(
            "kvaerno3", newton=NewtonConfig(tol=1e-4, max_iters=11, slow_iters=3))
        assert legacy.newton == modern.newton
        # the read-only properties survive as views over the config
        assert legacy.newton_tol == 1e-4
        assert legacy.max_newton_iters == 11
        assert legacy.slow_iters == 3

    def test_partial_legacy_kwargs_fill_defaults(self):
        with pytest.warns(DeprecationWarning):
            st = DiagonallyImplicitRK("trbdf2", max_newton_iters=3)
        assert st.newton == NewtonConfig(max_iters=3)
        assert st.newton_tol == NewtonConfig().tol

    def test_legacy_and_newton_conflict_raises(self):
        with pytest.raises(TypeError, match="cannot combine"):
            DiagonallyImplicitRK("kvaerno3", newton=NewtonConfig(),
                                 newton_tol=1e-4)

    def test_default_slow_iters_derives_from_budget(self):
        cfg = NewtonConfig(max_iters=8)
        assert cfg.effective_slow_iters == 4
        assert NewtonConfig(max_iters=2).effective_slow_iters == 2
        assert NewtonConfig(max_iters=8, slow_iters=6).effective_slow_iters == 6

    def test_newton_solve_rejects_loose_kwargs(self):
        M = jnp.broadcast_to(jnp.eye(2), (1, 2, 2))
        with pytest.raises(TypeError):
            newton_solve(lambda k: 0.5 * k, jnp.ones((1, 2)), M,
                         jnp.ones((1, 2)), tol=1e-5)
        with pytest.raises(TypeError):
            newton_solve(lambda k: 0.5 * k, jnp.ones((1, 2)), M,
                         jnp.ones((1, 2)), max_iters=5)

    def test_newton_solve_needs_exactly_one_matrix_path(self):
        M = jnp.broadcast_to(jnp.eye(2), (1, 2, 2))
        from repro.kernels import ops

        op = ops.batched_lu_factor(M)
        with pytest.raises(TypeError, match="exactly one"):
            newton_solve(lambda k: 0.5 * k, jnp.ones((1, 2)), M,
                         jnp.ones((1, 2)), operator=op)
        with pytest.raises(TypeError, match="exactly one"):
            newton_solve(lambda k: 0.5 * k, jnp.ones((1, 2)),
                         scale=jnp.ones((1, 2)))

    def test_operator_path_matches_matrix_path(self):
        """Config-first newton_solve: the prefactored-operator path converges
        to the same fixed point as the dense-matrix path."""
        from repro.kernels import ops

        b, f = 4, 3
        rng = np.random.default_rng(7)
        W = jnp.asarray(0.3 * rng.standard_normal((f, f)), jnp.float32)
        bias = jnp.asarray(rng.standard_normal((f,)), jnp.float32)

        def eval_fn(k):
            return k @ W.T + bias

        M = jnp.broadcast_to(jnp.eye(f) - W, (b, f, f))
        cfg = NewtonConfig(tol=1e-5, max_iters=5)
        res_m = newton_solve(eval_fn, jnp.zeros((b, f)), M, jnp.ones((b, f)),
                             config=cfg)
        res_op = newton_solve(eval_fn, jnp.zeros((b, f)),
                              operator=ops.batched_lu_factor(M),
                              scale=jnp.ones((b, f)), config=cfg)
        assert np.all(np.asarray(res_op.converged))
        # bitwise on the ref backend (verified by test_fused_implicit); the
        # interpret leg runs Gauss-Jordan vs LU, so allow rounding here
        np.testing.assert_allclose(np.asarray(res_m.k), np.asarray(res_op.k),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(res_m.n_iters),
                                      np.asarray(res_op.n_iters))


class TestVfJacHook:
    def test_custom_jacobian_matches_autodiff(self):
        A = jnp.asarray([[-1.0, 2.0], [0.0, -3.0]])

        def f(t, y, args):
            return y @ A.T

        term_auto = ODETerm(f)
        term_custom = ODETerm(f, f_jac=lambda t, y, args: jnp.broadcast_to(A, (y.shape[0], 2, 2)))
        t = jnp.zeros((3,))
        y = jnp.asarray(np.random.default_rng(0).standard_normal((3, 2)), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(term_auto.vf_jac(t, y, None)),
            np.asarray(term_custom.vf_jac(t, y, None)),
            atol=1e-6,
        )

    def test_custom_jacobian_drives_solver(self):
        A = jnp.asarray([[-2.0, 1.0], [1.0, -2.0]])
        term = ODETerm(lambda t, y, args: y @ A.T,
                       f_jac=lambda t, y, args: jnp.broadcast_to(A, (y.shape[0], 2, 2)))
        sol = solve_ivp(term, jnp.ones((2, 2)), None, t_start=0.0, t_end=1.0,
                        method="kvaerno5", atol=1e-7, rtol=1e-6)
        expm = np.asarray(jax.scipy.linalg.expm(np.asarray(A)))
        np.testing.assert_allclose(np.asarray(sol.ys), np.ones((2, 2)) @ expm.T, rtol=1e-4)

    def test_wrong_jacobian_costs_iterations(self):
        """The hook is really used: a zero Jacobian degrades the chord solve
        to fixed-point iteration, which needs more inner iterations."""
        def f(t, y, args):
            return -5.0 * y

        good = ODETerm(f)
        bad = ODETerm(f, f_jac=lambda t, y, args: jnp.zeros((y.shape[0], 2, 2)))
        kw = dict(t_start=0.0, t_end=1.0, method="kvaerno5", atol=1e-7, rtol=1e-6)
        sol_good = solve_ivp(good, jnp.ones((1, 2)), None, **kw)
        sol_bad = solve_ivp(bad, jnp.ones((1, 2)), None, **kw)
        assert np.all(np.asarray(sol_bad.status) == Status.SUCCESS.value)
        assert (np.asarray(sol_bad.stats["n_newton_iters"])[0]
                > np.asarray(sol_good.stats["n_newton_iters"])[0])

    def test_unbatched_term_jacobian(self):
        term = ODETerm(lambda t, y, args: -(y**3), batched=False)
        t = jnp.zeros((2,))
        y = jnp.asarray([[1.0, 2.0], [0.5, 1.5]])
        J = np.asarray(term.vf_jac(t, y, None))
        expect = np.stack([np.diag(-3.0 * np.asarray(row) ** 2) for row in y])
        np.testing.assert_allclose(J, expect, rtol=1e-5)


class TestBacksolveWithImplicit:
    @pytest.mark.reverse_diff
    def test_backsolve_adjoint_gradient(self):
        """BacksolveAdjoint wraps the solve in custom_vjp, so implicit
        steppers (with their inner while_loop) are reverse-differentiable."""
        driver = BacksolveAdjoint(DiagonallyImplicitRK("kvaerno3"), rtol=1e-7, atol=1e-8)

        def loss(a):
            y1 = driver.solve(lambda t, y, a_: a_ * y, jnp.ones((2, 2)),
                              t_start=0.0, t_end=1.0, args=a)
            return jnp.sum(y1)

        a0 = -1.5
        g = jax.grad(loss)(a0)
        # d/da sum(4 * exp(a)) = 4 * exp(a)
        np.testing.assert_allclose(float(g), 4.0 * np.exp(a0), rtol=1e-3)
