"""Gradient serving: training-time solves coalesce like forward solves.

A ``grad=True`` request's result -- the solution view *and* the pulled-back
gradients -- must be exactly what a solo VJP-compiled solve of that request
would produce.  The reference regime is the request's own padded batch class
(the solver's batch-invariance contract makes the coalesced batch bitwise
against a solo program of the same class); across *different* batch classes
``ys`` and the ``y0`` cotangent stay bitwise but args-gradients can move by
an ulp (XLA fuses the args-VJP contractions batch-size-dependently), so the
cross-class assertion is allclose.

Plus the training-specific policies: forward and gradient requests never
share a bucket, adjoint configuration splits buckets, prewarm covers the
VJP programs, async/multi-device scheduling stays invisible, and the
submit-time contract violations (dense grad requests, non-differentiable
drivers, mis-shaped cotangents) are rejected before anything is queued.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AutoDiffAdjoint,
    BacksolveAdjoint,
    CompiledSolver,
    GradRequest,
    ODETerm,
    ScanAdjoint,
    SolveRequest,
    SolveService,
    Stepper,
)


def decay(t, y, args):
    return -y * args


def make_grad_requests(n, rng, feat=3, f=decay, method=None, cotangent=True):
    """n mixed-value gradient requests of one shape class."""
    reqs = []
    for _ in range(n):
        reqs.append(GradRequest(
            f=f,
            y0=jnp.asarray(rng.uniform(0.5, 1.5, (feat,)), jnp.float32),
            t0=float(rng.uniform(0.0, 0.2)),
            t1=float(rng.uniform(0.8, 1.2)),
            args=jnp.asarray(rng.uniform(0.5, 2.0, (feat,)), jnp.float32),
            rtol=float(rng.choice([1e-3, 1e-4, 1e-5])),
            method=method,
            cotangent=(jnp.asarray(rng.normal(size=(feat,)), jnp.float32)
                       if cotangent else None),
        ))
    return reqs


def solve_grad_direct(req, batch_class=1, method=None):
    """The reference: this request alone through a VJP-compiled program of
    the given batch class (the request's row replicated)."""
    drv = method if method is not None else ScanAdjoint(Stepper("dopri5"))
    solver = CompiledSolver(drv, donate=False)
    b = batch_class
    f = req.f
    if (isinstance(drv, BacksolveAdjoint) and req.args is not None
            and not isinstance(f, ODETerm)):
        # What the service submits: per-request parameter rows marked for the
        # per-instance backward solve.
        f = ODETerm(f, batched=True, with_args=True, batched_args=True)

    def rep(x):
        x = jnp.asarray(x, jnp.float32)
        return jnp.stack([x] * b)

    def rep_tree(x):
        return jax.tree_util.tree_map(rep, x)

    ct = (req.cotangent if req.cotangent is not None
          else jax.tree_util.tree_map(
              lambda y: np.ones(np.shape(y), np.float32), req.y0))
    return solver.solve(
        f, rep_tree(req.y0), None,
        t_start=rep(req.t0), t_end=rep(req.t1),
        args=None if req.args is None else rep_tree(req.args),
        rtol=rep(req.rtol if req.rtol is not None else drv.rtol),
        atol=rep(req.atol if req.atol is not None else drv.atol),
        cotangent=rep_tree(ct))


def assert_grad_result(fut, req, batch_class, method=None, exact=True):
    """``exact=True``: the reference batch class matches the served bucket's,
    so values and gradients are bitwise.  ``exact=False``: cross-class
    reference -- ``ys`` stays bitwise (forward batch invariance) but the
    backward pass fuses batch-size-dependently, so gradients agree to
    rounding only."""
    view, grads = fut.result()
    ref = solve_grad_direct(req, batch_class=batch_class, method=method)
    assert_leaf = (np.testing.assert_array_equal if exact else
                   lambda a, b: np.testing.assert_allclose(a, b, rtol=1e-4,
                                                           atol=1e-7))
    np.testing.assert_array_equal(np.asarray(view.ys)[0], np.asarray(ref.ys)[0])
    assert_leaf(np.asarray(grads.y0), np.asarray(ref.grads.y0)[0])
    if req.args is None:
        assert grads.args is None
    else:
        assert_leaf(np.asarray(grads.args), np.asarray(ref.grads.args)[0])


class TestServedGradsBitwise:
    def test_single_request_matches_solo_scan_adjoint(self):
        """The acceptance bar: one served gradient request is bit-for-bit the
        solo ScanAdjoint VJP solve, and the service counts it."""
        rng = np.random.default_rng(0)
        svc = SolveService(max_batch=8, max_delay=None, default_method="dopri5")
        req = make_grad_requests(1, rng)[0]
        fut = svc.submit(req)
        svc.flush()
        assert_grad_result(fut, req, batch_class=1)
        st = svc.stats()
        assert st["n_grad_solves"] == 1
        assert st["grad_device_s"] > 0.0

    def test_coalesced_bucket_matches_same_class_solo(self):
        """5 mixed gradient requests pad to a bucket of 8; every per-request
        result -- values and both gradients -- is bitwise the solo program of
        the same batch class, and agrees with the b=1 solo solve to rounding
        (args-VJP fusion is batch-size dependent)."""
        rng = np.random.default_rng(1)
        svc = SolveService(max_batch=8, max_delay=None, default_method="dopri5")
        reqs = make_grad_requests(5, rng)
        futures = [svc.submit(r) for r in reqs]
        svc.flush()
        assert svc.stats()["n_pad_rows"] == 3
        for req, fut in zip(reqs, futures):
            assert_grad_result(fut, req, batch_class=8)
            assert_grad_result(fut, req, batch_class=1, exact=False)
        assert svc.stats()["n_grad_solves"] == 5

    def test_forward_and_grad_requests_never_share_a_bucket(self):
        """A mixed stream of one shape class splits into exactly two buckets:
        the forward rows keep their while_loop program, the gradient rows get
        the VJP program, and both sides stay bitwise against their solos."""
        rng = np.random.default_rng(2)
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        greqs = make_grad_requests(3, rng)
        freqs = [SolveRequest(f=decay, y0=g.y0, t0=g.t0, t1=g.t1,
                              args=g.args, rtol=g.rtol) for g in greqs]
        gfuts = [svc.submit(r) for r in greqs]
        ffuts = [svc.submit(r) for r in freqs]
        assert svc.stats()["n_buckets"] == 2
        svc.flush()
        fwd_solver = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5")),
                                    donate=False)
        for req, gfut, ffut in zip(greqs, gfuts, ffuts):
            assert_grad_result(gfut, req, batch_class=4)
            sol = ffut.result()
            assert sol.grads is None
            ref = fwd_solver.solve(
                decay, req.y0[None], None,
                t_start=jnp.asarray([req.t0], jnp.float32),
                t_end=jnp.asarray([req.t1], jnp.float32),
                args=req.args[None],
                rtol=jnp.asarray([req.rtol], jnp.float32),
                atol=jnp.asarray([1e-6], jnp.float32))
            np.testing.assert_array_equal(np.asarray(sol.ys),
                                          np.asarray(ref.ys))
        st = svc.stats()
        assert st["n_grad_solves"] == 3
        assert st["n_completed"] == 6

    def test_default_cotangent_sums_state_gradient(self):
        """No explicit cotangent: the service pulls back ones -- the gradient
        of ``sum(y1)`` -- and matches the solo solve with an explicit ones
        cotangent bitwise."""
        rng = np.random.default_rng(3)
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        req = make_grad_requests(1, rng, cotangent=False)[0]
        assert req.cotangent is None
        fut = svc.submit(req)
        svc.flush()
        assert_grad_result(fut, req, batch_class=1)

    def test_grad_flag_implied_by_cotangent(self):
        rng = np.random.default_rng(4)
        g = make_grad_requests(1, rng)[0]
        req = SolveRequest(f=decay, y0=g.y0, t0=g.t0, t1=g.t1, args=g.args,
                           rtol=g.rtol, cotangent=g.cotangent)
        assert not req.grad
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        fut = svc.submit(req)
        svc.flush()
        view, grads = fut.result()
        assert grads.y0.shape == g.y0.shape
        assert svc.stats()["n_grad_solves"] == 1

    def test_no_args_request_has_no_args_gradient(self):
        def free_decay(t, y, args):
            return -y

        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        req = GradRequest(f=free_decay, y0=jnp.ones((3,), jnp.float32),
                          t0=0.0, t1=1.0)
        fut = svc.submit(req)
        svc.flush()
        view, grads = fut.result()
        assert grads.args is None
        assert_grad_result(fut, req, batch_class=1)


class TestAdjointConfigurationBuckets:
    def test_backsolve_adjoint_served_bitwise(self):
        """An explicit ``BacksolveAdjoint`` method rides the same buckets:
        coalesced O(1)-memory adjoint solves, bitwise against the solo
        VJP-compiled backsolve of the same batch class.  Serving requires
        ``mode='per_instance'`` -- the row-independent backward solve."""
        rng = np.random.default_rng(5)
        drv = BacksolveAdjoint(Stepper("dopri5"), mode="per_instance",
                               rtol=1e-6, atol=1e-8)
        svc = SolveService(max_batch=4, max_delay=None)
        reqs = make_grad_requests(3, rng, method=drv)
        futures = [svc.submit(r) for r in reqs]
        svc.flush()
        for req, fut in zip(reqs, futures):
            assert_grad_result(fut, req, batch_class=4, method=drv)
        assert svc.stats()["n_grad_solves"] == 3

    def test_adjoint_identity_splits_buckets(self):
        """Same shape class, different adjoint programs: ScanAdjoint vs
        checkpointed ScanAdjoint vs BacksolveAdjoint modes -- each is its own
        bucket because the driver's static config is in the bucket key."""
        rng = np.random.default_rng(6)
        svc = SolveService(max_batch=8, max_delay=None)
        methods = [
            ScanAdjoint(Stepper("dopri5")),
            ScanAdjoint(Stepper("dopri5"), checkpoint_every=16),
            BacksolveAdjoint(Stepper("dopri5"), mode="per_instance"),
            BacksolveAdjoint(Stepper("dopri5"), mode="per_instance",
                             max_steps=5_000),
        ]
        futures = []
        for m in methods:
            req = make_grad_requests(1, rng, method=m)[0]
            futures.append((svc.submit(req), req, m))
        assert svc.stats()["n_buckets"] == len(methods)
        svc.flush()
        for fut, req, m in futures:
            assert_grad_result(fut, req, batch_class=1, method=m)

    def test_default_grad_method_is_service_wide(self):
        rng = np.random.default_rng(7)
        drv = BacksolveAdjoint(Stepper("dopri5"), mode="per_instance",
                               rtol=1e-6, atol=1e-8)
        svc = SolveService(max_batch=4, max_delay=None,
                           default_grad_method=drv, default_method="dopri5")
        req = make_grad_requests(1, rng)[0]
        fwd = SolveRequest(f=decay, y0=req.y0, t0=req.t0, t1=req.t1,
                           args=req.args)
        gfut, ffut = svc.submit(req), svc.submit(fwd)
        svc.flush()
        assert_grad_result(gfut, req, batch_class=1, method=drv)
        assert ffut.result().grads is None


class TestBatchedArgsRows:
    def test_per_request_parameter_rows(self):
        """Per-instance dynamics with per-request parameter rows: an
        ``ODETerm(batched=False, batched_args=True)`` request stream shares
        one bucket and every request gets the gradient of *its own* row."""
        def single(t, y, a):
            return -a["rate"] * y + a["drive"] * jnp.sin(t)

        term = ODETerm(single, batched=False, batched_args=True)
        rng = np.random.default_rng(8)
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        reqs = []
        for _ in range(3):
            reqs.append(GradRequest(
                f=term,
                y0=jnp.asarray(rng.uniform(0.5, 1.5, (3,)), jnp.float32),
                t0=0.0, t1=1.0,
                args={"rate": jnp.asarray(rng.uniform(0.5, 2.0, (3,)),
                                          jnp.float32),
                      "drive": jnp.asarray(rng.uniform(-1.0, 1.0), jnp.float32)},
                cotangent=jnp.asarray(rng.normal(size=(3,)), jnp.float32)))
        futures = [svc.submit(r) for r in reqs]
        assert svc.stats()["n_buckets"] == 1
        svc.flush()
        for req, fut in zip(reqs, futures):
            view, grads = fut.result()
            ref = solve_grad_direct(req, batch_class=4)
            np.testing.assert_array_equal(np.asarray(view.ys)[0],
                                          np.asarray(ref.ys)[0])
            np.testing.assert_array_equal(np.asarray(grads.y0),
                                          np.asarray(ref.grads.y0)[0])
            for k in ("rate", "drive"):
                np.testing.assert_array_equal(
                    np.asarray(grads.args[k]), np.asarray(ref.grads.args[k])[0])

    def test_backsolve_per_instance_parameter_rows(self):
        """The per-instance backsolve with batched_args: each instance's
        augmented state carries its own row-sized parameter adjoint, so the
        served row gradients agree with the b=1 solo backsolve to solver
        accuracy."""
        def single(t, y, rate):
            return -rate * y

        term = ODETerm(single, batched=False, batched_args=True)
        drv = BacksolveAdjoint(Stepper("dopri5"), mode="per_instance",
                               rtol=1e-8, atol=1e-10)
        rng = np.random.default_rng(9)
        svc = SolveService(max_batch=4, max_delay=None)
        reqs = []
        for _ in range(3):
            reqs.append(GradRequest(
                f=term,
                y0=jnp.asarray(rng.uniform(0.5, 1.5, (3,)), jnp.float32),
                t0=0.0, t1=1.0, method=drv, rtol=1e-6, atol=1e-8,
                args=jnp.asarray(rng.uniform(0.5, 2.0, (3,)), jnp.float32)))
        futures = [svc.submit(r) for r in reqs]
        svc.flush()
        for req, fut in zip(reqs, futures):
            view, grads = fut.result()
            # analytic: y1 = y0*exp(-r), dL/dr for L=sum(y1) is -y0*exp(-r)
            y0 = np.asarray(req.y0)
            r = np.asarray(req.args)
            np.testing.assert_allclose(np.asarray(grads.args),
                                       -y0 * np.exp(-r), rtol=1e-3)
            np.testing.assert_allclose(np.asarray(grads.y0),
                                       np.exp(-r), rtol=1e-3)


class TestAsyncAndMultiDevice:
    def test_out_of_order_harvest_bitwise(self):
        """A randomized (seeded) interleaving of submit/poll/drain/result over
        mixed forward+grad traffic resolves every future with the synchronous
        service's values."""
        def run(max_inflight):
            rng = np.random.default_rng(10)
            ops = np.random.default_rng(11)
            svc = SolveService(max_batch=4, max_delay=None,
                               max_inflight=max_inflight,
                               default_method="dopri5")
            futures = []
            for i in range(16):
                feat = (2, 3, 5)[i % 3]
                if i % 2:
                    futures.append(svc.submit(
                        make_grad_requests(1, rng, feat=feat)[0]))
                else:
                    futures.append(svc.submit(SolveRequest(
                        f=decay,
                        y0=jnp.asarray(rng.uniform(0.5, 1.5, (feat,)),
                                       jnp.float32),
                        t0=0.0, t1=1.0,
                        args=jnp.asarray(rng.uniform(0.5, 2.0, (feat,)),
                                         jnp.float32))))
                op = ops.integers(0, 4)
                if op == 0:
                    svc.poll()
                elif op == 1:
                    svc.drain(1)
                elif op == 2:
                    futures[int(ops.integers(0, len(futures)))].result()
            svc.flush()
            return [f.result() for f in futures]

        ref = run(max_inflight=0)
        got = run(max_inflight=2)
        for g, r in zip(got, ref):
            if isinstance(g, tuple):
                (gv, gg), (rv, rg) = g, r
                np.testing.assert_array_equal(np.asarray(gv.ys),
                                              np.asarray(rv.ys))
                for gl, rl in zip(jax.tree_util.tree_leaves(gg),
                                  jax.tree_util.tree_leaves(rg)):
                    np.testing.assert_array_equal(np.asarray(gl),
                                                  np.asarray(rl))
            else:
                np.testing.assert_array_equal(np.asarray(g.ys),
                                              np.asarray(r.ys))

    def test_multi_device_round_robin_grad_bitwise(self):
        """Gradient buckets round-robin the mesh like forward buckets (one
        device in the tier-1 suite, four in the CI smoke leg) and placement
        is invisible: the full-mesh stream equals the pinned-device stream
        bitwise."""
        devs = jax.devices()

        def run(devices, max_inflight):
            rng = np.random.default_rng(12)
            svc = SolveService(max_batch=2, max_delay=None,
                               max_inflight=max_inflight, devices=devices,
                               default_method="dopri5")
            futures = [svc.submit(r)
                       for r in make_grad_requests(4 * len(devs), rng)]
            svc.flush()
            return svc, [f.result() for f in futures]

        _, ref = run([devs[0]], max_inflight=0)
        svc, got = run(None, max_inflight=len(devs) + 1)
        for (gv, gg), (rv, rg) in zip(got, ref):
            np.testing.assert_array_equal(np.asarray(gv.ys), np.asarray(rv.ys))
            np.testing.assert_array_equal(np.asarray(gg.y0), np.asarray(rg.y0))
            np.testing.assert_array_equal(np.asarray(gg.args),
                                          np.asarray(rg.args))
        st = svc.stats()
        assert st["n_grad_solves"] == 4 * len(devs)
        if len(devs) >= 2:
            assert st["n_devices"] == len(devs)

    def test_prewarm_compiles_grad_programs(self):
        """Prewarming a gradient example AOT-compiles the VJP program for
        every batch class on every device; gradient traffic then never
        traces."""
        devs = jax.devices()
        rng = np.random.default_rng(13)
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        example = make_grad_requests(1, rng)[0]
        assert svc.prewarm(example) == 3 * len(devs)  # classes 1, 2, 4
        assert svc.prewarm(example) == 0
        base = svc.stats()["cache_misses"]
        for n in (1, 2, 3):
            futures = [svc.submit(r) for r in make_grad_requests(n, rng)]
            svc.flush()
            [f.result() for f in futures]
        st = svc.stats()
        assert st["cache_misses"] == base, \
            "prewarmed gradient traffic must never compile"
        assert st["cache_hits"] == 3


class TestGradValidation:
    def test_dense_grad_request_rejected(self):
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        with pytest.raises(ValueError, match="final state"):
            svc.submit(GradRequest(f=decay, y0=jnp.ones((3,), jnp.float32),
                                   t0=0.0, t1=1.0,
                                   t_eval=np.linspace(0.1, 0.9, 4,
                                                      dtype=np.float32)))

    def test_non_differentiable_driver_rejected(self):
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        with pytest.raises(TypeError, match="reverse-differentiable"):
            svc.submit(GradRequest(f=decay, y0=jnp.ones((3,), jnp.float32),
                                   t0=0.0, t1=1.0,
                                   method=AutoDiffAdjoint(Stepper("dopri5"))))

    def test_joint_mode_backsolve_rejected(self):
        """Joint-mode backsolve stacks the batch into one adjoint instance
        with a batch-shared time range -- a bucket of independent requests
        cannot guarantee that, so submit rejects it up front."""
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        with pytest.raises(TypeError, match="per_instance"):
            svc.submit(GradRequest(f=decay, y0=jnp.ones((3,), jnp.float32),
                                   t0=0.0, t1=1.0,
                                   method=BacksolveAdjoint(Stepper("dopri5"),
                                                           mode="joint")))

    def test_mis_shaped_cotangent_rejected(self):
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        with pytest.raises(ValueError, match="cotangent leaf shape"):
            svc.submit(GradRequest(f=decay, y0=jnp.ones((3,), jnp.float32),
                                   t0=0.0, t1=1.0,
                                   cotangent=jnp.ones((4,), jnp.float32)))

    def test_mis_structured_cotangent_rejected(self):
        def f(t, y, args):
            return {"a": -y["a"]}

        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        with pytest.raises(ValueError, match="structure"):
            svc.submit(GradRequest(f=f, y0={"a": jnp.ones((2,), jnp.float32)},
                                   t0=0.0, t1=1.0,
                                   cotangent=jnp.ones((2,), jnp.float32)))
