"""Sharding-rule and constraint-layer unit tests (single-device mesh: the
rules must degrade gracefully -- everything falls back to replication when an
axis has size 1 or a dim does not divide)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.constraints import activation_sharding, constrain, tp_size
from repro.distributed.sharding import (
    batch_spec,
    cache_shardings,
    dp_axes,
    param_shardings,
)
from repro.models import init_cache, init_params


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


class TestParamShardings:
    def test_full_config_rules_dense(self, mesh):
        cfg = get_config("qwen2_5_14b")
        abstract = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        sh = param_shardings(mesh, abstract, fsdp=True)
        # structure matches and every leaf got a NamedSharding
        flat_p = jax.tree_util.tree_leaves(abstract)
        flat_s = jax.tree_util.tree_leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
        assert len(flat_p) == len(flat_s)

    def test_divisibility_guard_replicates(self, mesh):
        # a dim of size 1 cannot shard over >1 devices -- on this 1x1 mesh all
        # axis sizes are 1, so every spec is valid; check the guard math via a
        # synthetic 16-way mesh instead (host platform only has 1 device, so
        # just exercise the spec computation path).
        cfg = get_config("starcoder2_7b")  # KV=4
        abstract = jax.eval_shape(lambda: init_params(cfg, jax.random.PRNGKey(0)))
        sh = param_shardings(mesh, abstract, fsdp=False)
        embed_spec = sh["embed"].spec
        assert len(embed_spec) <= 2

    def test_quantized_moment_leaves_inherit_rule(self, mesh):
        from repro.optim.quantized import qadamw_init

        cfg = get_config("stablelm_3b", reduced=True)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = qadamw_init(params)
        sh = param_shardings(mesh, jax.eval_shape(lambda: opt["m"]), fsdp=True)
        leaves = jax.tree_util.tree_leaves(sh, is_leaf=lambda x: hasattr(x, "spec"))
        assert leaves, "quantized moments must produce shardings"


class TestCacheShardings:
    @pytest.mark.parametrize("arch", ["qwen2_5_14b", "jamba_v0_1_52b", "xlstm_350m"])
    def test_cache_specs_cover_all_leaves(self, mesh, arch):
        cfg = get_config(arch, reduced=True)
        cache = jax.eval_shape(lambda: init_cache(cfg, 2, 16))
        sh = cache_shardings(mesh, cache)
        n_c = len(jax.tree_util.tree_leaves(cache))
        n_s = len(jax.tree_util.tree_leaves(sh, is_leaf=lambda x: hasattr(x, "spec")))
        assert n_c == n_s


class TestConstraints:
    def test_noop_outside_context(self):
        x = jnp.ones((4, 4))
        assert constrain(x, "dp", None) is x

    def test_tp_size_visibility(self, mesh):
        assert tp_size() is None
        with activation_sharding(dp=("data",), tp="model", tp_size=7):
            assert tp_size() == 7
        assert tp_size() is None

    def test_constrain_applies_inside_mesh(self, mesh):
        with mesh, activation_sharding(dp=("data",), tp="model", tp_size=1):
            out = jax.jit(lambda x: constrain(x, "dp", None) * 2)(jnp.ones((4, 4)))
        np.testing.assert_array_equal(np.asarray(out), 2.0)


class TestBatchSpec:
    def test_guarded_batch_one(self, mesh):
        s = batch_spec(mesh, jax.ShapeDtypeStruct((1, 8), jnp.float32))
        assert s.spec in (P(("data",), None), P(None, None), P((), None)) or True
        # with mesh size 1 anything divides; just assert it constructs
        assert hasattr(s, "spec")

    def test_dp_axes(self, mesh):
        assert dp_axes(mesh) == ("data",)
