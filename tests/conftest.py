import os
import sys

import pytest

# smoke tests and benches must see ONE device; only dryrun.py forces 512.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Backends whose kernels go through pallas_call, which has no reverse-mode
# rule: gradient-through-the-loop tests only run on the ref backend leg.
_NONDIFF_BACKENDS = ("pallas", "interpret")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "reverse_diff: test reverse-differentiates through the solver loop "
        "(skipped on pallas/interpret kernel backends)",
    )


def pytest_collection_modifyitems(config, items):
    from repro.kernels import ops

    backend = ops.backend()  # resolves "auto" (-> pallas on TPU, ref on CPU)
    if backend not in _NONDIFF_BACKENDS:
        return
    skip = pytest.mark.skip(
        reason=f"REPRO_KERNEL_BACKEND={backend}: pallas_call has no reverse-mode "
        "rule; gradient tests run on the ref backend"
    )
    for item in items:
        if item.get_closest_marker("reverse_diff"):
            item.add_marker(skip)
