"""End-to-end system tests: data determinism, checkpoint/restart, fault
tolerance, gradient compression, the HLO cost analyzer, and a short real
training run that must reduce loss."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, latest_step, restore, save
from repro.data import SyntheticTokens
from repro.distributed import compression
from repro.launch.fault_tolerance import RestartPolicy, StepTimeout, Watchdog
from repro.launch.hlocost import analyze


class TestDataPipeline:
    def test_deterministic_across_restarts(self):
        ds = SyntheticTokens(vocab=256, seq_len=32, global_batch=8, seed=3)
        b1 = ds.batch(5)
        b2 = ds.batch(5)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])

    def test_shardable_rows(self):
        ds = SyntheticTokens(vocab=256, seq_len=16, global_batch=8)
        full = ds.batch(0)
        lo = ds.batch(0, lo=0, hi=4)
        hi = ds.batch(0, lo=4, hi=8)
        np.testing.assert_array_equal(full["tokens"][:4], lo["tokens"])
        np.testing.assert_array_equal(full["tokens"][4:], hi["tokens"])

    def test_labels_are_shifted_tokens(self):
        ds = SyntheticTokens(vocab=256, seq_len=16, global_batch=2)
        b = ds.batch(0)
        assert b["tokens"].shape == b["labels"].shape
        np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(10.0), "b": {"c": jnp.ones((3, 4), jnp.bfloat16)}}
        save(str(tmp_path), 7, tree)
        assert latest_step(str(tmp_path)) == 7
        out = restore(str(tmp_path), 7, tree)
        np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(10.0))
        assert str(out["b"]["c"].dtype) == "bfloat16"

    def test_atomicity_no_partial_dirs(self, tmp_path):
        save(str(tmp_path), 1, {"a": jnp.zeros(4)})
        entries = [d for d in os.listdir(tmp_path) if not d.startswith("step_")]
        assert entries == [], f"leftover temp dirs: {entries}"

    def test_manager_async_and_retention(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        tree = {"w": jnp.ones(8)}
        for s in (1, 2, 3, 4):
            mgr.save_async(s, tree)
        mgr.wait()
        mgr.close()
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_path))
        assert steps == [3, 4]

    def test_restore_with_resharding_target(self, tmp_path):
        mesh = jax.make_mesh((1,), ("data",))
        from jax.sharding import NamedSharding, PartitionSpec as P

        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        save(str(tmp_path), 0, tree)
        sh = {"w": NamedSharding(mesh, P("data", None))}
        out = restore(str(tmp_path), 0, tree, shardings=sh)
        assert out["w"].sharding == sh["w"]


class TestFaultTolerance:
    def test_watchdog_passes_fast_steps(self):
        wd = Watchdog(timeout_s=5.0)
        out = wd.run(lambda x: x + 1, jnp.ones(4))
        np.testing.assert_array_equal(np.asarray(out), 2.0)

    def test_watchdog_kills_hung_step(self):
        import time

        wd = Watchdog(timeout_s=0.2)
        with pytest.raises(StepTimeout):
            wd.run(lambda: time.sleep(2.0))

    def test_restart_policy_retries_then_succeeds(self):
        calls = {"n": 0}

        def flaky():
            calls["n"] += 1
            if calls["n"] < 3:
                raise RuntimeError("simulated node failure")
            return "ok"

        assert RestartPolicy(max_restarts=3, backoff_s=0.01).supervise(flaky) == "ok"
        assert calls["n"] == 3

    def test_restart_policy_gives_up(self):
        def dead():
            raise RuntimeError("hard failure")

        with pytest.raises(RuntimeError):
            RestartPolicy(max_restarts=1, backoff_s=0.01).supervise(dead)


class TestCompression:
    def test_int8_roundtrip_error_bounded(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3.0
        y = compression.compress_roundtrip(x)
        err = jnp.max(jnp.abs(x - y))
        assert float(err) < 12.0 / 127.0

    def test_error_feedback_preserves_sum(self):
        """With error feedback the ACCUMULATED update converges to the true
        gradient sum (quantization error does not accumulate)."""
        g = {"w": jnp.full((64,), 0.003)}
        ef = compression.init_error_feedback(g)
        acc = jnp.zeros(64)
        for _ in range(50):
            comp, ef = compression.grads_with_error_feedback(g, ef)
            acc = acc + comp["w"]
        np.testing.assert_allclose(np.asarray(acc), 50 * 0.003, rtol=0.05)

    def test_quantize_shapes(self):
        x = jnp.ones((7, 33))
        q, s = compression.quantize_int8(x)
        assert q.dtype == jnp.int8
        y = compression.dequantize_int8(q, s, x.shape)
        np.testing.assert_allclose(np.asarray(y), 1.0, rtol=1e-2)


class TestHloCost:
    def test_counts_scan_trip_counts(self):
        def g(x, w):
            def body(c, _):
                return jnp.tanh(c @ w), None

            y, _ = jax.lax.scan(body, x, None, length=7)
            return y

        comp = jax.jit(g).lower(jax.ShapeDtypeStruct((8, 64), jnp.float32),
                                jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        r = analyze(comp.as_text())
        assert r["flops"] == 7 * 2 * 8 * 64 * 64

    def test_nested_scans(self):
        def h(x, w):
            def inner(c, _):
                return c @ w, None

            def outer(c, _):
                c, _ = jax.lax.scan(inner, c, None, length=5)
                return c, None

            y, _ = jax.lax.scan(outer, x, None, length=3)
            return y

        comp = jax.jit(h).lower(jax.ShapeDtypeStruct((8, 64), jnp.float32),
                                jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        r = analyze(comp.as_text())
        assert r["flops"] == 15 * 2 * 8 * 64 * 64

    def test_bytes_are_positive_and_bounded(self):
        f = lambda a: a @ a.T
        comp = jax.jit(f).lower(jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        r = analyze(comp.as_text())
        assert 0 < r["bytes"] < 64 * 64 * 4 * 100


class TestTraining:
    def test_short_training_reduces_loss_and_resumes(self, tmp_path):
        from repro.launch.train import run

        class A:  # argparse stand-in
            arch = "stablelm-3b"
            reduced = True
            steps = 14
            batch = 4
            seq = 64
            lr = 1e-3
            seed = 0
            model_parallel = 1
            fsdp = False
            remat = False
            ode_depth = False
            ckpt_dir = str(tmp_path)
            ckpt_every = 5
            step_timeout = 600.0
            log_every = 100
            max_restarts = 0

        out1 = run(A())
        assert out1["losses"][-1] < out1["losses"][0]
        A.steps = 18
        out2 = run(A())
        assert out2["start"] > 0, "should resume from checkpoint"
        assert len(out2["losses"]) == 18 - out2["start"]
