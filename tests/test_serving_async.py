"""The async serving engine: overlap must be invisible.

Non-blocking dispatch, the bounded in-flight window and round-robin device
placement are pure *scheduling*: whatever order batches launch, complete and
harvest in, every request must resolve with exactly the solution the
synchronous service (``max_inflight=0``, launch + harvest inline) produces
for the identical request stream.  Explicit steppers make that testable
bitwise -- in both regimes here, because both services build identical
batches, so even the dense interpolant contractions see the same shapes.

Runs on however many devices exist: 1 in the plain tier-1 suite, 4 in the CI
smoke leg via ``XLA_FLAGS=--xla_force_host_platform_device_count=4``.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SolveRequest, SolveService


def decay(t, y, args):
    return -y * args


def make_stream(n, seed, feats=(2, 3, 5), dense_every=None):
    """A deterministic mixed-shape request stream (fresh arrays per call --
    the values, not the objects, must determine the results)."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        feat = int(feats[i % len(feats)])
        n_eval = (None if dense_every is None or i % dense_every
                  else int(rng.integers(3, 9)))
        reqs.append(SolveRequest(
            f=decay,
            y0=jnp.asarray(rng.uniform(0.5, 1.5, (feat,)), jnp.float32),
            t0=float(rng.uniform(0.0, 0.2)),
            t1=float(rng.uniform(0.8, 1.2)),
            t_eval=(None if n_eval is None
                    else np.linspace(0.1, 0.7, n_eval, dtype=np.float32)),
            args=jnp.asarray(rng.uniform(0.5, 2.0, (feat,)), jnp.float32),
            rtol=float(rng.choice([1e-3, 1e-4, 1e-5])),
        ))
    return reqs


def serve_stream(reqs, **svc_kwargs):
    svc = SolveService(max_delay=None, default_method="dopri5", **svc_kwargs)
    futures = [svc.submit(r) for r in reqs]
    svc.flush()
    return svc, [f.result() for f in futures]


def assert_solutions_bitwise(got, ref, stats=None):
    """Bitwise equality of the served streams.  ``stats=None`` compares every
    accumulator (identical batch composition); pass the composition-invariant
    subset when the interleaving changes flush timing -- ``n_f_evals`` counts
    whole-batch overhang (instances that finish early keep counting while
    bucket-mates run) and is composition-dependent by design."""
    for g, r in zip(got, ref):
        np.testing.assert_array_equal(np.asarray(g.ts), np.asarray(r.ts))
        np.testing.assert_array_equal(np.asarray(g.ys), np.asarray(r.ys))
        np.testing.assert_array_equal(np.asarray(g.status),
                                      np.asarray(r.status))
        for name in (g.stats if stats is None else stats):
            np.testing.assert_array_equal(np.asarray(g.stats[name]),
                                          np.asarray(r.stats[name]),
                                          err_msg=f"stats[{name}]")


def hold_harvest(svc):
    """Disable the opportunistic (non-blocking) harvest so in-flight records
    stay observable: on CPU a tiny batch can finish before the next submit's
    ``poll()``, making window-size assertions racy.  Blocking harvests
    (``drain``/``result``/backpressure) still work."""
    svc._harvest_ready = lambda: 0


def release_harvest(svc):
    del svc.__dict__["_harvest_ready"]


class TestAsyncEqualsSync:
    def test_final_state_stream_bitwise(self):
        reqs = make_stream(24, seed=0)
        _, ref = serve_stream(make_stream(24, seed=0), max_batch=8,
                              max_inflight=0)
        svc, got = serve_stream(reqs, max_batch=8, max_inflight=4)
        assert_solutions_bitwise(got, ref)
        assert svc.stats()["n_completed"] == 24

    def test_dense_stream_bitwise(self):
        reqs = make_stream(18, seed=1, dense_every=1)
        _, ref = serve_stream(make_stream(18, seed=1, dense_every=1),
                              max_batch=4, max_inflight=0)
        _, got = serve_stream(reqs, max_batch=4, max_inflight=4)
        assert_solutions_bitwise(got, ref)

    def test_interleaved_submit_poll_result_bitwise(self):
        """A randomized (but seeded) interleaving of submit/poll/result/
        drain resolves every future with the synchronous service's values --
        harvest order must be invisible."""
        _, ref = serve_stream(make_stream(20, seed=2), max_batch=4,
                              max_inflight=0)
        rng = np.random.default_rng(7)
        svc = SolveService(max_batch=4, max_delay=None, max_inflight=2,
                           default_method="dopri5")
        reqs = make_stream(20, seed=2)
        futures = []
        for i, r in enumerate(reqs):
            futures.append(svc.submit(r))
            op = rng.integers(0, 4)
            if op == 0:
                svc.poll()
            elif op == 1:
                svc.drain(1)
            elif op == 2 and futures:
                fut = futures[int(rng.integers(0, len(futures)))]
                assert bool(fut.result().success.all())
        svc.flush()
        got = [f.result() for f in futures]
        assert_solutions_bitwise(got, ref, stats=("n_steps", "n_accepted"))
        st = svc.stats()
        assert st["n_inflight"] == 0 and st["queue_depth"] == 0
        assert st["n_completed"] == 20

    def test_hypothesis_interleaving_property(self):
        """Any interleaving of submit/poll/drain/result operations is
        bitwise-equal to the synchronous service on the same stream."""
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=15, deadline=None)
        @given(seed=st.integers(0, 2**30),
               n=st.integers(1, 12),
               max_inflight=st.sampled_from([1, 2, 4]),
               ops=st.lists(st.integers(0, 3), min_size=0, max_size=12))
        def run(seed, n, max_inflight, ops):
            _, ref = serve_stream(make_stream(n, seed=seed), max_batch=4,
                                  max_inflight=0)
            svc = SolveService(max_batch=4, max_delay=None,
                               max_inflight=max_inflight,
                               default_method="dopri5")
            futures = [svc.submit(r) for r in make_stream(n, seed=seed)]
            for i, op in enumerate(ops):
                if op == 0:
                    svc.poll()
                elif op == 1:
                    svc.drain(1)
                elif op == 2:
                    svc.flush()
                else:
                    futures[i % n].result()
            svc.flush()
            got = [f.result() for f in futures]
            assert_solutions_bitwise(got, ref, stats=("n_steps", "n_accepted"))

        run()


class TestInflightWindow:
    def test_backpressure_bounds_the_window(self):
        """Launching past ``max_inflight`` must block on the oldest launch:
        the window never exceeds the knob and the waits are counted."""
        svc = SolveService(max_batch=2, max_delay=None, max_inflight=2,
                           default_method="dopri5")
        hold_harvest(svc)  # only backpressure may shrink the window
        for r in make_stream(16, seed=3, feats=(2, 3, 5, 7)):
            svc.submit(r)
        svc.flush()
        st = svc.stats()
        assert st["n_batches"] == 8
        assert st["peak_inflight"] <= 2
        assert st["n_backpressure_waits"] == 6, \
            "every launch past the window must block on the oldest one"
        release_harvest(svc)
        svc.drain()
        assert svc.stats()["n_inflight"] == 0

    def test_max_inflight_zero_is_synchronous(self):
        """The blocking service: every launch harvests inline, so futures
        resolve without any poll/drain and nothing stays in flight."""
        svc = SolveService(max_batch=2, max_delay=None, max_inflight=0,
                           default_method="dopri5")
        futures = [svc.submit(r) for r in make_stream(4, seed=4, feats=(3,))]
        # both size-flushes harvested inline -- no drain needed
        assert all(f._solution is not None for f in futures)
        st = svc.stats()
        assert st["n_inflight"] == 0 and st["peak_inflight"] == 1
        assert st["n_backpressure_waits"] == 0

    def test_drain_is_bounded_and_ordered(self):
        svc = SolveService(max_batch=2, max_delay=None, max_inflight=8,
                           default_method="dopri5")
        hold_harvest(svc)
        futures = [svc.submit(r) for r in make_stream(8, seed=5,
                                                      feats=(2, 3, 5, 7))]
        svc.flush()
        assert svc.stats()["n_inflight"] == 4
        assert svc.drain(1) == 1  # oldest launch first
        assert futures[0]._solution is not None
        assert svc.stats()["n_inflight"] == 3
        assert svc.drain() == 3
        release_harvest(svc)
        assert all(f.done() for f in futures)


class TestDevicePlacement:
    def test_round_robin_across_devices(self):
        """Consecutive launches land on consecutive devices of the mesh (one
        device in the tier-1 suite, four in the CI smoke leg)."""
        devs = jax.devices()
        svc = SolveService(max_batch=2, max_delay=None,
                           max_inflight=len(devs) + 2,
                           default_method="dopri5")
        hold_harvest(svc)  # keep every launch observable in the window
        n_launch = len(devs) + 2
        for r in make_stream(2 * n_launch, seed=6,
                             feats=tuple(range(2, 2 + n_launch))):
            svc.submit(r)
        placed = [rec.device for rec in svc._inflight]
        assert len(placed) == n_launch
        assert placed == [devs[i % len(devs)] for i in range(n_launch)]
        if len(devs) >= 2:
            assert len(set(placed)) >= 2, "the mesh must actually be used"
        got = [rec.sol for rec in svc._inflight]
        for rec_sol, dev in zip(got, placed):
            leaves = [x for x in jax.tree_util.tree_leaves(rec_sol)
                      if isinstance(x, jax.Array)]
            assert all(x.devices() == {dev} for x in leaves)
        release_harvest(svc)
        svc.drain()
        assert svc.stats()["n_devices"] == len(devs)

    def test_multi_device_results_bitwise_equal_single_device(self):
        """Device placement is invisible: serving on the whole mesh equals
        serving pinned to one device, bitwise."""
        devs = jax.devices()
        reqs = make_stream(12, seed=8)
        _, ref = serve_stream(make_stream(12, seed=8), max_batch=4,
                              max_inflight=0, devices=[devs[0]])
        svc, got = serve_stream(reqs, max_batch=4, max_inflight=4)
        assert_solutions_bitwise(got, ref)
        if len(devs) >= 2:
            assert svc.stats()["n_batches"] >= 2

    def test_prewarm_covers_every_device(self):
        """Round-robin placement means any bucket can land anywhere, so
        prewarm compiles one program per class per device and traffic on any
        device is a pure cache hit."""
        devs = jax.devices()
        svc = SolveService(max_batch=2, max_delay=None,
                           default_method="dopri5")
        example = make_stream(1, seed=9, feats=(3,))[0]
        assert svc.prewarm(example) == 2 * len(devs)  # classes 1, 2
        assert svc.prewarm(example) == 0
        futures = []
        for r in make_stream(2 * len(devs), seed=9, feats=(3,)):
            futures.append(svc.submit(r))
        svc.flush()
        [f.result() for f in futures]
        st = svc.stats()
        assert st["cache_misses"] == 2 * len(devs), \
            "prewarmed traffic must never compile"
        assert st["cache_hits"] >= len(devs)
