"""Adjoint gradients: joint + per-instance backsolve vs direct autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solve_ivp_scan
from repro.core.adjoint import make_adjoint_solve


def linear(t, y, A):
    return y @ A.T


A0 = jnp.array([[-0.5, 0.3], [-0.2, -0.8]])
Y0 = jnp.array([[1.0, 0.5], [0.3, -1.2], [2.0, 0.1]])


def ref_grads():
    def loss(y0, A):
        s = solve_ivp_scan(linear, y0, None, t_start=0.0, t_end=1.0, args=A,
                           rtol=1e-8, atol=1e-8, max_steps=128)
        return jnp.sum(s.ys ** 2)

    return jax.grad(loss, argnums=(0, 1))(Y0, A0)


@pytest.fixture(scope="module")
def reference():
    return ref_grads()


@pytest.mark.reverse_diff
@pytest.mark.parametrize("mode", ["joint", "per_instance"])
def test_adjoint_matches_direct(mode, reference):
    solve = make_adjoint_solve(linear, mode=mode, rtol=1e-8, atol=1e-8)

    def loss(y0, A):
        return jnp.sum(solve(y0, 0.0, 1.0, A) ** 2)

    gy, gA = jax.jit(jax.grad(loss, argnums=(0, 1)))(Y0, A0)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(reference[0]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(gA), np.asarray(reference[1]), atol=2e-4)


def test_adjoint_time_gradients():
    solve = make_adjoint_solve(linear, mode="joint", rtol=1e-9, atol=1e-9)

    def loss(t1):
        return jnp.sum(solve(Y0, 0.0, t1, A0) ** 2)

    g = jax.grad(loss)(1.0)
    eps = 1e-3
    fd = (loss(1.0 + eps) - loss(1.0 - eps)) / (2 * eps)
    np.testing.assert_allclose(float(g), float(fd), rtol=1e-2)


def test_joint_and_per_instance_agree():
    s1 = make_adjoint_solve(linear, mode="joint", rtol=1e-9, atol=1e-9)
    s2 = make_adjoint_solve(linear, mode="per_instance", rtol=1e-9, atol=1e-9)

    def l1(A):
        return jnp.sum(jnp.sin(s1(Y0, 0.0, 1.0, A)))

    def l2(A):
        return jnp.sum(jnp.sin(s2(Y0, 0.0, 1.0, A)))

    g1 = jax.grad(l1)(A0)
    g2 = jax.grad(l2)(A0)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


@pytest.mark.reverse_diff
def test_adjoint_pytree_params():
    def mlp_dyn(t, y, p):
        return jnp.tanh(y @ p["w"]) @ p["v"]

    p = {"w": jnp.eye(2) * 0.5, "v": jnp.eye(2) * -0.3}
    solve = make_adjoint_solve(mlp_dyn, mode="joint", rtol=1e-7, atol=1e-9)

    def loss(p):
        return jnp.sum(solve(Y0, 0.0, 1.0, p) ** 2)

    g = jax.grad(loss)(p)

    def loss_ref(p):
        s = solve_ivp_scan(mlp_dyn, Y0, None, t_start=0.0, t_end=1.0, args=p,
                           rtol=1e-7, atol=1e-9, max_steps=128)
        return jnp.sum(s.ys ** 2)

    g_ref = jax.grad(loss_ref)(p)
    for k in p:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]), atol=3e-4)


@pytest.mark.reverse_diff
def test_dense_adjoint_matches_direct():
    """Adjoint with evaluation points: segment-wise backsolve (torchode's
    dense-output adjoint)."""
    from repro.core.adjoint import make_adjoint_solve_dense

    t_eval = jnp.linspace(0.0, 1.5, 6)
    solve = make_adjoint_solve_dense(linear, rtol=1e-8, atol=1e-8)
    w = jnp.arange(1.0, 7.0)[None, :, None]

    def loss(y0, A):
        return jnp.sum(jnp.sin(solve(y0, t_eval, A)) * w)

    g_adj = jax.jit(jax.grad(loss, argnums=(0, 1)))(Y0, A0)

    def loss_ref(y0, A):
        s = solve_ivp_scan(linear, y0, t_eval, args=A, rtol=1e-8, atol=1e-8,
                           max_steps=128)
        return jnp.sum(jnp.sin(s.ys) * w)

    g_ref = jax.grad(loss_ref, argnums=(0, 1))(Y0, A0)
    np.testing.assert_allclose(np.asarray(g_adj[0]), np.asarray(g_ref[0]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(g_adj[1]), np.asarray(g_ref[1]), atol=2e-4)
