"""Adjoint gradients: joint + per-instance backsolve vs direct autodiff."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import solve_ivp_scan
from repro.core.adjoint import make_adjoint_solve


def linear(t, y, A):
    return y @ A.T


A0 = jnp.array([[-0.5, 0.3], [-0.2, -0.8]])
Y0 = jnp.array([[1.0, 0.5], [0.3, -1.2], [2.0, 0.1]])


def ref_grads():
    def loss(y0, A):
        s = solve_ivp_scan(linear, y0, None, t_start=0.0, t_end=1.0, args=A,
                           rtol=1e-8, atol=1e-8, max_steps=128)
        return jnp.sum(s.ys ** 2)

    return jax.grad(loss, argnums=(0, 1))(Y0, A0)


@pytest.fixture(scope="module")
def reference():
    return ref_grads()


@pytest.mark.reverse_diff
@pytest.mark.parametrize("mode", ["joint", "per_instance"])
def test_adjoint_matches_direct(mode, reference):
    solve = make_adjoint_solve(linear, mode=mode, rtol=1e-8, atol=1e-8)

    def loss(y0, A):
        return jnp.sum(solve(y0, 0.0, 1.0, A) ** 2)

    gy, gA = jax.jit(jax.grad(loss, argnums=(0, 1)))(Y0, A0)
    np.testing.assert_allclose(np.asarray(gy), np.asarray(reference[0]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(gA), np.asarray(reference[1]), atol=2e-4)


def test_adjoint_time_gradients():
    solve = make_adjoint_solve(linear, mode="joint", rtol=1e-9, atol=1e-9)

    def loss(t1):
        return jnp.sum(solve(Y0, 0.0, t1, A0) ** 2)

    g = jax.grad(loss)(1.0)
    eps = 1e-3
    fd = (loss(1.0 + eps) - loss(1.0 - eps)) / (2 * eps)
    np.testing.assert_allclose(float(g), float(fd), rtol=1e-2)


def test_joint_and_per_instance_agree():
    s1 = make_adjoint_solve(linear, mode="joint", rtol=1e-9, atol=1e-9)
    s2 = make_adjoint_solve(linear, mode="per_instance", rtol=1e-9, atol=1e-9)

    def l1(A):
        return jnp.sum(jnp.sin(s1(Y0, 0.0, 1.0, A)))

    def l2(A):
        return jnp.sum(jnp.sin(s2(Y0, 0.0, 1.0, A)))

    g1 = jax.grad(l1)(A0)
    g2 = jax.grad(l2)(A0)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


@pytest.mark.reverse_diff
def test_adjoint_pytree_params():
    def mlp_dyn(t, y, p):
        return jnp.tanh(y @ p["w"]) @ p["v"]

    p = {"w": jnp.eye(2) * 0.5, "v": jnp.eye(2) * -0.3}
    solve = make_adjoint_solve(mlp_dyn, mode="joint", rtol=1e-7, atol=1e-9)

    def loss(p):
        return jnp.sum(solve(Y0, 0.0, 1.0, p) ** 2)

    g = jax.grad(loss)(p)

    def loss_ref(p):
        s = solve_ivp_scan(mlp_dyn, Y0, None, t_start=0.0, t_end=1.0, args=p,
                           rtol=1e-7, atol=1e-9, max_steps=128)
        return jnp.sum(s.ys ** 2)

    g_ref = jax.grad(loss_ref)(p)
    for k in p:
        np.testing.assert_allclose(np.asarray(g[k]), np.asarray(g_ref[k]), atol=3e-4)


@pytest.mark.reverse_diff
def test_dense_adjoint_matches_direct():
    """Adjoint with evaluation points: segment-wise backsolve (torchode's
    dense-output adjoint)."""
    from repro.core.adjoint import make_adjoint_solve_dense

    t_eval = jnp.linspace(0.0, 1.5, 6)
    solve = make_adjoint_solve_dense(linear, rtol=1e-8, atol=1e-8)
    w = jnp.arange(1.0, 7.0)[None, :, None]

    def loss(y0, A):
        return jnp.sum(jnp.sin(solve(y0, t_eval, A)) * w)

    g_adj = jax.jit(jax.grad(loss, argnums=(0, 1)))(Y0, A0)

    def loss_ref(y0, A):
        s = solve_ivp_scan(linear, y0, t_eval, args=A, rtol=1e-8, atol=1e-8,
                           max_steps=128)
        return jnp.sum(jnp.sin(s.ys) * w)

    g_ref = jax.grad(loss_ref, argnums=(0, 1))(Y0, A0)
    np.testing.assert_allclose(np.asarray(g_adj[0]), np.asarray(g_ref[0]), atol=2e-4)
    np.testing.assert_allclose(np.asarray(g_adj[1]), np.asarray(g_ref[1]), atol=2e-4)


@pytest.mark.reverse_diff
def test_per_instance_batched_args_rows():
    """``batched_args=True``: every params leaf carries the batch on its
    leading axis and instance i owns row i -- the per-instance backward must
    thread each instance's OWN row through the ravel boundary and return one
    gradient row per instance (no cross-instance sum)."""
    def row_decay(t, y, rates):
        return -rates * y

    y0 = jnp.asarray([[1.0, 0.5], [0.3, 1.2], [2.0, 0.1]], jnp.float32)
    rates = jnp.asarray([[0.5, 2.0], [1.3, 0.7], [0.9, 1.6]], jnp.float32)
    solve = make_adjoint_solve(row_decay, mode="per_instance",
                               rtol=1e-7, atol=1e-9, batched_args=True)

    def loss(y0_, rates_):
        return jnp.sum(solve(y0_, 0.0, 1.0, rates_))

    gy, gr = jax.jit(jax.grad(loss, argnums=(0, 1)))(y0, rates)
    assert gr.shape == rates.shape, "one gradient row per instance"
    # y1 = y0*exp(-r): dL/dy0 = exp(-r), dL/dr = -y0*exp(-r)
    np.testing.assert_allclose(np.asarray(gy), np.exp(-np.asarray(rates)),
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(gr),
                               -np.asarray(y0) * np.exp(-np.asarray(rates)),
                               atol=1e-4)


@pytest.mark.reverse_diff
def test_joint_mode_keeps_parameter_rows():
    """Joint mode needs no flag for per-request rows: the whole stack ravels
    into the augmented state and the returned cotangent keeps the rows."""
    def row_decay(t, y, rates):
        return -rates * y

    y0 = jnp.asarray([[1.0, 0.5], [0.3, 1.2]], jnp.float32)
    rates = jnp.asarray([[0.5, 2.0], [1.3, 0.7]], jnp.float32)
    solve = make_adjoint_solve(row_decay, mode="joint", rtol=1e-7, atol=1e-9)

    gr = jax.jit(jax.grad(
        lambda r: jnp.sum(solve(y0, 0.0, 1.0, r))))(rates)
    np.testing.assert_allclose(np.asarray(gr),
                               -np.asarray(y0) * np.exp(-np.asarray(rates)),
                               atol=1e-4)


def test_joint_mode_backward_accepts_tolerance_rows():
    """Per-row (b,)-shaped tolerances reach the joint backward solve, which
    is a SINGLE stacked instance: they must collapse to the strictest row
    instead of breaking the while_loop carry."""
    solve = make_adjoint_solve(linear, mode="joint",
                               rtol=jnp.full((3,), 1e-7, jnp.float32),
                               atol=jnp.full((3,), 1e-9, jnp.float32))
    ref = make_adjoint_solve(linear, mode="joint", rtol=1e-7, atol=1e-9)

    def loss(s, A):
        return jnp.sum(s(Y0, 0.0, 1.0, A) ** 2)

    g = jax.jit(jax.grad(lambda A: loss(solve, A)))(A0)
    g_ref = jax.jit(jax.grad(lambda A: loss(ref, A)))(A0)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), rtol=1e-6)


class TestDriverRegressions:
    """The two adjoint-driver bugs fixed alongside gradient serving."""

    def test_backsolve_memoizes_custom_vjp_closure(self):
        """Repeated ``BacksolveAdjoint.solve`` calls with the same term must
        reuse one traced closure: rebuilding the ``custom_vjp`` wrapper per
        call re-traced the vector field on every solve."""
        from repro.core import BacksolveAdjoint, Stepper

        traces = []

        def vf(t, y, args):
            traces.append(1)
            return -y * args

        drv = BacksolveAdjoint(Stepper("dopri5"), rtol=1e-6, atol=1e-8)
        y0 = jnp.ones((2, 3), jnp.float32)
        args = jnp.full((3,), 0.7, jnp.float32)
        first = drv.solve(vf, y0, t_start=0.0, t_end=1.0, args=args)
        n_first = len(traces)
        assert n_first > 0
        for _ in range(3):
            again = drv.solve(vf, y0, t_start=0.0, t_end=1.0, args=args)
        assert len(traces) == n_first, \
            "repeated solves must not rebuild (and re-trace) the closure"
        assert len(drv._solve_memo) == 1
        np.testing.assert_array_equal(np.asarray(first), np.asarray(again))

        def vf2(t, y, args):
            traces.append(1)
            return -2.0 * y * args

        drv.solve(vf2, y0, t_start=0.0, t_end=1.0, args=args)
        assert len(drv._solve_memo) == 2, \
            "a different vector field is a different closure"

    def test_backsolve_memo_excluded_from_pytree(self):
        """The memo is a derived cache: an unflattened driver copy starts
        empty (and stays independently usable)."""
        from repro.core import BacksolveAdjoint, Stepper

        drv = BacksolveAdjoint(Stepper("dopri5"), rtol=1e-6, atol=1e-8)
        drv.solve(linear, Y0, t_start=0.0, t_end=1.0, args=A0)
        assert len(drv._solve_memo) == 1
        leaves, treedef = jax.tree_util.tree_flatten(drv)
        copy = jax.tree_util.tree_unflatten(treedef, leaves)
        assert copy._solve_memo == {}
        copy.solve(linear, Y0, t_start=0.0, t_end=1.0, args=A0)
        assert len(copy._solve_memo) == 1

    @pytest.mark.reverse_diff
    def test_checkpoint_tail_gradient_parity(self):
        """``max_steps % checkpoint_every != 0``: the remainder block must
        integrate (and differentiate) exactly like the plain bounded scan --
        the tail used to run outside ``jax.checkpoint``, and a dropped or
        doubled tail would show up here as a value/gradient divergence."""
        from repro.core import ScanAdjoint, Stepper

        kw = dict(max_steps=50, rtol=1e-6, atol=1e-8)
        plain = ScanAdjoint(Stepper("dopri5"), **kw)
        ckpt = ScanAdjoint(Stepper("dopri5"), checkpoint_every=16, **kw)
        assert 50 % 16 != 0  # the regression needs a non-divisible split

        def loss(drv, A):
            sol = drv.solve(linear, Y0, t_start=0.0, t_end=1.0, args=A)
            return jnp.sum(sol.ys ** 2)

        v_plain, g_plain = jax.jit(
            jax.value_and_grad(lambda A: loss(plain, A)))(A0)
        v_ckpt, g_ckpt = jax.jit(
            jax.value_and_grad(lambda A: loss(ckpt, A)))(A0)
        np.testing.assert_array_equal(np.asarray(v_plain), np.asarray(v_ckpt))
        np.testing.assert_allclose(np.asarray(g_plain), np.asarray(g_ckpt),
                                   rtol=1e-6, atol=1e-8)
