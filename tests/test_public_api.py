"""The public-API contract of ``repro.core``.

Every name in ``__all__`` must import (no stale re-exports), resolve to the
module it claims to live in, and carry a mention in the README -- the
documented surface IS the exported surface.  Internal helpers (``next_pow2``
and friends) must not leak back into the package namespace.
"""

import pathlib

import pytest

import repro.core as core

README = (pathlib.Path(__file__).resolve().parent.parent / "README.md").read_text()


@pytest.mark.parametrize("name", sorted(core.__all__))
def test_all_entry_imports(name):
    obj = getattr(core, name)
    assert obj is not None


@pytest.mark.parametrize("name", sorted(core.__all__))
def test_all_entry_documented_in_readme(name):
    assert name in README, (
        f"public name {name!r} is exported from repro.core but never "
        "mentioned in README.md -- document it or drop the export"
    )


def test_no_duplicate_all_entries():
    assert len(core.__all__) == len(set(core.__all__))


def test_next_pow2_not_reexported():
    # internal serving util: reachable as repro.core.serving.next_pow2 only
    assert "next_pow2" not in core.__all__
    from repro.core.serving import next_pow2  # the supported import path

    assert next_pow2(5) == 8


def test_star_import_matches_all():
    ns = {}
    exec("from repro.core import *", ns)
    exported = {k for k in ns if not k.startswith("_")}
    assert set(core.__all__) <= exported
