"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU, output shapes, finiteness, decode/prefill consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_archs, get_config
from repro.models import (
    decode_step,
    forward,
    init_cache,
    init_params,
    pad_cache,
    prefill,
)
from repro.models.frontends import fake_audio_embeds, fake_img_embeds
from repro.optim.adamw import AdamWConfig
from repro.train.steps import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, b=B, s=S, labels=False):
    batch = {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    if labels:
        batch["labels"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    if cfg.n_img_tokens:
        batch["img_embeds"] = fake_img_embeds(cfg, b)
    if cfg.enc_dec:
        batch["audio_embeds"] = fake_audio_embeds(cfg, b, s)
    return batch


@pytest.mark.parametrize("arch", all_archs())
class TestForward:
    def test_forward_shapes_and_finite(self, arch):
        cfg = get_config(arch, reduced=True)
        params = init_params(cfg, KEY)
        logits, _ = jax.jit(lambda p, bt: forward(cfg, p, bt))(params, make_batch(cfg))
        assert logits.shape == (B, S, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_one_train_step(self, arch):
        cfg = get_config(arch, reduced=True)
        state = init_train_state(cfg, KEY)
        step = make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10))
        batch = make_batch(cfg, labels=True)
        new_state, metrics = jax.jit(step)(state, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert float(metrics["loss"]) > 0
        # params actually changed
        delta = jax.tree.map(
            lambda a, b_: float(jnp.max(jnp.abs(a.astype(jnp.float32) - b_.astype(jnp.float32)))),
            state["params"], new_state["params"])
        assert max(jax.tree_util.tree_leaves(delta)) > 0

    def test_remat_matches_no_remat(self, arch):
        cfg = get_config(arch, reduced=True)
        params = init_params(cfg, KEY)
        batch = make_batch(cfg)
        l1, _ = forward(cfg, params, batch, remat=False)
        l2, _ = forward(cfg, params, batch, remat=True)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-4, atol=1e-4)


DECODE_ARCHS = [a for a in all_archs() if a not in ("whisper_large_v3", "llava_next_34b")]


@pytest.mark.parametrize("arch", DECODE_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    params = init_params(cfg, KEY)
    s = 12
    tokens = jax.random.randint(KEY, (B, s), 0, cfg.vocab)
    logits_full, _ = forward(cfg, params, {"tokens": tokens})
    cache = init_cache(cfg, B, s + 2)
    step = jax.jit(lambda tok, pos, c: decode_step(cfg, params, tok, pos, c))
    errs = []
    for i in range(s):
        lg, cache = step(tokens[:, i], jnp.full((B,), i, jnp.int32), cache)
        errs.append(float(jnp.max(jnp.abs(lg - logits_full[:, i]))))
    assert max(errs) < 2e-2, errs


def test_whisper_prefill_decode_consistency():
    cfg = get_config("whisper_large_v3", reduced=True)
    params = init_params(cfg, KEY)
    s = 16
    tokens = jax.random.randint(KEY, (B, s + 1), 0, cfg.vocab)
    audio = fake_audio_embeds(cfg, B, s)
    lg_full, _ = forward(cfg, params, {"tokens": tokens, "audio_embeds": audio})
    lg_pre, cache = prefill(cfg, params, {"tokens": tokens[:, :s], "audio_embeds": audio})
    assert float(jnp.max(jnp.abs(lg_pre - lg_full[:, s - 1]))) < 2e-4
    cache = pad_cache(cfg, cache, s + 4)
    lg_dec, _ = decode_step(cfg, params, tokens[:, s], jnp.full((B,), s, jnp.int32), cache)
    assert float(jnp.max(jnp.abs(lg_dec - lg_full[:, s]))) < 2e-4


def test_llava_prefill_matches_forward():
    cfg = get_config("llava_next_34b", reduced=True)
    params = init_params(cfg, KEY)
    batch = make_batch(cfg)
    logits_full, _ = forward(cfg, params, batch)
    lg_pre, _ = prefill(cfg, params, batch)
    assert float(jnp.max(jnp.abs(lg_pre - logits_full[:, -1]))) < 2e-4


def test_vlm_image_tokens_change_output():
    cfg = get_config("llava_next_34b", reduced=True)
    params = init_params(cfg, KEY)
    batch = make_batch(cfg)
    l1, _ = forward(cfg, params, batch)
    batch2 = dict(batch, img_embeds=batch["img_embeds"] + 1.0)
    l2, _ = forward(cfg, params, batch2)
    assert float(jnp.max(jnp.abs(l1 - l2))) > 1e-4


def test_ode_depth_mode_runs():
    """Continuous-depth execution via the parallel ODE solver (paper tie-in)."""
    cfg = get_config("stablelm_3b", reduced=True)
    cfg = dataclasses.replace(cfg, ode_depth=True, n_layers=len(cfg.pattern))
    params = init_params(cfg, KEY)
    logits, aux = forward(cfg, params, make_batch(cfg))
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert "ode_steps" in aux


def test_param_counts_full_configs():
    """Full (non-reduced) configs build abstractly with plausible param counts."""
    expected = {
        "starcoder2_15b": (13e9, 18e9),
        "starcoder2_7b": (6e9, 9e9),
        "qwen2_5_14b": (12e9, 17e9),
        "stablelm_3b": (2.2e9, 4e9),
        "deepseek_moe_16b": (14e9, 20e9),
        "kimi_k2_1t_a32b": (0.9e12, 1.3e12),
        "jamba_v0_1_52b": (40e9, 60e9),
        "llava_next_34b": (30e9, 40e9),
        "xlstm_350m": (0.25e9, 0.55e9),
        "whisper_large_v3": (1.2e9, 2.2e9),
    }
    for arch, (lo, hi) in expected.items():
        cfg = get_config(arch)
        abstract = jax.eval_shape(lambda c=cfg: init_params(c, KEY))
        n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(abstract))
        assert lo <= n <= hi, f"{arch}: {n:,} params outside [{lo:.1e}, {hi:.1e}]"
