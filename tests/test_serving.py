"""The shape-bucketed solve service: batching must be a pure optimization.

The serving layer's one correctness obligation: a request's result must be
exactly what solving that request alone would have produced -- padding,
bucketing, batch composition and flush timing are invisible.  Explicit
steppers make that testable bitwise in the final-state regime (the solver's
batch-invariance contract); the dense regime agrees to rounding (XLA's
batched interpolant contractions are batch-size dependent).  Plus the
queueing policies: flush-on-size, flush-on-deadline, bounded backlog,
out-of-order completion across buckets, prewarmed cache accounting.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AutoDiffAdjoint,
    CompiledSolver,
    SolveRequest,
    SolveService,
    Solution,
    Status,
    Stepper,
    solve_ivp,
)
from repro.core.serving import next_pow2


def decay(t, y, args):
    return -y * args


def make_requests(n, rng, feat=3, n_eval=None, f=decay, method=None):
    """n mixed-value requests of one shape class."""
    reqs = []
    for _ in range(n):
        reqs.append(SolveRequest(
            f=f,
            y0=jnp.asarray(rng.uniform(0.5, 1.5, (feat,)), jnp.float32),
            t0=float(rng.uniform(0.0, 0.2)),
            t1=float(rng.uniform(0.8, 1.2)),
            t_eval=(None if n_eval is None
                    else np.linspace(0.1, 0.7, n_eval, dtype=np.float32)),
            args=jnp.asarray(rng.uniform(0.5, 2.0, (feat,)), jnp.float32),
            rtol=float(rng.choice([1e-3, 1e-4, 1e-5])),
            method=method,
        ))
    return reqs


def solve_direct(req, t_eval_padded=None, dense=False):
    """The reference: this request alone, b=1, through CompiledSolver."""
    solver = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5")), donate=False)
    f32 = jnp.float32
    kw = dict(
        t_start=jnp.asarray([req.t0], f32),
        t_end=jnp.asarray([req.t1], f32),
        args=None if req.args is None else req.args[None],
        rtol=jnp.asarray([req.rtol if req.rtol is not None else 1e-3], f32),
        atol=jnp.asarray([req.atol if req.atol is not None else 1e-6], f32),
    )
    t_eval = None
    if dense:
        grid = req.t_eval if t_eval_padded is None else t_eval_padded
        t_eval = jnp.asarray(grid, f32)[None]
    return solver.solve(req.f, req.y0[None], t_eval, **kw)


class TestBitwiseAgainstDirectSolves:
    def test_padded_bucket_matches_direct_bitwise_final_state(self):
        """5 mixed requests pad to a bucket of 8; every per-request result is
        bit-for-bit the solo CompiledSolver solve (explicit stepper)."""
        rng = np.random.default_rng(0)
        svc = SolveService(max_batch=8, max_delay=None, default_method="dopri5")
        reqs = make_requests(5, rng)
        futures = [svc.submit(r) for r in reqs]
        svc.flush()
        assert svc.stats()["n_pad_rows"] == 3
        for req, fut in zip(reqs, futures):
            got = fut.result()
            ref = solve_direct(req)
            np.testing.assert_array_equal(np.asarray(got.ys), np.asarray(ref.ys))
            np.testing.assert_array_equal(np.asarray(got.ts), np.asarray(ref.ts))
            np.testing.assert_array_equal(np.asarray(got.status),
                                          np.asarray(ref.status))
            # n_f_evals is whole-batch overhang accounting (instances that
            # finish early keep counting while bucket-mates run) and is
            # composition-dependent by design; the per-instance-masked
            # counters must match exactly.
            for name in ("n_steps", "n_accepted"):
                np.testing.assert_array_equal(np.asarray(got.stats[name]),
                                              np.asarray(ref.stats[name]))

    def test_dense_bucket_matches_direct_to_rounding(self):
        """Dense-output requests with *different grid lengths* share a padded
        length class; values agree with solo solves to rounding and the step
        pattern exactly (the trajectory is identical, only the interpolant
        contraction layout differs with batch size)."""
        rng = np.random.default_rng(1)
        svc = SolveService(max_batch=8, max_delay=None, default_method="dopri5")
        reqs = [make_requests(1, rng, n_eval=n)[0] for n in (3, 5, 6, 8)]
        futures = [svc.submit(r) for r in reqs]
        svc.flush()
        for req, fut in zip(reqs, futures):
            got = fut.result()
            n = req.t_eval.shape[0]
            assert got.ts.shape == (1, n)
            assert got.ys.shape == (1, n, 3)
            np.testing.assert_array_equal(np.asarray(got.ts)[0], req.t_eval)
            # the same request solved alone on its *padded* grid
            cls = next_pow2(n)
            padded = np.concatenate(
                [req.t_eval, np.full(cls - n, req.t_eval[-1], np.float32)])
            ref = solve_direct(req, t_eval_padded=padded, dense=True)
            np.testing.assert_allclose(np.asarray(got.ys),
                                       np.asarray(ref.ys)[:, :n],
                                       rtol=1e-6, atol=1e-7)
            np.testing.assert_array_equal(np.asarray(got.stats["n_steps"]),
                                          np.asarray(ref.stats["n_steps"]))

    def test_pytree_state_requests(self):
        """PyTree y0 round-trips: the served solution keeps the caller's
        structure and matches the batched driver solve."""
        def f(t, y, args):
            return {"a": -y["a"], "b": 2.0 * y["b"]}

        rng = np.random.default_rng(2)
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        y0s = [{"a": jnp.asarray(rng.uniform(1, 2, (2,)), jnp.float32),
                "b": jnp.asarray(rng.uniform(1, 2), jnp.float32)}
               for _ in range(3)]
        futures = [svc.submit(SolveRequest(f=f, y0=y0, t0=0.0, t1=1.0))
                   for y0 in y0s]
        svc.flush()
        for y0, fut in zip(y0s, futures):
            sol = fut.result()
            assert set(sol.ys) == {"a", "b"}
            assert sol.ys["a"].shape == (1, 2)
            assert sol.ys["b"].shape == (1,)
            ref = solve_ivp(f, {"a": y0["a"][None], "b": y0["b"][None]}, None,
                            t_start=0.0, t_end=1.0, method="dopri5")
            np.testing.assert_allclose(sol.ys["a"], np.asarray(ref.ys["a"]),
                                       rtol=1e-6)
            np.testing.assert_allclose(sol.ys["b"], np.asarray(ref.ys["b"]),
                                       rtol=1e-6)

    def test_pytree_state_with_per_request_args(self):
        """Per-request ``args`` ride the ravel boundary: PyTree-state
        requests with *different parameter values* share one bucket (and one
        compiled program) and each matches its solo solve."""
        import jax

        def f(t, y, args):
            return {"a": -args["k"] * y["a"], "b": args["w"] * y["b"]}

        rng = np.random.default_rng(14)
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        reqs = []
        for _ in range(3):
            y0 = {"a": jnp.asarray(rng.uniform(1, 2, (2,)), jnp.float32),
                  "b": jnp.asarray(rng.uniform(1, 2), jnp.float32)}
            args = {"k": jnp.asarray(rng.uniform(0.5, 2.0), jnp.float32),
                    "w": jnp.asarray(rng.uniform(-1.0, 1.0), jnp.float32)}
            reqs.append(SolveRequest(f=f, y0=y0, t0=0.0, t1=1.0, args=args))
        futures = [svc.submit(r) for r in reqs]
        assert svc.stats()["n_buckets"] == 1, \
            "requests with different args values must share a bucket"
        svc.flush()
        for req, fut in zip(reqs, futures):
            sol = fut.result()
            ref = solve_ivp(
                f, jax.tree_util.tree_map(lambda x: x[None], req.y0), None,
                t_start=0.0, t_end=1.0, args=req.args, method="dopri5")
            np.testing.assert_allclose(sol.ys["a"], np.asarray(ref.ys["a"]),
                                       rtol=1e-6)
            np.testing.assert_allclose(sol.ys["b"], np.asarray(ref.ys["b"]),
                                       rtol=1e-6)


class TestQueueingPolicies:
    def test_poll_harvests_with_deadlines_disabled(self):
        """Regression: ``poll()`` with ``max_delay=None`` used to return
        without doing anything -- it must still harvest completed in-flight
        launches and launch full buckets, so a ``poll()``-driven event loop
        makes progress without deadline flushing configured."""
        import time as wall

        rng = np.random.default_rng(13)
        svc = SolveService(max_batch=4, max_delay=None, clock=lambda: 0.0)
        futures = [svc.submit(r) for r in make_requests(2, rng,
                                                        method="dopri5")]
        assert svc.flush() == 1
        for _ in range(1000):  # poll alone must resolve the futures
            svc.poll()
            if all(f._solution is not None for f in futures):
                break
            wall.sleep(0.005)
        assert all(f._solution is not None for f in futures), \
            "poll() must harvest in-flight batches even with max_delay=None"
        assert svc.stats()["n_inflight"] == 0
        assert all(bool(f.result().success.all()) for f in futures)

    def test_flush_on_size(self):
        rng = np.random.default_rng(3)
        svc = SolveService(max_batch=4, max_delay=None)
        futures = [svc.submit(r) for r in make_requests(4, rng, method="dopri5")]
        # the 4th submit hit max_batch: launched immediately, nothing queued
        svc.drain()
        assert all(f.done() for f in futures)
        st = svc.stats()
        assert st["queue_depth"] == 0
        assert st["n_size_flushes"] == 1
        assert st["n_batches"] == 1
        assert st["n_pad_rows"] == 0

    def test_out_of_order_completion_across_buckets(self):
        """A bucket that fills flushes immediately even while an older,
        unrelated bucket is still queued."""
        rng = np.random.default_rng(4)
        svc = SolveService(max_batch=2, max_delay=None)
        slow = svc.submit(make_requests(1, rng, feat=5, method="dopri5")[0])
        fast = [svc.submit(r) for r in make_requests(2, rng, feat=2,
                                                     method="dopri5")]
        svc.drain()
        assert all(f.done() for f in fast), "full bucket must flush eagerly"
        assert not slow.done(), "half-full bucket must keep waiting"
        svc.flush()
        svc.drain()
        assert slow.done()
        assert bool(slow.result().success.all())

    def test_flush_on_deadline(self):
        now = [0.0]
        rng = np.random.default_rng(5)
        svc = SolveService(max_batch=8, max_delay=1.0, clock=lambda: now[0])
        fut = svc.submit(make_requests(1, rng, method="dopri5")[0])
        assert svc.poll() == 0 and not fut.done()
        now[0] = 0.99
        assert svc.poll() == 0 and not fut.done()
        now[0] = 1.0
        assert svc.poll() == 1
        svc.drain()
        assert fut.done()
        assert svc.stats()["n_deadline_flushes"] == 1
        # a later submit triggers the deadline sweep itself
        f2 = svc.submit(make_requests(1, rng, method="dopri5")[0])
        now[0] = 2.5
        f3 = svc.submit(make_requests(1, rng, feat=7, method="dopri5")[0])
        svc.drain()
        assert f2.done(), "submit must deadline-flush other buckets"
        assert not f3.done()

    def test_bounded_queue_drains(self):
        rng = np.random.default_rng(6)
        svc = SolveService(max_batch=8, max_delay=None, max_queue=8)
        futures = [svc.submit(r) for r in make_requests(7, rng, method="dopri5")]
        f8 = svc.submit(make_requests(1, rng, feat=2, method="dopri5")[0])
        assert not f8.done() and svc.stats()["queue_depth"] == 8
        # the 9th submit finds the backlog full and launches everything first
        f9 = svc.submit(make_requests(1, rng, feat=4, method="dopri5")[0])
        svc.drain()
        assert all(f.done() for f in futures) and f8.done()
        assert not f9.done()
        assert svc.stats()["queue_depth"] == 1

    def test_deadline_sweep_only_scans_waiting_buckets(self):
        """The per-submit deadline sweep must not grow with the number of
        shape classes ever served -- only buckets with queued work are
        scanned (a long-lived service sees a long tail of drained classes)."""
        rng = np.random.default_rng(12)
        svc = SolveService(max_batch=2, max_delay=1.0, clock=lambda: 0.0)
        for feat in range(2, 8):  # six classes, each filled and drained
            [svc.submit(r) for r in make_requests(2, rng, feat=feat,
                                                  method="dopri5")]
        assert svc.stats()["n_buckets"] == 6
        assert len(svc._waiting) == 0, "drained buckets must leave the sweep set"
        pending = svc.submit(make_requests(1, rng, feat=2, method="dopri5")[0])
        assert list(svc._waiting) == [pending._bucket.key]
        svc.flush()
        svc.drain()
        assert len(svc._waiting) == 0 and pending.done()

    def test_result_flush_semantics(self):
        rng = np.random.default_rng(7)
        svc = SolveService(max_batch=8, max_delay=None)
        fut = svc.submit(make_requests(1, rng, method="dopri5")[0])
        with pytest.raises(RuntimeError, match="still queued"):
            fut.result(flush=False)
        sol = fut.result()  # flushes its own bucket
        assert bool(sol.success.all())

    def test_failed_batch_delivers_error_and_service_survives(self):
        def bad(t, y, args):
            raise RuntimeError("boom")  # dies at trace time

        rng = np.random.default_rng(8)
        svc = SolveService(max_batch=4, max_delay=None)
        fut = svc.submit(SolveRequest(f=bad, y0=jnp.ones((3,), jnp.float32),
                                      t0=0.0, t1=1.0))
        with pytest.raises(Exception):
            fut.result()
        assert svc.stats()["n_failed_batches"] == 1
        ok = svc.submit(make_requests(1, rng, method="dopri5")[0])
        assert bool(ok.result().success.all())


class TestPrewarm:
    def test_prewarm_compiles_every_class_and_flushes_hit(self):
        import jax

        n_dev = len(jax.devices())  # prewarm covers every serving device
        rng = np.random.default_rng(9)
        svc = SolveService(max_batch=8, max_delay=None)
        example = make_requests(1, rng, method="dopri5")[0]
        assert svc.prewarm(example) == 4 * n_dev  # classes 1, 2, 4, 8
        assert svc.prewarm(example) == 0  # idempotent
        base = svc.stats()
        assert base["cache_misses"] == 4 * n_dev and base["cache_hits"] == 0

        for n in (1, 2, 3, 8):  # classes 1, 2, 4 (padded), 8
            futures = [svc.submit(r) for r in make_requests(n, rng,
                                                            method="dopri5")]
            svc.flush()
            assert all(bool(f.result().success.all()) for f in futures)
        st = svc.stats()
        assert st["cache_misses"] == 4 * n_dev, \
            "prewarmed traffic must never compile"
        assert st["cache_hits"] == 4
        assert st["n_programs"] == 4 * n_dev

    def test_numpy_requests_share_buckets_and_prewarm_with_jnp(self):
        """Dtypes canonicalize at submit: a NumPy float64 request (NumPy's
        default dtype) must hit the same bucket -- and the same prewarmed
        program -- as the float32 jnp request of the same logical shape,
        because the packed batch is float32 either way (x64 off)."""
        import jax

        n_dev = len(jax.devices())
        svc = SolveService(max_batch=4, max_delay=None, default_method="dopri5")
        np_req = SolveRequest(f=decay, y0=np.ones(3), t0=0.0, t1=1.0,
                              args=np.full(3, 0.5))
        assert svc.prewarm(np_req, batch_classes=[2]) == n_dev
        f1 = svc.submit(np_req)
        f2 = svc.submit(SolveRequest(f=decay, y0=jnp.ones((3,), jnp.float32),
                                     t0=0.0, t1=1.0,
                                     args=jnp.full((3,), 0.5, jnp.float32)))
        svc.flush()
        st = svc.stats()
        assert st["n_buckets"] == 1, "dtype canonicalization must not split buckets"
        assert st["cache_misses"] == n_dev and st["cache_hits"] == 1, \
            "the prewarmed program must serve the flush without tracing"
        np.testing.assert_array_equal(np.asarray(f1.result().ys),
                                      np.asarray(f2.result().ys))
        assert f1.result().ys.dtype == np.float32

    def test_unwarmed_class_counts_a_miss(self):
        import jax

        n_dev = len(jax.devices())
        rng = np.random.default_rng(10)
        svc = SolveService(max_batch=8, max_delay=None)
        example = make_requests(1, rng, method="dopri5")[0]
        svc.prewarm(example, batch_classes=[4])
        [svc.submit(r) for r in make_requests(2, rng, method="dopri5")]
        svc.flush()
        st = svc.stats()
        # prewarm(b=4) per device + the cold b=2 class on device 0
        assert st["cache_misses"] == n_dev + 1
        with pytest.raises(ValueError, match="batch class"):
            svc.prewarm(example, batch_classes=[3])


class TestValidationAndStats:
    def test_request_validation(self):
        svc = SolveService(max_batch=4, max_delay=None)
        with pytest.raises(ValueError, match="1-D"):
            svc.submit(SolveRequest(f=decay, y0=jnp.ones((2, 2)), t0=0, t1=1))
        with pytest.raises(ValueError, match="rtol must be scalar"):
            svc.submit(SolveRequest(f=decay, y0=jnp.ones((2,)), t0=0, t1=1,
                                    rtol=np.ones((2,))))
        with pytest.raises(ValueError, match="1-D grid"):
            svc.submit(SolveRequest(f=decay, y0=jnp.ones((2,)), t0=0, t1=1,
                                    t_eval=np.zeros((2, 2))))
        with pytest.raises(ValueError, match="power of two"):
            SolveService(max_batch=6)

    def test_stats_surface_builds_on_registry(self):
        """The service aggregates whatever the per-instance statistics
        registry recorded -- padding rows excluded."""
        rng = np.random.default_rng(11)
        svc = SolveService(max_batch=4, max_delay=None)
        reqs = make_requests(3, rng, method="dopri5")
        futures = [svc.submit(r) for r in reqs]
        svc.flush()
        svc.drain()
        st = svc.stats()
        assert st["pad_waste"] == pytest.approx(0.25)
        assert st["solves_per_sec"] > 0
        expected_steps = sum(float(f.result().stats["n_steps"].sum())
                             for f in futures)
        assert st["solver/n_steps"] == expected_steps
        assert st["solver/n_f_evals"] > 0

    def test_next_pow2(self):
        assert [next_pow2(n) for n in (1, 2, 3, 5, 8, 9)] == [1, 2, 4, 8, 8, 16]
        with pytest.raises(ValueError):
            next_pow2(0)


class TestSolutionViews:
    def test_slice_batch_with_events(self):
        from repro.core import Event

        def fall(t, y, args):
            return jnp.stack((y[..., 1], jnp.full_like(y[..., 1], -9.81)),
                             axis=-1)

        y0 = jnp.asarray([[10.0, 0.0], [20.0, 0.0], [5.0, 1.0]], jnp.float32)
        ev = Event(lambda t, y, args: y[0], terminal=True, direction=-1.0)
        sol = solve_ivp(fall, y0, None, t_start=0.0, t_end=10.0, events=ev)
        part = sol.slice_batch(slice(1, 3))
        assert part.ys.shape == (2, 2)
        assert part.event_t.shape == (2, 1)
        np.testing.assert_array_equal(np.asarray(part.event_t),
                                      np.asarray(sol.event_t)[1:3])
        np.testing.assert_array_equal(np.asarray(part.stats["n_steps"]),
                                      np.asarray(sol.stats["n_steps"])[1:3])

    def test_truncate_eval_rejects_final_state(self):
        sol = solve_ivp(decay, jnp.ones((2, 2)), None, t_start=0.0, t_end=1.0,
                        args=1.0)
        with pytest.raises(ValueError, match="dense-output"):
            sol.truncate_eval(1)

    def test_views_are_plain_dataclass_copies(self):
        sol = solve_ivp(decay, jnp.ones((3, 2)), jnp.linspace(0, 1, 6),
                        args=1.0)
        view = sol.slice_batch(slice(0, 2)).truncate_eval(4)
        assert isinstance(view, Solution)
        assert view.ys.shape == (2, 4, 2)
        assert dataclasses.is_dataclass(view)
        np.testing.assert_array_equal(np.asarray(view.ys),
                                      np.asarray(sol.ys)[:2, :4])


class TestRandomRequestMixes:
    """Hypothesis property: any mix of shapes/values/flush order serves every
    request with its solo solution."""

    def test_random_mix_property(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=10, deadline=None)
        @given(seed=st.integers(0, 2**30),
               n=st.integers(1, 12),
               max_batch=st.sampled_from([2, 4, 8]))
        def run(seed, n, max_batch):
            rng = np.random.default_rng(seed)
            svc = SolveService(max_batch=max_batch, max_delay=None,
                               default_method="dopri5")
            reqs = [make_requests(1, rng,
                                  feat=int(rng.choice([2, 3, 4])))[0]
                    for _ in range(n)]
            futures = [svc.submit(r) for r in reqs]
            svc.flush()
            for req, fut in zip(reqs, futures):
                got = fut.result()
                ref = solve_direct(req)
                assert np.all(np.asarray(got.status)
                              == Status.SUCCESS.value)
                np.testing.assert_array_equal(np.asarray(got.ys),
                                              np.asarray(ref.ys))
                np.testing.assert_array_equal(
                    np.asarray(got.stats["n_steps"]),
                    np.asarray(ref.stats["n_steps"]))

        run()
