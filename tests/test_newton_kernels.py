"""Newton-subsystem kernel validation: Pallas (interpret mode) vs the
pure-jnp oracles -- runs without optional deps (no hypothesis), so the
implicit solver's kernel contract is always checked."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import pallas_impl as pi, ref

SHAPES = [(1, 1), (3, 5), (8, 128), (17, 300), (2, 1025), (9, 64)]


class TestBatchedLinsolve:
    """Newton linear-solve kernel vs the jnp.linalg.solve oracle.  Matrices
    are I - dt*gamma*J-shaped (diagonally dominant), the regime the kernel is
    specified for; agreement there is to 1e-6 in f32."""

    @pytest.mark.parametrize("b,f", [(1, 1), (2, 3), (3, 8), (8, 128), (5, 37), (17, 130)])
    def test_matches_ref(self, b, f):
        rng = np.random.default_rng(b * f)
        A = jnp.asarray(
            np.eye(f) + (0.25 / np.sqrt(f)) * rng.standard_normal((b, f, f)), jnp.float32
        )
        rhs = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        r = ref.batched_linsolve(A, rhs)
        p = pi.batched_linsolve(A, rhs, interpret=True)
        np.testing.assert_allclose(r, p, rtol=1e-4, atol=1e-5)

    def test_oracle_tight(self):
        """Well-conditioned small systems: interpret == ref to 1e-6."""
        rng = np.random.default_rng(7)
        b, f = 4, 6
        A = jnp.asarray(np.eye(f) + 0.1 * rng.standard_normal((b, f, f)), jnp.float32)
        rhs = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        r = ref.batched_linsolve(A, rhs)
        p = pi.batched_linsolve(A, rhs, interpret=True)
        np.testing.assert_allclose(r, p, rtol=1e-6, atol=1e-6)

    def test_residual_is_small(self):
        """The kernel's solution satisfies A @ x = rhs directly."""
        rng = np.random.default_rng(3)
        b, f = 3, 20
        A = jnp.asarray(np.eye(f) + 0.1 * rng.standard_normal((b, f, f)), jnp.float32)
        rhs = jnp.asarray(rng.standard_normal((b, f)), jnp.float32)
        x = pi.batched_linsolve(A, rhs, interpret=True)
        res = jnp.einsum("bij,bj->bi", A, x) - rhs
        np.testing.assert_allclose(np.asarray(res), 0.0, atol=2e-6)

    def test_pivoting_handles_zero_diagonal(self):
        """A matrix needing row swaps (zero on the diagonal) still solves."""
        A = jnp.asarray([[[0.0, 1.0], [1.0, 0.0]]], jnp.float32)
        rhs = jnp.asarray([[2.0, 3.0]], jnp.float32)
        x = pi.batched_linsolve(A, rhs, interpret=True)
        np.testing.assert_allclose(np.asarray(x), [[3.0, 2.0]], atol=1e-6)


class TestErrorNormToleranceShapes:
    """The Pallas error_norm accepts the same tolerance shapes as the ref
    oracle: scalar, per-instance (b,), and full (b, f) (regression)."""

    @pytest.mark.parametrize("shape", ["scalar", "b", "bf"])
    def test_matches_ref(self, shape):
        rng = np.random.default_rng(11)
        b, f = 5, 37
        err, y0, y1 = [jnp.asarray(rng.standard_normal((b, f)), jnp.float32) for _ in range(3)]
        if shape == "scalar":
            atol, rtol = 1e-6, 1e-3
        elif shape == "b":
            atol = jnp.asarray(rng.uniform(1e-8, 1e-4, (b,)), jnp.float32)
            rtol = jnp.asarray(rng.uniform(1e-6, 1e-2, (b,)), jnp.float32)
        else:
            atol = jnp.asarray(rng.uniform(1e-8, 1e-4, (b, f)), jnp.float32)
            rtol = jnp.asarray(rng.uniform(1e-6, 1e-2, (b, f)), jnp.float32)
        r = ref.error_norm(err, y0, y1, atol, rtol)
        p = pi.error_norm(err, y0, y1, atol, rtol, interpret=True)
        np.testing.assert_allclose(r, p, rtol=1e-4, atol=1e-6)


class TestMaskedNewtonUpdate:
    @pytest.mark.parametrize("b,f", SHAPES)
    def test_matches_ref(self, b, f):
        rng = np.random.default_rng(b + 3 * f)
        k, d = [jnp.asarray(rng.standard_normal((b, f)), jnp.float32) for _ in range(2)]
        active = jnp.asarray(rng.uniform(size=(b,)) > 0.4)
        scale = jnp.asarray(np.abs(rng.standard_normal((b, f))) + 0.3, jnp.float32)
        rk, rn = ref.masked_newton_update(k, d, active, scale)
        pk, pn = pi.masked_newton_update(k, d, active, scale, interpret=True)
        np.testing.assert_allclose(rk, pk, rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(rn, pn, rtol=1e-6, atol=1e-6)

    def test_inactive_rows_frozen(self):
        k = jnp.ones((3, 4))
        d = jnp.full((3, 4), 0.5)
        active = jnp.asarray([True, False, True])
        pk, pn = pi.masked_newton_update(k, d, active, jnp.ones((3, 4)), interpret=True)
        np.testing.assert_allclose(np.asarray(pk[1]), 1.0)
        np.testing.assert_allclose(np.asarray(pk[0]), 0.5)
        # the norm is reported for every row (callers mask by active)
        np.testing.assert_allclose(np.asarray(pn), 0.5, rtol=1e-6)


def _chord(rng, b, f):
    """I - dt*gamma*J-shaped matrices, the regime the factor-once ops see."""
    return jnp.asarray(
        np.eye(f) + (0.25 / np.sqrt(f)) * rng.standard_normal((b, f, f)), jnp.float32
    )


class TestBatchedLuFactor:
    """Factor-once LU kernel vs the lax.linalg.lu oracle."""

    @pytest.mark.parametrize("b,f", SHAPES)
    def test_matches_ref(self, b, f):
        rng = np.random.default_rng(5 * b + f)
        A = _chord(rng, b, f)
        r_lu, r_p = ref.batched_lu_factor(A)
        p_lu, p_p = pi.batched_lu_factor(A, interpret=True)
        # identical pivot choices (same max-magnitude, first-match rule) ...
        np.testing.assert_array_equal(np.asarray(r_p), np.asarray(p_p))
        # ... and matching factors up to f32 elimination rounding
        np.testing.assert_allclose(r_lu, p_lu, rtol=1e-4, atol=1e-5)

    def test_factors_reconstruct_matrix(self):
        """P @ A == L @ U for the packed kernel output."""
        rng = np.random.default_rng(2)
        b, f = 3, 12
        A = _chord(rng, b, f)
        lu, perm = pi.batched_lu_factor(A, interpret=True)
        lu = np.asarray(lu)
        L = np.tril(lu, -1) + np.eye(f)
        U = np.triu(lu)
        PA = np.take_along_axis(np.asarray(A), np.asarray(perm)[:, :, None], axis=1)
        np.testing.assert_allclose(L @ U, PA, rtol=1e-5, atol=1e-5)

    def test_pivoting_handles_zero_diagonal(self):
        A = jnp.asarray([[[0.0, 1.0], [1.0, 0.0]]], jnp.float32)
        lu, perm = pi.batched_lu_factor(A, interpret=True)
        np.testing.assert_array_equal(np.asarray(perm), [[1, 0]])


class TestFusedNewtonIter:
    """The one-launch Newton iteration vs the ref composition."""

    @pytest.mark.parametrize("b,f", SHAPES)
    def test_matches_ref(self, b, f):
        rng = np.random.default_rng(7 * b + f)
        A = _chord(rng, b, f)
        k, fk = [jnp.asarray(rng.standard_normal((b, f)), jnp.float32) for _ in range(2)]
        active = jnp.asarray(rng.uniform(size=(b,)) > 0.4)
        scale = jnp.asarray(np.abs(rng.standard_normal((b, f))) + 0.3, jnp.float32)
        r_lu, r_p = ref.batched_lu_factor(A)
        rk, rn = ref.fused_newton_iter(r_lu, r_p, k, fk, active, scale)
        p_lu, p_p = pi.batched_lu_factor(A, interpret=True)
        pk, pn = pi.fused_newton_iter(p_lu, p_p, k, fk, active, scale, interpret=True)
        np.testing.assert_allclose(rk, pk, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(rn, pn, rtol=2e-4, atol=2e-4)

    def test_solves_the_chord_system(self):
        """The committed update satisfies M @ delta = k - f(k) directly."""
        rng = np.random.default_rng(13)
        b, f = 4, 24
        A = _chord(rng, b, f)
        k, fk = [jnp.asarray(rng.standard_normal((b, f)), jnp.float32) for _ in range(2)]
        active = jnp.ones((b,), bool)
        lu, perm = pi.batched_lu_factor(A, interpret=True)
        k_new, _ = pi.fused_newton_iter(lu, perm, k, fk, active,
                                        jnp.ones((b, f)), interpret=True)
        delta = np.asarray(k) - np.asarray(k_new)
        res = np.einsum("bij,bj->bi", np.asarray(A), delta) - np.asarray(k - fk)
        np.testing.assert_allclose(res, 0.0, atol=5e-6)

    def test_inactive_rows_frozen(self):
        rng = np.random.default_rng(17)
        b, f = 3, 4
        A = _chord(rng, b, f)
        k, fk = [jnp.asarray(rng.standard_normal((b, f)), jnp.float32) for _ in range(2)]
        active = jnp.asarray([True, False, True])
        lu, perm = pi.batched_lu_factor(A, interpret=True)
        k_new, _ = pi.fused_newton_iter(lu, perm, k, fk, active,
                                        jnp.ones((b, f)), interpret=True)
        np.testing.assert_array_equal(np.asarray(k_new)[1], np.asarray(k)[1])
        assert not np.array_equal(np.asarray(k_new)[0], np.asarray(k)[0])


