"""The fused diagonally-implicit step: factor-once chord Newton through the
kernel registry.

The contract under test mirrors the explicit fused path (PR 6/9): on the ref
backend a fused DIRK solve is BITWISE-identical to the unfused solver on every
implicit tableau -- including steps that reject on Newton failure and refresh
the chord Jacobian -- because ``batched_lu_factor`` + ``fused_newton_iter``
compose the very jnp primitives ``jnp.linalg.solve`` lowers to, in the same
order.  On top of that: engagement accounting, the FixedController
failure-is-not-success path, and ref/interpret parity for the two new ops.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AutoDiffAdjoint,
    DiagonallyImplicitRK,
    FixedController,
    NewtonConfig,
    Status,
    solve_ivp,
)
from repro.core.tableau import TABLEAUS
from repro.kernels import ops, pallas_impl as pi, ref

IMPLICIT = sorted(n for n in TABLEAUS if TABLEAUS[n].implicit)


def vdp(t, y, mu):
    x, xd = y[..., 0], y[..., 1]
    return jnp.stack((xd, mu * (1 - x**2) * xd - x), axis=-1)


def robertson(t, y, args):
    y1, y2, y3 = y[..., 0], y[..., 1], y[..., 2]
    return jnp.stack(
        (
            -0.04 * y1 + 1e4 * y2 * y3,
            0.04 * y1 - 1e4 * y2 * y3 - 3e7 * y2**2,
            3e7 * y2**2,
        ),
        axis=-1,
    )


@pytest.fixture
def ref_backend():
    old = ops.backend()
    ops.set_backend("ref")
    yield
    ops.set_backend(old)


def _assert_bitwise(a, c):
    """Whole-Solution equality plus proof the fused path actually ran."""
    np.testing.assert_array_equal(np.asarray(a.ys), np.asarray(c.ys))
    np.testing.assert_array_equal(np.asarray(a.ts), np.asarray(c.ts))
    np.testing.assert_array_equal(np.asarray(a.status), np.asarray(c.status))
    for key in ("n_steps", "n_accepted", "n_f_evals", "n_newton_iters",
                "n_jac_evals"):
        np.testing.assert_array_equal(
            np.asarray(a.stats[key]), np.asarray(c.stats[key]), err_msg=key)
    np.testing.assert_array_equal(np.asarray(c.stats["n_fused_steps"]),
                                  np.asarray(c.stats["n_steps"]))
    assert "n_fused_steps" not in a.stats
    assert not np.asarray(c.stats["fused_fallback_reason"]).any()


class TestFusedImplicitBitwise:
    """ref-backend fused DIRK solves are indistinguishable from unfused."""

    @pytest.mark.parametrize("method", IMPLICIT)
    @pytest.mark.parametrize("dense", [False, True])
    def test_vdp_mixed_stiffness(self, ref_backend, method, dense):
        # One batch spanning four decades of stiffness: the mu=1 instance
        # accepts nearly every step while mu=1000 rejects and refreshes its
        # chord Jacobian on its own schedule.
        mu = jnp.asarray([1.0, 10.0, 100.0, 1000.0], jnp.float32)
        y0 = jnp.tile(jnp.asarray([[2.0, 0.0]], jnp.float32), (4, 1))
        te = jnp.linspace(0.0, 1.0, 5) if dense else None
        kw = dict(t_start=0.0, t_end=1.0, args=mu,
                  method=DiagonallyImplicitRK(method),
                  rtol=1e-4, atol=1e-6, max_steps=8000, dense=dense)
        a = solve_ivp(vdp, y0, te, fused=False, **kw)
        c = solve_ivp(vdp, y0, te, fused=True, **kw)
        _assert_bitwise(a, c)
        if method != "implicit_euler":
            # 1st-order implicit_euler grinds to max_steps under PID at this
            # tolerance (identically on both paths -- the equality above is
            # the contract); the higher-order tableaus must actually finish.
            assert np.all(np.asarray(a.status) == Status.SUCCESS.value)

    @pytest.mark.parametrize("method", ["trbdf2", "kvaerno5"])
    def test_robertson(self, ref_backend, method):
        y0 = jnp.tile(jnp.asarray([[1.0, 0.0, 0.0]], jnp.float32), (3, 1))
        kw = dict(t_start=0.0, t_end=100.0,
                  method=DiagonallyImplicitRK(method),
                  rtol=1e-4, atol=1e-8, max_steps=8000)
        a = solve_ivp(robertson, y0, None, fused=False, **kw)
        c = solve_ivp(robertson, y0, None, fused=True, **kw)
        _assert_bitwise(a, c)
        assert np.all(np.asarray(a.status) == Status.SUCCESS.value)

    def test_newton_reject_path(self, ref_backend):
        # A starved Newton budget forces solver-failure rejects (n_steps >
        # n_accepted): the failed -> inf-ratio -> controller-reject route must
        # agree bitwise between the fused kernel and the unfused solver.
        stepper = DiagonallyImplicitRK("kvaerno5", newton=NewtonConfig(max_iters=2))
        kw = dict(rtol=1e-5, atol=1e-6, max_steps=20_000)
        sk = dict(t_start=0.0, t_end=20.0, args=1000.0)
        y0 = jnp.asarray([[2.0, 0.0]], jnp.float32)
        a = AutoDiffAdjoint(stepper, fused=False, **kw).solve(vdp, y0, None, **sk)
        c = AutoDiffAdjoint(stepper, fused=True, **kw).solve(vdp, y0, None, **sk)
        _assert_bitwise(a, c)
        assert np.all(np.asarray(a.stats["n_steps"])
                      > np.asarray(a.stats["n_accepted"]))

    def test_fixed_controller_failure_is_not_success(self, ref_backend):
        # The fused kernel's ctrl_mode="fixed" switch would happily accept
        # everything; the solver-failure column must veto the commit exactly
        # like the unfused path (regression contract of PR 9's fixed mode).
        stepper = DiagonallyImplicitRK(
            "implicit_euler", newton=NewtonConfig(tol=1e-12, max_iters=1))
        kw = dict(max_steps=50, controller=FixedController())
        f = lambda t, y, a: -(y**3)
        y0 = jnp.full((2, 1), 2.0, jnp.float32)
        a = AutoDiffAdjoint(stepper, fused=False, **kw).solve(
            f, y0, None, t_start=0.0, t_end=1.0, dt0=0.25)
        c = AutoDiffAdjoint(stepper, fused=True, **kw).solve(
            f, y0, None, t_start=0.0, t_end=1.0, dt0=0.25)
        _assert_bitwise(a, c)
        assert np.all(np.asarray(c.status) == Status.REACHED_MAX_STEPS.value)
        assert np.all(np.asarray(c.stats["n_accepted"]) == 0)
        np.testing.assert_allclose(np.asarray(c.ys), 2.0)

    def test_fixed_controller_bitwise(self, ref_backend):
        stepper = DiagonallyImplicitRK("trbdf2")
        kw = dict(max_steps=200, controller=FixedController())
        y0 = jnp.asarray([[2.0, 0.0]], jnp.float32)
        a = AutoDiffAdjoint(stepper, fused=False, **kw).solve(
            vdp, y0, None, t_start=0.0, t_end=1.0, dt0=0.05, args=5.0)
        c = AutoDiffAdjoint(stepper, fused=True, **kw).solve(
            vdp, y0, None, t_start=0.0, t_end=1.0, dt0=0.05, args=5.0)
        _assert_bitwise(a, c)


class TestNewOpsParity:
    """ref vs pallas-interpret agreement for the two new registry ops."""

    SHAPES = [(1, 1), (3, 5), (8, 128), (17, 300), (2, 129), (9, 64)]

    @staticmethod
    def _chordlike(rng, b, f):
        # diagonally-dominant like M = I - dt*gamma*J on a sane step
        A = rng.normal(size=(b, f, f)).astype(np.float32)
        A += (3.0 + np.abs(A).sum(axis=-1).max(axis=-1))[:, None, None] * np.eye(f)
        return jnp.asarray(A)

    @pytest.mark.parametrize("b,f", SHAPES)
    def test_lu_factor_and_iter_match_ref(self, b, f):
        rng = np.random.default_rng(b * 131 + f)
        A = self._chordlike(rng, b, f)
        k = jnp.asarray(rng.normal(size=(b, f)).astype(np.float32))
        fk = jnp.asarray(rng.normal(size=(b, f)).astype(np.float32))
        active = jnp.asarray(rng.integers(0, 2, size=(b,)).astype(bool))
        scale = jnp.asarray(rng.uniform(0.5, 2.0, size=(b, f)).astype(np.float32))

        lu_r, p_r = ref.batched_lu_factor(A)
        lu_i, p_i = pi.batched_lu_factor(A, interpret=True)
        np.testing.assert_array_equal(np.asarray(p_r), np.asarray(p_i))
        k_r, n_r = ref.fused_newton_iter(lu_r, p_r, k, fk, active, scale)
        k_i, n_i = pi.fused_newton_iter(lu_i, p_i, k, fk, active, scale,
                                        interpret=True)
        tol = dict(rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(k_r), np.asarray(k_i), **tol)
        np.testing.assert_allclose(np.asarray(n_r), np.asarray(n_i), **tol)
        # inactive rows commit nothing, in both backends
        frozen = ~np.asarray(active)
        np.testing.assert_array_equal(np.asarray(k_i)[frozen],
                                      np.asarray(k)[frozen])

    def test_ref_iter_is_masked_linsolve_update(self):
        # The ref fused iteration IS batched_linsolve + masked_newton_update
        # against the same matrix, bitwise -- the factor-once parity anchor.
        rng = np.random.default_rng(3)
        b, f = 6, 7
        A = self._chordlike(rng, b, f)
        k = jnp.asarray(rng.normal(size=(b, f)).astype(np.float32))
        fk = jnp.asarray(rng.normal(size=(b, f)).astype(np.float32))
        active = jnp.asarray([True, True, False, True, False, True])
        scale = jnp.asarray(rng.uniform(0.5, 2.0, size=(b, f)).astype(np.float32))

        delta = ref.batched_linsolve(A, k - fk)
        k_a, n_a = ref.masked_newton_update(k, delta, active, scale)
        k_b, n_b = ref.fused_newton_iter(*ref.batched_lu_factor(A), k, fk,
                                         active, scale)
        np.testing.assert_array_equal(np.asarray(k_a), np.asarray(k_b))
        np.testing.assert_array_equal(np.asarray(n_a), np.asarray(n_b))

    def test_all_inactive_batch_is_identity(self):
        rng = np.random.default_rng(11)
        b, f = 4, 9
        A = self._chordlike(rng, b, f)
        k = jnp.asarray(rng.normal(size=(b, f)).astype(np.float32))
        fk = jnp.asarray(rng.normal(size=(b, f)).astype(np.float32))
        active = jnp.zeros((b,), bool)
        scale = jnp.ones((b, f), jnp.float32)
        for impl, extra in ((ref, {}), (pi, {"interpret": True})):
            lu, p = impl.batched_lu_factor(A, **extra)
            k_new, _ = impl.fused_newton_iter(lu, p, k, fk, active, scale, **extra)
            np.testing.assert_array_equal(np.asarray(k_new), np.asarray(k))

    def test_masked_parity_property(self):
        """Hypothesis sweep: parity between the fused iteration and the
        unfused linsolve+update pair holds under arbitrary active masks,
        including all-inactive and all-active batches."""
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        @settings(max_examples=25, deadline=None)
        @given(
            b=st.integers(1, 9),
            f=st.integers(1, 24),
            seed=st.integers(0, 2**16),
            mask=st.sampled_from(["none", "all", "random"]),
        )
        def prop(b, f, seed, mask):
            rng = np.random.default_rng(seed)
            A = self._chordlike(rng, b, f)
            k = jnp.asarray(rng.normal(size=(b, f)).astype(np.float32))
            fk = jnp.asarray(rng.normal(size=(b, f)).astype(np.float32))
            active = jnp.asarray(
                np.zeros(b, bool) if mask == "none"
                else np.ones(b, bool) if mask == "all"
                else rng.integers(0, 2, size=b).astype(bool))
            scale = jnp.asarray(rng.uniform(0.5, 2.0, (b, f)).astype(np.float32))
            delta = ref.batched_linsolve(A, k - fk)
            k_a, n_a = ref.masked_newton_update(k, delta, active, scale)
            k_b, n_b = ref.fused_newton_iter(*ref.batched_lu_factor(A), k, fk,
                                             active, scale)
            np.testing.assert_array_equal(np.asarray(k_a), np.asarray(k_b))
            np.testing.assert_array_equal(np.asarray(n_a), np.asarray(n_b))

        prop()


class TestFusedImplicitInterpret:
    """End-to-end fused DIRK solve through the pallas interpret backend."""

    @pytest.mark.parametrize("method", ["trbdf2", "kvaerno5"])
    def test_interpret_solve_matches_ref(self, method):
        mu = jnp.asarray([1.0, 100.0], jnp.float32)
        y0 = jnp.tile(jnp.asarray([[2.0, 0.0]], jnp.float32), (2, 1))
        kw = dict(t_start=0.0, t_end=1.0, args=mu,
                  method=DiagonallyImplicitRK(method),
                  rtol=1e-4, atol=1e-6, max_steps=4000, fused=True)
        old = ops.backend()
        try:
            ops.set_backend("ref")
            a = solve_ivp(vdp, y0, None, **kw)
            ops.set_backend("interpret")
            c = solve_ivp(vdp, y0, None, **kw)
        finally:
            ops.set_backend(old)
        assert np.all(np.asarray(c.status) == Status.SUCCESS.value)
        np.testing.assert_array_equal(np.asarray(c.stats["n_fused_steps"]),
                                      np.asarray(c.stats["n_steps"]))
        np.testing.assert_allclose(np.asarray(a.ys), np.asarray(c.ys),
                                   rtol=5e-3, atol=1e-4)
