"""The componentized API: PyTree states, drivers, and the statistics registry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AutoDiffAdjoint,
    BacksolveAdjoint,
    ODETerm,
    ScanAdjoint,
    Status,
    Stepper,
    StepFunction,
    integral_controller,
    make_solver,
    pid_controller,
    ravel_state,
    solve_ivp,
    solve_ivp_scan,
)
from repro.core.stepper import initial_step_size


def decay(t, y, args):
    return -y


def tree_decay(t, y, args):
    """Per-instance PyTree dynamics: every leaf decays."""
    return jax.tree_util.tree_map(lambda x: -x, y)


NESTED_Y0 = {
    "pos": jnp.array([[1.0, 2.0], [0.5, -1.0], [3.0, 0.1]]),
    "aux": {"v": jnp.array([[2.0], [1.0], [-0.5]])},
}


class TestPyTreeStates:
    def test_nested_dict_roundtrip_matches_flat(self):
        """A nested-dict IVP through AutoDiffAdjoint equals the flat-array
        solve on the raveled state, and stats come from the registry."""
        t_eval = jnp.linspace(0.0, 1.5, 7)
        solver = AutoDiffAdjoint(Stepper("tsit5"), pid_controller(),
                                 rtol=1e-7, atol=1e-9)
        sol = solver.solve(tree_decay, NESTED_Y0, t_eval)

        y0_flat, raveled = ravel_state(NESTED_Y0)
        assert raveled is not None and raveled.num_features == 3
        flat = solver.solve(decay, y0_flat, t_eval)

        assert jax.tree_util.tree_structure(sol.ys) == jax.tree_util.tree_structure(NESTED_Y0)
        assert sol.ys["pos"].shape == (3, 7, 2)
        assert sol.ys["aux"]["v"].shape == (3, 7, 1)
        # same flat trajectory once re-raveled
        reravel = jnp.concatenate(
            [sol.ys["aux"]["v"], sol.ys["pos"]], axis=-1
        )  # ravel_pytree sorts dict keys: aux < pos
        np.testing.assert_allclose(np.asarray(reravel), np.asarray(flat.ys),
                                   rtol=1e-6, atol=1e-8)
        for key in ("n_steps", "n_accepted", "n_f_evals", "n_initialized"):
            np.testing.assert_array_equal(np.asarray(sol.stats[key]),
                                          np.asarray(flat.stats[key]))
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)

    def test_pytree_backward_in_time(self):
        """Integrating dy/dt = -y from t=1 down to t=0 grows by e."""
        solver = AutoDiffAdjoint(Stepper("dopri5"), rtol=1e-9, atol=1e-9)
        sol = solver.solve(tree_decay, NESTED_Y0, None, t_start=1.0, t_end=0.0)
        expect = jax.tree_util.tree_map(lambda x: np.asarray(x) * np.e, NESTED_Y0)
        for got, want in zip(jax.tree_util.tree_leaves(sol.ys),
                             jax.tree_util.tree_leaves(expect)):
            np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5)

    def test_pytree_mixed_directions(self):
        """Per-instance integration ranges with mixed directions."""
        y0 = {"a": jnp.ones((3, 1)), "b": jnp.full((3, 2), 2.0)}
        t_start = jnp.array([0.0, 0.0, 1.0])
        t_end = jnp.array([1.0, 2.0, -1.0])
        solver = AutoDiffAdjoint(Stepper("dopri5"), rtol=1e-9, atol=1e-9)
        sol = solver.solve(tree_decay, y0, None, t_start=t_start, t_end=t_end)
        scale = np.exp(-(np.asarray(t_end) - np.asarray(t_start)))
        np.testing.assert_allclose(np.asarray(sol.ys["a"])[:, 0], scale, rtol=1e-5)
        np.testing.assert_allclose(
            np.asarray(sol.ys["b"]),
            np.broadcast_to(2.0 * scale[:, None], (3, 2)),
            rtol=1e-5,
        )

    def test_tuple_pytree_of_1d_leaves_not_mistaken_for_flat(self):
        """A tuple of (b,)-shaped states is a PyTree, not a (b, f) array."""
        y0 = (jnp.array([1.0, 2.0, 3.0]), jnp.array([0.5, 0.5, 0.5]))
        sol = AutoDiffAdjoint(Stepper("dopri5"), rtol=1e-8, atol=1e-8).solve(
            tree_decay, y0, None, t_start=0.0, t_end=1.0)
        assert isinstance(sol.ys, tuple) and len(sol.ys) == 2
        for got, want in zip(sol.ys, y0):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want) * np.exp(-1.0),
                                       rtol=1e-5)

    def test_nested_numeric_lists_still_flat(self):
        y0_flat, raveled = ravel_state([[1.0, 2.0], [3.0, 4.0]])
        assert raveled is None and y0_flat.shape == (2, 2)

    def test_solve_ivp_wrapper_accepts_pytree(self):
        """The compatibility wrapper inherits PyTree support from the driver."""
        sol = solve_ivp(tree_decay, NESTED_Y0, None, t_start=0.0, t_end=1.0,
                        rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(
            np.asarray(sol.ys["pos"]), np.asarray(NESTED_Y0["pos"]) * np.exp(-1.0),
            rtol=1e-5,
        )

    @pytest.mark.reverse_diff
    def test_scan_adjoint_pytree_gradient(self):
        """Reverse-mode gradients flow through the ravel boundary."""
        def dyn(t, y, a):
            return jax.tree_util.tree_map(lambda x: -a * x, y)

        def loss(a):
            driver = ScanAdjoint(Stepper("dopri5"), max_steps=64, rtol=1e-6, atol=1e-8)
            sol = driver.solve(dyn, {"x": jnp.ones((2, 1))}, None,
                               t_start=0.0, t_end=1.0, args=a)
            return jnp.sum(sol.ys["x"])

        g = jax.grad(loss)(1.5)
        assert abs(float(g) - (-2 * np.exp(-1.5))) < 1e-4


class TestDrivers:
    def test_autodiff_adjoint_matches_solve_ivp(self):
        y0 = jnp.array([[1.0, 0.5], [0.2, -0.4]])
        t_eval = jnp.linspace(0.0, 2.0, 9)
        a = AutoDiffAdjoint(Stepper("dopri5"), integral_controller()).solve(decay, y0, t_eval)
        b = solve_ivp(decay, y0, t_eval, method="dopri5", controller=integral_controller())
        np.testing.assert_allclose(np.asarray(a.ys), np.asarray(b.ys), rtol=1e-7)
        for key in a.stats:
            np.testing.assert_array_equal(np.asarray(a.stats[key]), np.asarray(b.stats[key]))

    @pytest.mark.reverse_diff
    def test_scan_adjoint_matches_solve_ivp_scan_gradient(self):
        def loss_driver(a):
            sol = ScanAdjoint(Stepper("dopri5"), max_steps=64, rtol=1e-6, atol=1e-8,
                              checkpoint_every=16).solve(
                lambda t, y, a_: -a_ * y, jnp.ones((2, 1)), None,
                t_start=0.0, t_end=1.0, args=a)
            return jnp.sum(sol.ys)

        def loss_wrapper(a):
            sol = solve_ivp_scan(lambda t, y, a_: -a_ * y, jnp.ones((2, 1)), None,
                                 t_start=0.0, t_end=1.0, args=a, max_steps=64,
                                 rtol=1e-6, atol=1e-8, checkpoint_every=16)
            return jnp.sum(sol.ys)

        g1 = jax.grad(loss_driver)(1.3)
        g2 = jax.grad(loss_wrapper)(1.3)
        np.testing.assert_allclose(float(g1), float(g2), rtol=1e-6)

    @pytest.mark.reverse_diff
    def test_backsolve_adjoint_gradients(self):
        A0 = jnp.array([[-0.5, 0.3], [-0.2, -0.8]])
        Y0 = jnp.array([[1.0, 0.5], [0.3, -1.2]])

        def linear(t, y, A):
            return y @ A.T

        driver = BacksolveAdjoint(Stepper("dopri5"), rtol=1e-8, atol=1e-8)

        def loss(A):
            return jnp.sum(driver.solve(linear, Y0, t_start=jnp.zeros(2),
                                        t_end=jnp.ones(2), args=A) ** 2)

        def loss_ref(A):
            s = solve_ivp_scan(linear, Y0, None, t_start=0.0, t_end=1.0, args=A,
                               rtol=1e-8, atol=1e-8, max_steps=128)
            return jnp.sum(s.ys ** 2)

        gA = jax.grad(loss)(A0)
        gA_ref = jax.grad(loss_ref)(A0)
        np.testing.assert_allclose(np.asarray(gA), np.asarray(gA_ref), atol=2e-4)

    def test_make_solver_triple_still_composable(self):
        """The legacy (init, body, finish) triple drives a hand-rolled loop."""
        init, body, finish = make_solver(decay, method="dopri5", rtol=1e-8, atol=1e-8)
        state, consts = init(jnp.ones((2, 1)), None, 0.0, 1.0, None, None)
        state = jax.lax.while_loop(
            lambda s: jnp.any(s.running) & (s.it < 1000),
            lambda s: body(s, consts, None),
            state,
        )
        sol = finish(state, consts)
        np.testing.assert_allclose(np.asarray(sol.ys)[:, 0], np.exp(-1.0), rtol=1e-6)

    def test_driver_accepts_method_string(self):
        sol = AutoDiffAdjoint("tsit5").solve(decay, jnp.ones((1, 1)), None,
                                             t_start=0.0, t_end=1.0)
        np.testing.assert_allclose(np.asarray(sol.ys)[0, 0], np.exp(-1.0), rtol=1e-3)

    @pytest.mark.reverse_diff
    def test_backsolve_adjoint_custom_tableau(self):
        """A Stepper built from an unregistered tableau must drive the
        backward solve with its own coefficients (regression: the stepper used
        to be degraded to its tableau *name*)."""
        import dataclasses as dc

        from repro.core import get_tableau

        custom = dc.replace(get_tableau("dopri5"), name="my_dopri5")
        driver = BacksolveAdjoint(Stepper(custom), rtol=1e-8, atol=1e-8)
        y = driver.solve(decay, jnp.ones((2, 1)), t_start=jnp.zeros(2),
                         t_end=jnp.ones(2))
        np.testing.assert_allclose(np.asarray(y)[:, 0], np.exp(-1.0), rtol=1e-6)
        g = jax.grad(lambda y0: jnp.sum(driver.solve(decay, y0, t_start=jnp.zeros(2),
                                                     t_end=jnp.ones(2))))(jnp.ones((2, 1)))
        np.testing.assert_allclose(np.asarray(g), np.exp(-1.0), rtol=1e-5)


class TestBackwardTime:
    """Backward integration (t_end < t_start) with dense output, through both
    loop drivers and the windowed dense-output path."""

    # integrate y' = -y from t=1 down to t=0, starting at y(1) = e^-1:
    # the exact trajectory is y(t) = exp(-t), ending at y(0) = 1.
    T_EVAL = jnp.linspace(1.0, 0.0, 9)
    Y0 = jnp.full((3, 2), float(np.exp(-1.0)))

    def _check(self, sol):
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
        exact = np.exp(-np.asarray(sol.ts))[..., None]
        np.testing.assert_allclose(
            np.asarray(sol.ys), np.broadcast_to(exact, sol.ys.shape), rtol=1e-4, atol=1e-6
        )

    def test_autodiff_adjoint_backward_dense(self):
        solver = AutoDiffAdjoint(Stepper("dopri5"), rtol=1e-7, atol=1e-9)
        self._check(solver.solve(decay, self.Y0, self.T_EVAL))

    def test_scan_adjoint_backward_dense(self):
        solver = ScanAdjoint(Stepper("dopri5"), max_steps=128, rtol=1e-7, atol=1e-9)
        self._check(solver.solve(decay, self.Y0, self.T_EVAL))

    @pytest.mark.parametrize("window", [2, 4])
    def test_windowed_dense_backward(self, window):
        """The windowed dense-output cursor walks eval points in integration
        order, which for a backward solve is decreasing time."""
        solver = AutoDiffAdjoint(Stepper("dopri5"), rtol=1e-7, atol=1e-9,
                                 dense_window=window)
        sol = solver.solve(decay, self.Y0, self.T_EVAL)
        self._check(sol)
        assert np.all(np.asarray(sol.stats["n_initialized"]) == self.T_EVAL.shape[0])

    def test_backward_final_state_only(self):
        sol = AutoDiffAdjoint(Stepper("tsit5"), rtol=1e-7, atol=1e-9).solve(
            decay, self.Y0, None, t_start=1.0, t_end=0.0
        )
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
        np.testing.assert_allclose(np.asarray(sol.ys), 1.0, rtol=1e-5)

    def test_backward_implicit(self):
        solver = AutoDiffAdjoint("kvaerno5", rtol=1e-6, atol=1e-8)
        self._check(solver.solve(decay, self.Y0, self.T_EVAL))

    @pytest.mark.reverse_diff
    def test_scan_adjoint_backward_gradient(self):
        """Reverse-mode gradients flow through a backward-time dense solve."""

        def loss(y0):
            sol = solve_ivp_scan(decay, y0, self.T_EVAL, max_steps=96,
                                 rtol=1e-6, atol=1e-8)
            return jnp.sum(sol.ys[:, -1])  # y at t=0 == y0 * e

        g = jax.grad(loss)(self.Y0)
        np.testing.assert_allclose(np.asarray(g), np.e, rtol=1e-4)


class TestInitialStepClamp:
    """Regression: the automatic first-step proposal must respect the
    controller's dt bounds (it used to be unbounded -- on smooth problems the
    heuristic proposes 100x its pilot step)."""

    def test_proposal_clamped_to_dt_max(self):
        term = ODETerm(decay)
        y0 = jnp.ones((2, 4))
        t0 = jnp.zeros((2,))
        direction = jnp.ones((2,))
        f0 = term.vf(t0, y0, None)
        free = initial_step_size(term, t0, y0, f0, direction, 5, 1e-6, 1e-3)
        assert np.all(np.asarray(jnp.abs(free)) > 0.05), "smooth problem: eager proposal"
        clamped = initial_step_size(term, t0, y0, f0, direction, 5, 1e-6, 1e-3,
                                    dt_min=0.0, dt_max=0.01)
        np.testing.assert_allclose(np.asarray(jnp.abs(clamped)), 0.01, rtol=1e-6)
        floored = initial_step_size(term, t0, y0, f0, direction, 5, 1e-6, 1e-3,
                                    dt_min=0.5, dt_max=10.0)
        assert np.all(np.asarray(jnp.abs(floored)) >= 0.5)

    def test_solver_first_step_respects_controller_dt_max(self):
        ctrl = integral_controller(dt_max=0.01)
        sol = solve_ivp(decay, jnp.ones((1, 1)), None, t_start=0.0, t_end=1.0,
                        controller=ctrl, rtol=1e-3, atol=1e-6)
        # dt <= 0.01 everywhere (including the first step) forces >= 100 steps
        assert int(np.asarray(sol.stats["n_steps"])[0]) >= 100
        assert np.asarray(sol.status)[0] == Status.SUCCESS.value

    def test_clamp_preserves_direction(self):
        term = ODETerm(decay)
        y0 = jnp.ones((1, 2))
        f0 = term.vf(jnp.zeros((1,)), y0, None)
        h = initial_step_size(term, jnp.zeros((1,)), y0, f0, -jnp.ones((1,)), 5,
                              1e-6, 1e-3, dt_max=0.01)
        assert float(h[0]) == pytest.approx(-0.01)


class RejectionCounter:
    """A user-registered statistics contributor (counts rejected attempts)."""

    def init_stats(self, batch):
        return {"n_rejected": jnp.zeros((batch,), dtype=jnp.int32)}

    def update_stats(self, stats, ctx):
        rejected = ctx.running & ~ctx.accept
        return {**stats, "n_rejected": stats["n_rejected"] + rejected.astype(jnp.int32)}


class TestStatsRegistry:
    def vdp(self, t, y, mu):
        x, xd = y[..., 0], y[..., 1]
        return jnp.stack((xd, mu * (1 - x ** 2) * xd - x), axis=-1)

    def test_default_registry_keys(self):
        sol = solve_ivp(decay, jnp.ones((2, 1)), None, t_start=0.0, t_end=1.0)
        assert set(sol.stats) == {"n_steps", "n_accepted", "n_f_evals", "n_initialized"}

    def test_custom_contributor(self):
        y0 = jnp.stack([jnp.array([2.0, 0.0]) + 0.3 * i for i in range(4)])
        driver = AutoDiffAdjoint(Stepper("dopri5"), extra_stats=(RejectionCounter(),))
        sol = driver.solve(self.vdp, y0, None, t_start=0.0, t_end=10.0, args=10.0)
        stats = {k: np.asarray(v) for k, v in sol.stats.items()}
        assert "n_rejected" in stats
        np.testing.assert_array_equal(
            stats["n_rejected"], stats["n_steps"] - stats["n_accepted"]
        )

    def test_duplicate_stat_name_rejected(self):
        class Clash:
            def init_stats(self, batch):
                return {"n_steps": jnp.zeros((batch,), jnp.int32)}

        sf = StepFunction(ODETerm(decay), Stepper("dopri5"), extra_stats=(Clash(),))
        with pytest.raises(ValueError, match="duplicate statistic"):
            sf.init(jnp.ones((1, 1)), None, 0.0, 1.0, None, None)

    def test_registry_under_jit(self):
        driver = AutoDiffAdjoint(Stepper("tsit5"), extra_stats=(RejectionCounter(),))
        f = jax.jit(lambda y: driver.solve(self.vdp, y, None, t_start=0.0,
                                           t_end=5.0, args=5.0).stats["n_rejected"])
        out = f(jnp.array([[2.0, 0.0]] * 3))
        assert out.shape == (3,)

    def test_duck_typed_controller_still_records_n_accepted(self):
        """Pre-registry custom controllers (no init_stats hook) keep the
        unconditional n_accepted stat the Solution contract promises."""
        class OldSchoolController:
            dt_min = 0.0
            dt_max = float("inf")

            def init(self, batch, dtype):
                one = jnp.ones((batch,), dtype=dtype)
                from repro.core.controller import ControllerState
                return ControllerState(one, one)

            def __call__(self, err_ratio, dt, state, k):
                accept = jnp.isfinite(err_ratio) & (err_ratio <= 1.0)
                factor = jnp.where(accept, 1.1, 0.5)
                return accept, dt * factor, state

        sol = solve_ivp(decay, jnp.ones((2, 1)), None, t_start=0.0, t_end=1.0,
                        controller=OldSchoolController())
        stats = {k: np.asarray(v) for k, v in sol.stats.items()}
        assert "n_accepted" in stats
        assert np.all(stats["n_accepted"] <= stats["n_steps"])
        assert np.all(stats["n_accepted"] > 0)

    def test_fixed_controller_registry(self):
        sol = solve_ivp(decay, jnp.ones((2, 1)), None, t_start=0.0, t_end=1.0,
                        method="rk4", dt0=0.1, max_steps=20)
        stats = {k: np.asarray(v) for k, v in sol.stats.items()}
        np.testing.assert_array_equal(stats["n_steps"], stats["n_accepted"])
