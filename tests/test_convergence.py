"""Empirical convergence-order harness for EVERY registered tableau.

For each tableau the harness runs ONE batched fixed-step solve of the
harmonic oscillator (closed-form solution) with a per-instance step-size
sweep -- the batch axis IS the dt sweep, exercising the per-instance step
independence the solver is built around -- and asserts the slope of
log(error) vs log(dt) is within 0.4 of the tableau's nominal order.

Implicit tableaus additionally run through the fused factor-once chord-Newton
path (``fused=True``), which must preserve the discretization order.

Runs in float64 (via the ``jax.experimental.enable_x64`` context, so the
global f32 default of the rest of the suite is untouched): order-5 methods
reach ~1e-11 errors at the small-dt end, far below f32 resolution.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import (
    TABLEAUS,
    DiagonallyImplicitRK,
    FixedController,
    NewtonConfig,
    Status,
    solve_ivp,
)

IMPLICIT = sorted(n for n in TABLEAUS if TABLEAUS[n].implicit)


def oscillator(t, y, args):
    """y'' = -y as a system; exact solution (cos t, -sin t) from (1, 0)."""
    return jnp.stack((y[..., 1], -y[..., 0]), axis=-1)


T_END = 2.0 * np.pi  # one full period: the exact endpoint state is (1, 0)


def measured_order(name: str, fused: bool = False) -> tuple[float, np.ndarray]:
    tab = TABLEAUS[name]
    # The dt sweep must sit inside the method's asymptotic regime: large
    # enough that the leading error term dominates f64 roundoff, small enough
    # that higher-order terms don't steepen the slope (tuned empirically; the
    # 5th-order pairs superconverge above dt ~ 0.3 on smooth problems).
    base = 0.25 if tab.order >= 4 else 0.2
    dts = base * 2.0 ** (-np.arange(4))
    b = len(dts)
    y0 = jnp.tile(jnp.asarray([[1.0, 0.0]], jnp.float64), (b, 1))
    if tab.implicit:
        # Tight Newton tolerance so the inner solve never floors the
        # discretization error the harness is measuring.
        method = DiagonallyImplicitRK(name, newton=NewtonConfig(tol=1e-3, max_iters=20))
    else:
        method = name
    sol = solve_ivp(
        oscillator, y0, None, t_start=0.0, t_end=T_END, method=method,
        controller=FixedController(), dt0=jnp.asarray(dts),
        atol=1e-13, rtol=1e-13, max_steps=2000, fused=fused,
    )
    assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
    if fused:  # the fast path must actually engage, not silently fall back
        assert np.all(np.asarray(sol.stats["n_fused_steps"])
                      == np.asarray(sol.stats["n_steps"]))
    err = np.abs(np.asarray(sol.ys) - np.array([1.0, 0.0])).max(axis=1)
    slope = np.polyfit(np.log(dts), np.log(np.maximum(err, 1e-16)), 1)[0]
    return float(slope), err


@pytest.mark.parametrize("name", sorted(TABLEAUS))
def test_empirical_order_matches_nominal(name):
    with enable_x64():
        order, err = measured_order(name)
    nominal = TABLEAUS[name].order
    assert abs(order - nominal) <= 0.4, (
        f"{name}: measured order {order:.2f} vs nominal {nominal} (errors {err})"
    )


@pytest.mark.parametrize("name", sorted(TABLEAUS))
def test_errors_decrease_monotonically(name):
    """Halving dt must never increase the error anywhere in the sweep."""
    with enable_x64():
        _, err = measured_order(name)
    assert np.all(np.diff(err) < 0), f"{name}: errors not monotone: {err}"


@pytest.mark.parametrize("name", IMPLICIT)
def test_fused_implicit_order_matches_nominal(name):
    """The factor-once fused DIRK path preserves the discretization order on
    every implicit tableau (and engages on every step)."""
    with enable_x64():
        order, err = measured_order(name, fused=True)
    nominal = TABLEAUS[name].order
    assert abs(order - nominal) <= 0.4, (
        f"{name} (fused): measured order {order:.2f} vs nominal {nominal} "
        f"(errors {err})"
    )
