"""Golden tests against scipy.integrate.solve_ivp at matched tolerances:
terminal event times and dense output on the bouncing ball and a
threshold-crossing exponential, plus the analytic values both solvers chase.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Event, Status, solve_ivp

scipy_integrate = pytest.importorskip("scipy.integrate")

G = 9.81
RTOL, ATOL = 1e-6, 1e-9


def ball(t, y, args):
    return jnp.stack((y[..., 1], jnp.full_like(y[..., 1], -G)), axis=-1)


def ball_np(t, y):
    return [y[1], -G]


def exp_growth(t, y, a):
    return a * y


class TestBouncingBallGolden:
    H0 = np.array([10.0, 5.0, 20.0])
    V0 = np.array([0.0, 2.0, -1.0])

    def _ours(self):
        y0 = jnp.asarray(np.stack([self.H0, self.V0], 1), jnp.float32)
        ev = Event(lambda t, y, args: y[0], terminal=True, direction=-1.0)
        return solve_ivp(ball, y0, None, t_start=0.0, t_end=5.0, events=ev,
                         rtol=RTOL, atol=ATOL)

    def _scipy_hit(self, h0, v0):
        ground = lambda t, y: y[0]
        ground.terminal = True
        ground.direction = -1.0
        res = scipy_integrate.solve_ivp(ball_np, (0.0, 5.0), [h0, v0],
                                        events=ground, rtol=RTOL, atol=ATOL)
        return res.t_events[0][0]

    def test_terminal_times_match_scipy_and_analytic(self):
        sol = self._ours()
        t_ev = np.asarray(sol.event_t)[:, 0]
        analytic = (self.V0 + np.sqrt(self.V0**2 + 2.0 * G * self.H0)) / G
        scipy_t = np.array([self._scipy_hit(h, v) for h, v in zip(self.H0, self.V0)])
        # acceptance bar: within 10*rtol of the analytic value, per instance
        np.testing.assert_allclose(t_ev, analytic, rtol=10 * RTOL)
        np.testing.assert_allclose(t_ev, scipy_t, rtol=10 * RTOL)
        assert np.all(np.asarray(sol.status) == Status.EVENT.value)

    def test_dense_output_matches_scipy(self):
        t_eval = np.linspace(0.0, 1.2, 25)  # before every instance's impact
        y0 = jnp.asarray(np.stack([self.H0, self.V0], 1), jnp.float32)
        ours = solve_ivp(ball, y0, jnp.asarray(t_eval, jnp.float32),
                         rtol=RTOL, atol=ATOL)
        for i, (h0, v0) in enumerate(zip(self.H0, self.V0)):
            res = scipy_integrate.solve_ivp(ball_np, (0.0, 1.2), [h0, v0],
                                            t_eval=t_eval, dense_output=True,
                                            rtol=RTOL, atol=ATOL)
            np.testing.assert_allclose(np.asarray(ours.ys)[i], res.y.T,
                                       rtol=1e-4, atol=1e-4)


class TestThresholdExponentialGolden:
    A = 0.9
    Y0 = np.array([0.5, 1.0, 2.0])
    THRESHOLD = 6.0

    def _event(self):
        return Event(lambda t, y, args: y[0] - self.THRESHOLD,
                     terminal=True, direction=1.0)

    def test_terminal_times_match_scipy_and_analytic(self):
        y0 = jnp.asarray(self.Y0[:, None], jnp.float32)
        sol = solve_ivp(exp_growth, y0, None, t_start=0.0, t_end=6.0,
                        events=self._event(), args=self.A, rtol=RTOL, atol=ATOL)
        t_ev = np.asarray(sol.event_t)[:, 0]
        analytic = np.log(self.THRESHOLD / self.Y0) / self.A

        cross = lambda t, y: y[0] - self.THRESHOLD
        cross.terminal = True
        cross.direction = 1.0
        scipy_t = []
        for v in self.Y0:
            res = scipy_integrate.solve_ivp(lambda t, y: [self.A * y[0]],
                                            (0.0, 6.0), [v], events=cross,
                                            rtol=RTOL, atol=ATOL)
            scipy_t.append(res.t_events[0][0])
        np.testing.assert_allclose(t_ev, analytic, rtol=10 * RTOL)
        np.testing.assert_allclose(t_ev, np.asarray(scipy_t), rtol=10 * RTOL)
        # the recorded event state sits on the threshold
        np.testing.assert_allclose(np.asarray(sol.event_y)[:, 0, 0],
                                   self.THRESHOLD, rtol=1e-5)

    def test_non_terminal_matches_analytic_with_full_horizon(self):
        y0 = jnp.asarray(self.Y0[:, None], jnp.float32)
        ev = Event(lambda t, y, args: y[0] - self.THRESHOLD, terminal=False,
                   direction=1.0)
        sol = solve_ivp(exp_growth, y0, None, t_start=0.0, t_end=6.0,
                        events=ev, args=self.A, rtol=RTOL, atol=ATOL)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)
        np.testing.assert_allclose(np.asarray(sol.event_t)[:, 0],
                                   np.log(self.THRESHOLD / self.Y0) / self.A,
                                   rtol=10 * RTOL)
        # final states ran through to t_end regardless of the marker event
        np.testing.assert_allclose(np.asarray(sol.ys)[:, 0],
                                   self.Y0 * np.exp(self.A * 6.0), rtol=1e-4)
