"""The static/dynamic split and the zero-retrace compiled front end.

Covers: component hashability/frozenness (the static half of the contract),
trace counting through ``CompiledSolver`` (exactly one trace for repeated
same-shape solves; retrace on shape/dtype/static-config change), buffer
donation, bitwise agreement with the uncompiled drivers, ``sharded_solve``
consistency, the ``make_solver`` max_steps warning and the kernel-backend
error path.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AutoDiffAdjoint,
    BacksolveAdjoint,
    CompiledSolver,
    DiagonallyImplicitRK,
    Event,
    ExplicitRK,
    FixedController,
    NewtonConfig,
    ODETerm,
    ScanAdjoint,
    Status,
    StepFunction,
    Stepper,
    get_tableau,
    make_solver,
    pid_controller,
    sharded_solve,
)


def decay(t, y, args):
    return -y if args is None else -y * args


class TraceCounter:
    """A vector field that counts how many times it is *traced* (any call
    during tracing increments; a cached/compiled dispatch calls it zero
    times, so a stable count across solves proves zero retraces)."""

    def __init__(self):
        self.calls = 0

    def __call__(self, t, y, args):
        self.calls += 1
        return -y * args


# ---------------------------------------------------------------------------
# Static config: hashability, value equality, frozenness, pytree round-trips.


class TestStaticConfig:
    def test_components_hash_by_value(self):
        assert ExplicitRK("tsit5") == ExplicitRK("tsit5")
        assert hash(ExplicitRK("tsit5")) == hash(ExplicitRK("tsit5"))
        assert ExplicitRK("tsit5") != ExplicitRK("dopri5")
        assert DiagonallyImplicitRK("kvaerno3") == DiagonallyImplicitRK("kvaerno3")
        assert DiagonallyImplicitRK(
            "kvaerno3", newton=NewtonConfig(tol=1e-5)
        ) != DiagonallyImplicitRK("kvaerno3")
        assert get_tableau("dopri5") == get_tableau("dopri5")
        assert hash(get_tableau("dopri5")) != hash(get_tableau("tsit5"))
        assert pid_controller() == pid_controller()
        assert FixedController() == FixedController()
        assert hash(ODETerm(decay)) == hash(ODETerm(decay))
        assert hash(Event(decay)) == hash(Event(decay))

    def test_components_frozen(self):
        for obj in (ExplicitRK("tsit5"), DiagonallyImplicitRK("kvaerno3"),
                    AutoDiffAdjoint(Stepper("dopri5")),
                    StepFunction(decay), CompiledSolver()):
            with pytest.raises(AttributeError):
                obj.anything = 1
        tab = get_tableau("dopri5")
        with pytest.raises(ValueError):
            tab.a[0, 0] = 99.0  # coefficient arrays are read-only

    def test_driver_is_pytree_with_tolerance_leaves(self):
        drv = AutoDiffAdjoint(Stepper("tsit5"), pid_controller(),
                              rtol=jnp.full((4,), 1e-5), atol=1e-8)
        leaves, treedef = jax.tree_util.tree_flatten(drv)
        assert len(leaves) == 2  # rtol, atol -- everything else is static aux
        hash(treedef)  # aux data must be hashable
        # value-equal configs produce equal treedefs (same compiled program)
        other = jax.tree_util.tree_flatten(
            AutoDiffAdjoint(Stepper("tsit5"), pid_controller(),
                            rtol=jnp.ones((4,)), atol=0.1)
        )[1]
        assert treedef == other
        # round-trip reconstructs a working driver
        drv2 = jax.tree_util.tree_unflatten(treedef, leaves)
        sol = drv2.solve(decay, jnp.ones((4, 2)), jnp.linspace(0, 1, 5), args=1.0)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)

    def test_driver_as_jit_argument(self):
        """A driver crosses jax.jit as an ordinary argument: tolerances are
        dynamic (no retrace), static config keys the cache."""
        t_eval = jnp.linspace(0.0, 1.0, 5)

        @jax.jit
        def run(drv, y0):
            return drv.solve(decay, y0, t_eval, args=1.0).ys

        y0 = jnp.ones((4, 2))
        a = run(AutoDiffAdjoint(Stepper("tsit5"), rtol=1e-3), y0)
        b = run(AutoDiffAdjoint(Stepper("tsit5"), rtol=1e-7), y0)
        assert a.shape == b.shape == (4, 5, 2)

    def test_backsolve_adjoint_final_state_only(self):
        """BacksolveAdjoint compiles since the gradient-serving PR (its
        custom-VJP solve wraps into a synthesized final-state Solution), but
        it tracks only the final state: eval grids / dt0 must be refused
        with a real message, not crash in the stepper-coercion path."""
        solver = CompiledSolver(BacksolveAdjoint(Stepper("dopri5"),
                                                 rtol=1e-7, atol=1e-9),
                                donate=False)
        y0 = jnp.ones((2, 3))
        with pytest.raises(TypeError, match="final state"):
            solver.solve(decay, y0, jnp.linspace(0.0, 1.0, 4), args=1.0)
        with pytest.raises(TypeError, match="final state"):
            solver.solve(decay, y0, None, t_start=0.0, t_end=1.0, args=1.0,
                         dt0=0.01)
        sol = solver.solve(decay, y0, None, t_start=0.0, t_end=1.0, args=1.0)
        np.testing.assert_allclose(np.asarray(sol.ys),
                                   np.exp(-1.0) * np.ones((2, 3)), atol=1e-5)
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)

    def test_stepfunction_pytree_roundtrip(self):
        sf = StepFunction(decay, "dopri5", events=Event(lambda t, y, a: y[0] - 0.5))
        leaves, treedef = jax.tree_util.tree_flatten(sf)
        sf2 = jax.tree_util.tree_unflatten(treedef, leaves)
        state, consts = sf2.init(jnp.ones((3, 2)), jnp.linspace(0, 1, 4))
        state = sf2.step(state, consts, 1.0)
        assert state.it == 1
        # the rebuilt statistics registry still points at the new instance
        assert sf2 in sf2.stat_contributors


# ---------------------------------------------------------------------------
# Trace counting: the zero-retrace contract.


class TestZeroRetrace:
    def test_exactly_one_trace_for_repeated_same_shape_solves(self):
        vf = TraceCounter()
        solver = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5")), donate=False)
        t_eval = jnp.linspace(0.0, 1.0, 6)
        sols = [solver.solve(vf, jnp.full((8, 3), 1.0), t_eval, args=1.0)]
        after_first = vf.calls
        assert after_first > 0
        for i in range(5):
            sols.append(
                solver.solve(vf, jnp.full((8, 3), 0.5 + i), t_eval, args=0.5 + i)
            )
        assert vf.calls == after_first, "same-shape solve retraced the program"
        assert solver.cache_info().misses == 1
        assert solver.cache_info().hits == 5
        # and the numbers are real
        np.testing.assert_allclose(
            np.asarray(sols[1].ys[:, -1]), np.exp(-0.5) * 0.5, rtol=1e-4
        )

    def test_retrace_on_shape_dtype_and_static_change(self):
        vf = TraceCounter()
        solver = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5")), donate=False)
        t_eval = jnp.linspace(0.0, 1.0, 6)
        args = jnp.asarray(1.0)
        solver.solve(vf, jnp.ones((8, 3)), t_eval, args=args)
        base = vf.calls

        # batch-shape change -> new program
        solver.solve(vf, jnp.ones((4, 3)), t_eval, args=args)
        after_shape = vf.calls
        assert after_shape > base
        # dtype change of a dynamic arg -> new program
        solver.solve(vf, jnp.ones((4, 3)), t_eval, args=jnp.asarray(1, jnp.int32))
        after_dtype = vf.calls
        assert after_dtype > after_shape
        # t_eval length change -> new program
        solver.solve(vf, jnp.ones((4, 3)), jnp.linspace(0.0, 1.0, 9), args=args)
        after_teval = vf.calls
        assert after_teval > after_dtype
        # static-config change (different tableau) -> new program
        CompiledSolver(AutoDiffAdjoint(Stepper("tsit5")), donate=False).solve(
            vf, jnp.ones((4, 3)), t_eval, args=args
        )
        assert vf.calls > after_teval
        # ...but returning to an already-seen point stays cached
        final = vf.calls
        solver.solve(vf, jnp.ones((8, 3)), t_eval, args=args)
        solver.solve(vf, jnp.ones((4, 3)), t_eval, args=args)
        assert vf.calls == final

    def test_tolerances_are_dynamic(self):
        """Per-call rtol/atol overrides reuse the same executable."""
        vf = TraceCounter()
        solver = CompiledSolver(
            AutoDiffAdjoint(Stepper("dopri5"), rtol=jnp.asarray(1e-3),
                            atol=jnp.asarray(1e-6)),
            donate=False,
        )
        t_eval = jnp.linspace(0.0, 1.0, 6)
        loose = solver.solve(vf, jnp.ones((4, 2)), t_eval, args=1.0)
        base = vf.calls
        tight = solver.solve(vf, jnp.ones((4, 2)), t_eval, args=1.0,
                             rtol=jnp.asarray(1e-9), atol=jnp.asarray(1e-12))
        assert vf.calls == base, "tolerance change must not retrace"
        assert np.all(np.asarray(tight.stats["n_steps"])
                      >= np.asarray(loose.stats["n_steps"]))

    def test_aot_compile_handle(self):
        """compile() builds the executable ahead of the first request; solve
        with matching shapes dispatches to it without tracing again."""
        vf = TraceCounter()
        solver = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5")), donate=False)
        spec = jax.ShapeDtypeStruct((8, 3), jnp.float32)
        sspec = jax.ShapeDtypeStruct((), jnp.float32)
        handle = solver.compile(vf, spec, None, t_start=sspec, t_end=sspec, args=sspec)
        traced = vf.calls
        assert traced > 0
        # strong-f32 scalars: they must key identically to the compile() specs
        t0, t1, a = (jnp.zeros((), jnp.float32), jnp.ones((), jnp.float32),
                     jnp.ones((), jnp.float32))
        out = handle(jnp.ones((8, 3)), None, t_start=t0, t_end=t1, args=a)
        assert out.ys.shape == (8, 3)
        sol = solver.solve(vf, jnp.ones((8, 3)), None, t_start=t0, t_end=t1, args=a)
        assert vf.calls == traced, "AOT-compiled point must not trace again"
        np.testing.assert_array_equal(np.asarray(out.ys), np.asarray(sol.ys))


class TestDonation:
    def test_final_state_solve_donates_y0(self):
        """donate='auto' consumes the y0 buffer in the final-state regime:
        the input is aliased into an output (visible in the HLO) and the
        caller's array is actually deleted -- fewer live buffers, and reuse
        raises instead of silently reading freed memory."""
        solver = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5")))
        handle = solver.compile(
            decay,
            jax.ShapeDtypeStruct((8, 3), jnp.float32),
            None,
            t_start=jax.ShapeDtypeStruct((), jnp.float32),
            t_end=jax.ShapeDtypeStruct((), jnp.float32),
            args=jax.ShapeDtypeStruct((), jnp.float32),
        )
        assert "input_output_alias" in handle.as_text()

        y0 = jnp.ones((8, 3))
        sol = solver.solve(decay, y0, None, t_start=jnp.asarray(0.0),
                           t_end=jnp.asarray(1.0), args=jnp.asarray(1.0))
        jax.block_until_ready(sol.ys)
        assert y0.is_deleted(), "y0 was not donated"
        with pytest.raises(Exception):
            np.asarray(y0 + 1.0)

    def test_dense_solve_does_not_donate_and_does_not_warn(self):
        """With t_eval no output matches y0's shape, so 'auto' keeps the
        buffer alive (and XLA's 'donated buffers were not usable' warning
        never fires)."""
        solver = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5")))
        y0 = jnp.ones((8, 3))
        with warnings.catch_warnings():
            warnings.filterwarnings("error", message=".*donated buffers.*")
            sol = solver.solve(decay, y0, jnp.linspace(0.0, 1.0, 5), args=1.0)
            jax.block_until_ready(sol.ys)
        assert not y0.is_deleted()
        np.asarray(y0 + 1.0)  # still usable

    def test_new_shape_tol_override_after_aot_compile(self):
        """A per-instance tolerance override on an AOT-compiled point cannot
        go through the strict-aval executable; it must fall back to jit and
        compile the variant, not raise."""
        solver = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5"),
                                                rtol=jnp.asarray(1e-3),
                                                atol=jnp.asarray(1e-6)),
                                donate=False)
        spec = jax.ShapeDtypeStruct((4, 2), jnp.float32)
        sspec = jax.ShapeDtypeStruct((), jnp.float32)
        solver.compile(decay, spec, None, t_start=sspec, t_end=sspec, args=sspec)
        t0, t1, a = (jnp.zeros((), jnp.float32), jnp.ones((), jnp.float32),
                     jnp.ones((), jnp.float32))
        sol = solver.solve(decay, jnp.ones((4, 2)), None, t_start=t0, t_end=t1,
                           args=a, rtol=jnp.full((4,), 1e-7))
        assert np.all(np.asarray(sol.status) == Status.SUCCESS.value)

    def test_donate_false_keeps_buffers(self):
        solver = CompiledSolver(AutoDiffAdjoint(Stepper("dopri5")), donate=False)
        y0 = jnp.ones((8, 3))
        solver.solve(decay, y0, None, t_start=0.0, t_end=1.0, args=1.0)
        assert not y0.is_deleted()


# ---------------------------------------------------------------------------
# Numerical identity with the uncompiled path.


def _mixed_configs():
    ground = Event(lambda t, y, args: y[0] - 0.2, terminal=True, direction=-1.0)
    return [
        ("dopri5-explicit", AutoDiffAdjoint(Stepper("dopri5")), None),
        ("tsit5-mixed-tol", AutoDiffAdjoint(
            Stepper("tsit5"), rtol=jnp.full((6,), 1e-3).at[::2].set(1e-7)), None),
        ("kvaerno3-implicit", AutoDiffAdjoint(DiagonallyImplicitRK("kvaerno3")), None),
        ("dopri5-events", AutoDiffAdjoint(Stepper("dopri5"), events=ground), None),
        ("kvaerno3-events", AutoDiffAdjoint(
            DiagonallyImplicitRK("kvaerno3"), events=ground), None),
    ]


class TestCompiledMatchesUncompiled:
    """``CompiledSolver`` must be the *same program*, not a numerical cousin.

    The reference is the jit of the uncompiled ``AutoDiffAdjoint.solve`` --
    identical jaxpr, so results must be bitwise identical.  (Fully eager
    op-by-op execution is NOT a bitwise reference on any backend: XLA fuses
    and reassociates differently when the whole program compiles as one unit,
    which shifts f32 roundings at the 1e-7 level; eager agreement is asserted
    to tolerance instead.)
    """

    @pytest.mark.parametrize("name,driver,_", _mixed_configs())
    def test_bitwise_vs_jit_and_close_vs_eager(self, name, driver, _):
        vf = ODETerm(decay)
        t_eval = jnp.linspace(0.0, 1.2, 7)
        y0 = jnp.linspace(0.3, 1.5, 12).reshape(6, 2)
        args = jnp.asarray(1.7)

        compiled = CompiledSolver(driver, donate=False)
        got = compiled.solve(vf, y0, t_eval, args=args)

        ref_fn = jax.jit(lambda y, a: driver.solve(vf, y, t_eval, args=a))
        ref = ref_fn(y0, args)
        np.testing.assert_array_equal(np.asarray(got.ys), np.asarray(ref.ys))
        np.testing.assert_array_equal(np.asarray(got.status), np.asarray(ref.status))
        for k in ref.stats:
            np.testing.assert_array_equal(
                np.asarray(got.stats[k]), np.asarray(ref.stats[k]), err_msg=k
            )
        if ref.event_t is not None:
            np.testing.assert_array_equal(
                np.asarray(got.event_t), np.asarray(ref.event_t)
            )

        # Eager sanity check only: op-by-op XLA rounds differently, which can
        # flip accept/reject decisions sitting on the error-ratio boundary, so
        # trajectories agree to solver-tolerance scale, not machine eps.
        eager = driver.solve(vf, y0, t_eval, args=args)
        np.testing.assert_allclose(
            np.asarray(got.ys), np.asarray(eager.ys), rtol=5e-3, atol=1e-5
        )

    def test_vmap_over_parameters(self):
        """The solve program is vmap-compatible: mapping over a dynamics
        parameter batches the whole adaptive loop one level up."""
        driver = AutoDiffAdjoint(Stepper("dopri5"), rtol=1e-7, atol=1e-9)
        t_eval = jnp.linspace(0.0, 1.0, 5)
        y0 = jnp.ones((4, 2))
        rates = jnp.linspace(0.5, 2.0, 3)
        ys = jax.jit(jax.vmap(lambda a: driver.solve(decay, y0, t_eval, args=a).ys))(
            rates
        )
        assert ys.shape == (3, 4, 5, 2)
        for i in range(3):
            direct = driver.solve(decay, y0, t_eval, args=rates[i])
            np.testing.assert_allclose(
                np.asarray(ys[i]), np.asarray(direct.ys), rtol=1e-5, atol=1e-7
            )

    def test_scan_driver_through_compiled(self):
        driver = ScanAdjoint(Stepper("bosh3"), max_steps=64)
        compiled = CompiledSolver(driver, donate=False)
        t_eval = jnp.linspace(0.0, 1.0, 5)
        y0 = jnp.ones((4, 2))
        got = compiled.solve(decay, y0, t_eval, args=1.0)
        ref = jax.jit(lambda y: driver.solve(decay, y, t_eval, args=1.0))(y0)
        np.testing.assert_array_equal(np.asarray(got.ys), np.asarray(ref.ys))


class TestCompiledPropertyHypothesis:
    """Property form of the bitwise guarantee, randomized over solver config
    x batch shape x tolerance mix (runs when hypothesis is installed)."""

    def test_property(self):
        pytest.importorskip("hypothesis")
        from hypothesis import given, settings, strategies as st

        configs = _mixed_configs()

        @settings(max_examples=8, deadline=None)
        @given(
            idx=st.integers(0, len(configs) - 1),
            batch=st.integers(1, 6),
            feat=st.integers(1, 3),
            seed=st.integers(0, 2**16),
        )
        def check(idx, batch, feat, seed):
            _, driver, _ = configs[idx]
            if getattr(driver, "rtol", None) is not None and hasattr(driver.rtol, "shape") \
                    and getattr(driver.rtol, "ndim", 0) == 1:
                driver = AutoDiffAdjoint(driver.stepper)  # (b,)-tol config needs b=6
            key = jax.random.PRNGKey(seed)
            y0 = 0.2 + jax.random.uniform(key, (batch, feat))
            t_eval = jnp.linspace(0.0, 1.0, 4)
            args = jnp.asarray(1.3)
            got = CompiledSolver(driver, donate=False).solve(decay, y0, t_eval, args=args)
            ref = jax.jit(lambda y, a: driver.solve(decay, y, t_eval, args=a))(y0, args)
            np.testing.assert_array_equal(np.asarray(got.ys), np.asarray(ref.ys))
            np.testing.assert_array_equal(np.asarray(got.status), np.asarray(ref.status))

        check()


# ---------------------------------------------------------------------------
# Multi-device sharding.


class TestShardedSolve:
    """Runs on however many devices exist: 1 in the plain tier-1 suite (the
    shard_map plumbing is still exercised), 4 in the CI smoke leg via
    XLA_FLAGS=--xla_force_host_platform_device_count=4."""

    def _mesh(self):
        from jax.sharding import Mesh

        return Mesh(np.array(jax.devices()), ("data",))

    def test_matches_single_device_exactly_mixed_tolerances(self):
        mesh = self._mesh()
        b = 8 * mesh.shape["data"]
        y0 = jnp.linspace(-1.5, 1.5, 2 * b).reshape(b, 2)
        t_eval = jnp.linspace(0.0, 1.0, 5)
        rtol = jnp.where(jnp.arange(b) % 3 == 0, 1e-7, 1e-3)
        args = jnp.asarray(3.0)

        def vdp(t, y, mu):
            x, xd = y[..., 0], y[..., 1]
            return jnp.stack((xd, mu * (1 - x**2) * xd - x), axis=-1)

        sol = sharded_solve(mesh, vdp, y0, t_eval, rtol=rtol, atol=1e-8, args=args)
        driver = AutoDiffAdjoint(Stepper("dopri5"), rtol=rtol, atol=1e-8)
        ref = jax.jit(lambda y, a: driver.solve(vdp, y, t_eval, args=a))(y0, args)
        np.testing.assert_array_equal(np.asarray(sol.ys), np.asarray(ref.ys))
        np.testing.assert_array_equal(np.asarray(sol.ts), np.asarray(ref.ts))
        np.testing.assert_array_equal(np.asarray(sol.status), np.asarray(ref.status))
        for k in ("n_steps", "n_accepted", "n_initialized"):
            np.testing.assert_array_equal(
                np.asarray(sol.stats[k]), np.asarray(ref.stats[k]), err_msg=k
            )

    def test_implicit_stepper_sharded(self):
        mesh = self._mesh()
        b = 4 * mesh.shape["data"]
        y0 = jnp.ones((b, 3))
        args = jnp.asarray(40.0)
        sol = sharded_solve(mesh, decay, y0, None, t_start=0.0, t_end=0.5,
                            method="kvaerno3", args=args)
        driver = AutoDiffAdjoint(DiagonallyImplicitRK("kvaerno3"))
        ref = jax.jit(
            lambda y, a: driver.solve(decay, y, None, t_start=0.0, t_end=0.5, args=a)
        )(y0, args)
        # The implicit stepper's batched linear algebra compiles to batch-size
        # dependent fusions, so cross-shard agreement is to tolerance (the
        # explicit path above is held to bitwise equality).
        np.testing.assert_allclose(np.asarray(sol.ys), np.asarray(ref.ys),
                                   rtol=1e-3, atol=1e-12)
        np.testing.assert_array_equal(np.asarray(sol.status), np.asarray(ref.status))

    def test_solver_kwarg_conflict_raises(self):
        """Options next to an explicit solver= would be silently ignored --
        refuse them instead."""
        mesh = self._mesh()
        drv = AutoDiffAdjoint(Stepper("dopri5"))
        with pytest.raises(TypeError, match="to the driver given"):
            sharded_solve(mesh, decay, jnp.ones((4, 2)), None, t_start=0.0,
                          t_end=1.0, solver=drv, rtol=1e-9)

    def test_ragged_batch_pads_per_shard(self):
        """Regression: batches that do not divide the mesh used to raise --
        now they pad (replicating instance 0, the serving layer's trick) and
        the sliced-back results match the unsharded solve exactly."""
        mesh = self._mesh()
        n_dev = mesh.shape["data"]
        for b in sorted({1, n_dev + 1, 2 * n_dev - 1, 3 * n_dev + 2}):
            y0 = jnp.linspace(-1.0, 1.0, 2 * b).reshape(b, 2)
            rtol = jnp.where(jnp.arange(b) % 2 == 0, 1e-6, 1e-3)
            sol = sharded_solve(mesh, decay, y0, None, t_start=0.0,
                                t_end=1.0, rtol=rtol, args=1.0)
            driver = AutoDiffAdjoint(Stepper("dopri5"), rtol=rtol)
            ref = jax.jit(
                lambda y, a: driver.solve(decay, y, None, t_start=0.0,
                                          t_end=1.0, args=a)
            )(y0, jnp.asarray(1.0))
            assert sol.ys.shape == (b, 2), "padding must be sliced off"
            np.testing.assert_array_equal(np.asarray(sol.ys),
                                          np.asarray(ref.ys))
            np.testing.assert_array_equal(np.asarray(sol.status),
                                          np.asarray(ref.status))
            np.testing.assert_array_equal(np.asarray(sol.stats["n_steps"]),
                                          np.asarray(ref.stats["n_steps"]))

    def test_ragged_batch_dense_output(self):
        mesh = self._mesh()
        b = mesh.shape["data"] + 1
        y0 = jnp.linspace(0.5, 1.5, 3 * b).reshape(b, 3)
        t_eval = jnp.linspace(0.0, 1.0, 4)
        sol = sharded_solve(mesh, decay, y0, t_eval, args=1.0)
        driver = AutoDiffAdjoint(Stepper("dopri5"))
        ref = jax.jit(
            lambda y, a: driver.solve(decay, y, t_eval, args=a)
        )(y0, jnp.asarray(1.0))
        assert sol.ys.shape == (b, 4, 3)
        np.testing.assert_array_equal(np.asarray(sol.ys), np.asarray(ref.ys))
        np.testing.assert_array_equal(np.asarray(sol.ts), np.asarray(ref.ts))


# ---------------------------------------------------------------------------
# Satellites: make_solver max_steps warning, backend error path.


class TestMakeSolverMaxSteps:
    def test_non_default_max_steps_warns(self):
        with pytest.warns(UserWarning, match="iteration bound belongs to the caller"):
            fns = make_solver(decay, max_steps=500)
        assert len(fns) == 3  # still returns the triple

    def test_default_is_silent(self):
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            init_fn, step_fn, finish_fn = make_solver(decay)
        state, consts = init_fn(jnp.ones((3, 2)), jnp.linspace(0, 1, 4))
        state = step_fn(state, consts, 1.0)
        sol = finish_fn(state, consts)
        assert sol.ys.shape == (3, 4, 2)


class TestBackendErrors:
    def test_set_backend_unknown_raises_valueerror(self):
        from repro.kernels import ops

        old = ops.backend()
        try:
            with pytest.raises(ValueError, match="unknown kernel backend"):
                ops.set_backend("cuda")
            assert ops.backend() == old  # a rejected name must not stick
        finally:
            ops.set_backend(old)

    def test_interpret_mode_switch_roundtrip(self):
        from repro.kernels import ops

        old = ops.backend()
        try:
            ops.set_backend("interpret")
            assert ops.backend() == "interpret"
            y = jnp.ones((2, 3))
            K = jnp.ones((2, 2, 3))
            out = ops.stage_accum(y, jnp.full((2,), 0.1), K, np.array([0.5, 0.5]))
            assert out.shape == (2, 3)
        finally:
            ops.set_backend(old)
        assert ops.backend() == old
