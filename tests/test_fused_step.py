"""Fused step megakernel: interpret-mode parity vs the ref oracle, the
bitwise fused-vs-unfused solve contract, the running-mask freeze, and the
``reset_backend`` regression.  Deliberately hypothesis-free so this file runs
even where ``test_kernels.py``'s property tests are skipped."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Event,
    FixedController,
    FusedFallbackReason,
    PIDController,
    PolynomialTerm,
    pid_controller,
    polynomial_term,
    solve_ivp,
)
from repro.core.stepper import _tableau_arrays
from repro.core.tableau import TABLEAUS
from repro.kernels import ops, pallas_impl as pi, ref

EXPLICIT = [n for n, tab in TABLEAUS.items() if not tab.implicit]
EXPLICIT_FSAL = [
    n for n in EXPLICIT if TABLEAUS[n].fsal and TABLEAUS[n].b_err is not None
]
CTRL = pid_controller()


class TestResetBackend:
    def test_reset_backend_rereads_env(self, monkeypatch):
        # Regression: backend() used to latch its choice on the FIRST dispatch
        # forever -- REPRO_KERNEL_BACKEND set afterwards was silently ignored.
        # reset_backend() must drop the latch and re-read the environment.
        old = ops.backend()
        target = "interpret" if old != "interpret" else "ref"
        try:
            monkeypatch.setenv("REPRO_KERNEL_BACKEND", target)
            assert ops.backend() == old  # still latched: env change invisible
            ops.reset_backend()
            assert ops.backend() == target  # re-read after reset
        finally:
            ops.set_backend(old)


def _fused_inputs(seed, b, f, s, dtype=np.float32):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.uniform(0.5, 1.5, (b, f)), dtype)
    K = jnp.asarray(rng.standard_normal((s, b, f)), dtype)
    t = jnp.asarray(rng.uniform(0.0, 1.0, b), dtype)
    dt_cur = jnp.asarray(rng.uniform(0.05, 0.2, b), dtype)
    safe_dt = dt_cur * 0.9
    t_new = t + safe_dt
    running = jnp.asarray(rng.uniform(size=b) > 0.25)
    prev_inv = jnp.asarray(rng.uniform(0.5, 2.0, b), dtype)
    prev2_inv = jnp.asarray(rng.uniform(0.5, 2.0, b), dtype)
    return y, K, t, t_new, dt_cur, safe_dt, running, prev_inv, prev2_inv


class TestFusedStepOp:
    """Interpret-mode megakernel vs the ref oracle, every explicit tableau,
    both controller modes, and shapes on both sides of the feature-tile
    boundary (f > 128 engages the two-pass tiled schedule)."""

    def _check(self, name, b, f, ctrl_mode="pid", rtol=3e-5):
        tab = TABLEAUS[name]
        s = tab.stages
        (y, K, t, t_new, dt_cur, safe_dt,
         running, prev_inv, prev2_inv) = _fused_inputs(sum(name.encode()) + f, b, f, s)
        _, _, b_sol, b_err = _tableau_arrays(tab, np.float32)
        ctrl = CTRL.filter_params(tab.error_order) if ctrl_mode == "pid" else ()
        kw = dict(b_sol=tuple(b_sol.tolist()), b_err=tuple(b_err.tolist()),
                  ctrl=ctrl, want_coeffs=True, ctrl_mode=ctrl_mode)
        # Pick atol so the RUNNING rows' error ratios straddle 1 (mixed
        # accept/reject): scale is atol-dominated here, so ratio ~ 1/atol, and
        # rescaling by the running-row median pins the middle row's ratio near
        # 1 — the min/max running rows then land on opposite sides of the
        # accept boundary no matter where the middle one falls.
        probe = np.asarray(ref.fused_step(
            y, K, K[-1], t, t_new, dt_cur, safe_dt, running,
            prev_inv, prev2_inv, 0.05, 1e-3, **kw)[1])
        live = probe[np.asarray(running)]
        atol = float(0.05 * np.median(live)) if live.any() else 0.05
        r = ref.fused_step(y, K, K[-1], t, t_new, dt_cur, safe_dt, running,
                           prev_inv, prev2_inv, atol, 1e-3, **kw)
        p = pi.fused_step(y, K, K[-1], t, t_new, dt_cur, safe_dt, running,
                          prev_inv, prev2_inv, atol, 1e-3, interpret=True, **kw)
        if ctrl_mode == "pid" and tab.b_err is not None:
            accept = np.asarray(r[2])[np.asarray(running)]
            assert accept.any() and (~accept).any(), "want a mixed batch"
        if ctrl_mode == "fixed":
            np.testing.assert_array_equal(np.asarray(r[2]), np.asarray(running))
        for rr, pp in zip(r[:9], p[:9]):
            np.testing.assert_allclose(np.asarray(rr), np.asarray(pp),
                                       rtol=rtol, atol=1e-5)
        for rc, pc in zip(r[9], p[9]):
            np.testing.assert_allclose(np.asarray(rc), np.asarray(pc),
                                       rtol=rtol, atol=1e-5)

    @pytest.mark.parametrize("name", EXPLICIT)
    def test_matches_ref(self, name):
        self._check(name, 9, 37)

    @pytest.mark.parametrize("name", ["dopri5", "heun"])
    @pytest.mark.parametrize("b,f", [(5, 200), (4, 300)])
    def test_tiled_matches_ref(self, name, b, f):
        # The two-pass WRMS reduction must be indistinguishable from the
        # single-pass schedule's math (partial sums are exact in this regime).
        self._check(name, b, f, rtol=1e-4)

    @pytest.mark.parametrize("b,f", [(9, 37), (5, 200)])
    def test_fixed_mode_matches_ref(self, b, f):
        # ctrl_mode="fixed": accept == running, dt passthrough, both schedules.
        self._check("rk4", b, f, ctrl_mode="fixed")

    @pytest.mark.parametrize("name", [n for n in EXPLICIT
                                      if TABLEAUS[n].b_err is not None])
    @pytest.mark.parametrize("b,f", [(6, 19), (4, 200)])
    def test_poly_matches_ref(self, name, b, f):
        # Covers FSAL (trailing stage reused) and non-FSAL (in-kernel trailing
        # vf evaluation) tableaus, untiled and feature-tiled shapes.
        tab = TABLEAUS[name]
        (y, _, t, t_new, dt_cur, safe_dt,
         running, prev_inv, prev2_inv) = _fused_inputs(3 + f, b, f, tab.stages)
        # Moderate dt keeps the error estimate well above float32 cancellation
        # noise (a tiny estimate is the difference of O(1) stage slopes).
        dt_cur = dt_cur * 4.0
        safe_dt = dt_cur * 0.9
        t_new = t + safe_dt
        poly = (0.0, 1.0, -1.0)  # logistic: dy/dt = y - y^2
        f0 = ref.poly_eval(y, poly)
        a, c, b_sol, b_err = _tableau_arrays(tab, np.float32)
        kw = dict(a=tuple(map(tuple, a.tolist())), c=tuple(c.tolist()),
                  b_sol=tuple(b_sol.tolist()), b_err=tuple(b_err.tolist()),
                  poly=poly, ctrl=CTRL.filter_params(tab.error_order),
                  want_coeffs=True, fsal=tab.fsal)
        r = ref.fused_step_poly(y, f0, t, t_new, dt_cur, safe_dt, running,
                                prev_inv, prev2_inv, 1e-4, 1e-3, **kw)
        p = pi.fused_step_poly(y, f0, t, t_new, dt_cur, safe_dt, running,
                               prev_inv, prev2_inv, 1e-4, 1e-3,
                               interpret=True, **kw)
        # State outputs are tight; the error estimate b_err@K is a CANCELLING
        # combination of O(1) stage slopes, so the controller outputs derived
        # from it (err_ratio, dt_out, new_inv*) carry percent-level float32
        # summation-order noise for high-order tableaus -- gate them loosely.
        tight, loose = (0,), (1, 6, 7, 8)
        for i in tight:
            np.testing.assert_allclose(np.asarray(r[i]), np.asarray(p[i]),
                                       rtol=2e-4, atol=1e-5)
        for i in loose:
            np.testing.assert_allclose(np.asarray(r[i]), np.asarray(p[i]),
                                       rtol=3e-2, atol=1e-5)
        # Accept decisions must agree wherever the error ratio is clear of the
        # knife edge at 1 (the percent-level ratio noise above can flip the
        # decision only there); the committed outputs are compared on the
        # agreeing instances.
        ratio, accept_r, accept_p = (np.asarray(r[1]), np.asarray(r[2]),
                                     np.asarray(p[2]))
        clear = np.abs(ratio - 1.0) > 0.05
        np.testing.assert_array_equal(accept_r[clear], accept_p[clear])
        agree = accept_r == accept_p
        for i in (3, 4, 5):
            np.testing.assert_allclose(np.asarray(r[i])[agree],
                                       np.asarray(p[i])[agree],
                                       rtol=2e-4, atol=1e-5)
        for rc, pc in zip(r[9], p[9]):
            np.testing.assert_allclose(np.asarray(rc), np.asarray(pc),
                                       rtol=2e-4, atol=1e-5)

    @pytest.mark.parametrize("b,f", [(6, 19), (4, 200)])
    def test_poly_fixed_mode_matches_ref(self, b, f):
        # rk4 + fixed mode: non-FSAL, zero error weights, empty ctrl tuple.
        tab = TABLEAUS["rk4"]
        (y, _, t, t_new, dt_cur, safe_dt,
         running, prev_inv, prev2_inv) = _fused_inputs(13 + f, b, f, tab.stages)
        poly = (0.0, 1.0, -1.0)
        f0 = ref.poly_eval(y, poly)
        a, c, b_sol, b_err = _tableau_arrays(tab, np.float32)
        kw = dict(a=tuple(map(tuple, a.tolist())), c=tuple(c.tolist()),
                  b_sol=tuple(b_sol.tolist()), b_err=tuple(b_err.tolist()),
                  poly=poly, ctrl=(), want_coeffs=True, fsal=tab.fsal,
                  ctrl_mode="fixed")
        r = ref.fused_step_poly(y, f0, t, t_new, dt_cur, safe_dt, running,
                                prev_inv, prev2_inv, 1e-4, 1e-3, **kw)
        p = pi.fused_step_poly(y, f0, t, t_new, dt_cur, safe_dt, running,
                               prev_inv, prev2_inv, 1e-4, 1e-3,
                               interpret=True, **kw)
        np.testing.assert_array_equal(np.asarray(r[2]), np.asarray(running))
        for i in range(9):
            np.testing.assert_allclose(np.asarray(r[i]), np.asarray(p[i]),
                                       rtol=2e-4, atol=1e-5)
        for rc, pc in zip(r[9], p[9]):
            np.testing.assert_allclose(np.asarray(rc), np.asarray(pc),
                                       rtol=2e-4, atol=1e-5)

    def test_running_mask_freezes_state(self):
        # The contract the loop relies on: a non-running instance commits
        # NOTHING -- y, f, t keep their inputs and dt keeps the standing
        # proposal, regardless of what the controller would have decided.
        b, f, s = 8, 12, 7
        (y, K, t, t_new, dt_cur, safe_dt,
         running, prev_inv, prev2_inv) = _fused_inputs(11, b, f, s)
        running = jnp.asarray([True, False] * 4)
        _, _, b_sol, b_err = _tableau_arrays(TABLEAUS["dopri5"], np.float32)
        kw = dict(b_sol=tuple(b_sol.tolist()), b_err=tuple(b_err.tolist()),
                  ctrl=CTRL.filter_params(5), want_coeffs=False)
        for impl, extra in ((ref.fused_step, {}), (pi.fused_step, {"interpret": True})):
            (y1, ratio, accept, y_out, f_out, t_out, dt_out,
             i1, i2, coeffs) = impl(
                y, K, K[-1], t, t_new, dt_cur, safe_dt, running,
                prev_inv, prev2_inv, 1e-2, 1e-3, **kw, **extra)
            frozen = ~np.asarray(running)
            assert not np.asarray(accept)[frozen].any()
            np.testing.assert_array_equal(np.asarray(y_out)[frozen], np.asarray(y)[frozen])
            np.testing.assert_array_equal(np.asarray(f_out)[frozen], np.asarray(K)[0][frozen])
            np.testing.assert_array_equal(np.asarray(t_out)[frozen], np.asarray(t)[frozen])
            np.testing.assert_array_equal(np.asarray(dt_out)[frozen], np.asarray(dt_cur)[frozen])
            assert coeffs is None


class TestFusedSolve:
    """The fused=True fast path end to end against the unfused solver."""

    def _solve(self, term, y0, fused, method="dopri5", dense=True, **kw):
        te = jnp.linspace(0.0, 2.0, 9) if dense else None
        return solve_ivp(term, y0, te, t_start=0.0, t_end=2.0, dense=dense,
                         method=method, controller=pid_controller(),
                         rtol=1e-4, atol=1e-7, fused=fused, **kw)

    @staticmethod
    def _assert_bitwise(a, c):
        np.testing.assert_array_equal(np.asarray(a.ys), np.asarray(c.ys))
        np.testing.assert_array_equal(np.asarray(a.ts), np.asarray(c.ts))
        np.testing.assert_array_equal(np.asarray(a.status), np.asarray(c.status))
        for key in ("n_steps", "n_accepted", "n_f_evals"):
            np.testing.assert_array_equal(
                np.asarray(a.stats[key]), np.asarray(c.stats[key]), err_msg=key)
        # The counter proves the megakernel path actually ran every step.
        np.testing.assert_array_equal(np.asarray(c.stats["n_fused_steps"]),
                                      np.asarray(c.stats["n_steps"]))
        assert "n_fused_steps" not in a.stats
        assert not np.asarray(c.stats["fused_fallback_reason"]).any()

    @pytest.mark.parametrize("method", EXPLICIT)
    @pytest.mark.parametrize("dense", [False, True])
    def test_bitwise_equal_on_ref_backend(self, method, dense):
        # EVERY explicit tableau -- FSAL and non-FSAL, adaptive and fixed-step
        # (zero error weights under the PID controller) -- takes the fused
        # path and must be indistinguishable from the unfused solver.
        old = ops.backend()
        ops.set_backend("ref")
        try:
            y0 = jnp.asarray(np.random.default_rng(5).uniform(0.5, 1.5, (6, 8)),
                             jnp.float32)
            term = lambda t, y, args: -y + 0.1 * jnp.sin(y)
            kw = {} if TABLEAUS[method].b_err is not None else {"dt0": 0.05}
            a = self._solve(term, y0, False, method=method, dense=dense, **kw)
            c = self._solve(term, y0, True, method=method, dense=dense, **kw)
            self._assert_bitwise(a, c)
        finally:
            ops.set_backend(old)

    @pytest.mark.parametrize("method", ["heun", "rk4"])
    def test_fixed_controller_fused_bitwise(self, method):
        # FixedController routes through the kernel's ctrl_mode="fixed"
        # switch: always-accept, dt passthrough, controller state untouched.
        old = ops.backend()
        ops.set_backend("ref")
        try:
            y0 = jnp.asarray(np.random.default_rng(7).uniform(0.5, 1.5, (4, 6)),
                             jnp.float32)
            term = lambda t, y, args: -y + 0.1 * jnp.sin(y)
            kw = dict(t_start=0.0, t_end=1.0, method=method, dt0=0.05,
                      controller=FixedController())
            a = solve_ivp(term, y0, jnp.linspace(0.0, 1.0, 5), fused=False, **kw)
            c = solve_ivp(term, y0, jnp.linspace(0.0, 1.0, 5), fused=True, **kw)
            self._assert_bitwise(a, c)
            np.testing.assert_array_equal(np.asarray(c.stats["n_steps"]),
                                          np.asarray(c.stats["n_accepted"]))
        finally:
            ops.set_backend(old)

    @pytest.mark.parametrize("method", ["dopri5", "heun"])
    def test_events_fused_bitwise(self, method):
        # Events run through the same fused_event_detect/commit ops on both
        # paths; the whole Solution -- terminal stop, bisection-refined event
        # times, recorded states -- must stay bitwise-equal.
        old = ops.backend()
        ops.set_backend("ref")
        try:
            y0 = jnp.asarray(np.random.default_rng(9).uniform(0.8, 1.6, (5, 3)),
                             jnp.float32)
            term = lambda t, y, args: -y
            events = [
                Event(lambda t, y, args: jnp.min(y) - 0.5, terminal=True),
                Event(lambda t, y, args: jnp.sum(y) - 2.0, terminal=False,
                      direction=-1.0),
            ]
            kw = dict(t_start=0.0, t_end=3.0, method=method, events=events)
            a = solve_ivp(term, y0, jnp.linspace(0.0, 3.0, 7), fused=False, **kw)
            c = solve_ivp(term, y0, jnp.linspace(0.0, 3.0, 7), fused=True, **kw)
            self._assert_bitwise(a, c)
            for key in ("event_t", "event_y", "event_mask"):
                np.testing.assert_array_equal(np.asarray(getattr(a, key)),
                                              np.asarray(getattr(c, key)),
                                              err_msg=key)
            assert np.asarray(c.event_mask).any(), "want events to actually fire"
        finally:
            ops.set_backend(old)

    def test_polynomial_term_bitwise_and_fused(self):
        old = ops.backend()
        ops.set_backend("ref")
        try:
            y0 = jnp.asarray(np.random.default_rng(6).uniform(0.5, 1.5, (5, 7)),
                             jnp.float32)
            term = polynomial_term(0.0, 1.0, -1.0)  # logistic
            assert isinstance(term, PolynomialTerm)
            a = self._solve(term, y0, False)
            c = self._solve(term, y0, True)
            np.testing.assert_array_equal(np.asarray(a.ys), np.asarray(c.ys))
            np.testing.assert_array_equal(np.asarray(a.stats["n_f_evals"]),
                                          np.asarray(c.stats["n_f_evals"]))
            np.testing.assert_array_equal(np.asarray(c.stats["n_fused_steps"]),
                                          np.asarray(c.stats["n_steps"]))
        finally:
            ops.set_backend(old)

    @pytest.mark.parametrize("f", [4, 200])
    def test_interpret_backend_fused_solve(self, f):
        # f=200 crosses the 128-lane tile boundary, so the two-phase tiled
        # schedule runs inside the actual solver loop, not just the op tests.
        old = ops.backend()
        ops.set_backend("interpret")
        try:
            y0 = jnp.ones((3, f), jnp.float32)
            sol = self._solve(polynomial_term(0.0, -1.0), y0, True, method="tsit5")
            exp = np.exp(-np.asarray(sol.ts))[..., None] * np.ones((1, 1, f))
            np.testing.assert_allclose(np.asarray(sol.ys), exp, rtol=1e-3, atol=1e-5)
            assert "n_fused_steps" in sol.stats
        finally:
            ops.set_backend(old)

    @pytest.mark.parametrize("method", ["heun", "rk4"])
    def test_non_fsal_methods_now_fuse(self, method):
        # Non-FSAL (heun) and fixed-step (rk4) tableaus used to fall back to
        # the unfused path; they now fuse -- bitwise, counter engaged.
        old = ops.backend()
        ops.set_backend("ref")
        try:
            y0 = jnp.ones((2, 3), jnp.float32)
            term = polynomial_term(0.0, -1.0)
            kw = {} if method == "heun" else {"dt0": 0.05}
            a = solve_ivp(term, y0, jnp.linspace(0.0, 1.0, 5), method=method,
                          fused=False, **kw)
            c = solve_ivp(term, y0, jnp.linspace(0.0, 1.0, 5), method=method,
                          fused=True, **kw)
            self._assert_bitwise(a, c)
        finally:
            ops.set_backend(old)


class TestFusedFallbackReason:
    """The machine-readable engagement report: when ``fused=True`` is
    requested, ``stats["fused_fallback_reason"]`` says whether the megakernel
    ran and, if not, why -- one test per cause."""

    def _solve(self, fused, **kw):
        kw.setdefault("method", "dopri5")
        return solve_ivp(lambda t, y, args: -y, jnp.ones((3, 4), jnp.float32),
                         jnp.linspace(0.0, 1.0, 5), fused=fused, **kw)

    def test_engaged(self):
        sol = self._solve(True)
        np.testing.assert_array_equal(
            np.asarray(sol.stats["fused_fallback_reason"]),
            np.full(3, int(FusedFallbackReason.ENGAGED)))
        assert "n_fused_steps" in sol.stats

    def test_absent_when_not_requested(self):
        assert "fused_fallback_reason" not in self._solve(False).stats

    def test_implicit_stepper_engages(self):
        # DIRK methods take the factor-once fused path since the implicit
        # megakernel landed; the fallback reason must say ENGAGED.
        sol = self._solve(True, method="kvaerno3")
        np.testing.assert_array_equal(
            np.asarray(sol.stats["fused_fallback_reason"]),
            np.full(3, int(FusedFallbackReason.ENGAGED)))
        assert "n_fused_steps" in sol.stats

    def test_implicit_stepper_subclass_falls_back(self):
        from repro.core import DiagonallyImplicitRK

        class CustomDIRK(DiagonallyImplicitRK):
            pass

        sol = self._solve(True, method=CustomDIRK("kvaerno3"))
        np.testing.assert_array_equal(
            np.asarray(sol.stats["fused_fallback_reason"]),
            np.full(3, int(FusedFallbackReason.UNSUPPORTED_IMPLICIT)))
        assert "n_fused_steps" not in sol.stats

    def test_unsupported_controller(self):
        # A controller SUBCLASS may override __call__, so only exact
        # PIDController/FixedController types engage the kernel.
        class LenientController(PIDController):
            def __call__(self, err_ratio, dt, state, k):
                accept, dt_next, new_state = super().__call__(err_ratio, dt, state, k)
                return accept | (err_ratio <= 2.0), dt_next, new_state

        sol = self._solve(True, controller=LenientController())
        np.testing.assert_array_equal(
            np.asarray(sol.stats["fused_fallback_reason"]),
            np.full(3, int(FusedFallbackReason.UNSUPPORTED_CONTROLLER)))
        assert "n_fused_steps" not in sol.stats
