"""Fused step megakernel: interpret-mode parity vs the ref oracle, the
bitwise fused-vs-unfused solve contract, the running-mask freeze, and the
``reset_backend`` regression.  Deliberately hypothesis-free so this file runs
even where ``test_kernels.py``'s property tests are skipped."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PolynomialTerm,
    pid_controller,
    polynomial_term,
    solve_ivp,
)
from repro.core.stepper import _tableau_arrays
from repro.core.tableau import TABLEAUS
from repro.kernels import ops, pallas_impl as pi, ref

EXPLICIT = [n for n, tab in TABLEAUS.items() if not tab.implicit]
EXPLICIT_FSAL = [
    n for n in EXPLICIT if TABLEAUS[n].fsal and TABLEAUS[n].b_err is not None
]
CTRL = pid_controller()


class TestResetBackend:
    def test_reset_backend_rereads_env(self, monkeypatch):
        # Regression: backend() used to latch its choice on the FIRST dispatch
        # forever -- REPRO_KERNEL_BACKEND set afterwards was silently ignored.
        # reset_backend() must drop the latch and re-read the environment.
        old = ops.backend()
        target = "interpret" if old != "interpret" else "ref"
        try:
            monkeypatch.setenv("REPRO_KERNEL_BACKEND", target)
            assert ops.backend() == old  # still latched: env change invisible
            ops.reset_backend()
            assert ops.backend() == target  # re-read after reset
        finally:
            ops.set_backend(old)


def _fused_inputs(seed, b, f, s, dtype=np.float32):
    rng = np.random.default_rng(seed)
    y = jnp.asarray(rng.uniform(0.5, 1.5, (b, f)), dtype)
    K = jnp.asarray(rng.standard_normal((s, b, f)), dtype)
    t = jnp.asarray(rng.uniform(0.0, 1.0, b), dtype)
    dt_cur = jnp.asarray(rng.uniform(0.05, 0.2, b), dtype)
    safe_dt = dt_cur * 0.9
    t_new = t + safe_dt
    running = jnp.asarray(rng.uniform(size=b) > 0.25)
    prev_inv = jnp.asarray(rng.uniform(0.5, 2.0, b), dtype)
    prev2_inv = jnp.asarray(rng.uniform(0.5, 2.0, b), dtype)
    return y, K, t, t_new, dt_cur, safe_dt, running, prev_inv, prev2_inv


class TestFusedStepOp:
    """Interpret-mode megakernel vs the ref oracle, every explicit tableau."""

    @pytest.mark.parametrize("name", EXPLICIT)
    def test_matches_ref(self, name):
        tab = TABLEAUS[name]
        b, f, s = 9, 37, tab.stages
        (y, K, t, t_new, dt_cur, safe_dt,
         running, prev_inv, prev2_inv) = _fused_inputs(hash(name) % 1000, b, f, s)
        _, _, b_sol, b_err = _tableau_arrays(tab, np.float32)
        kw = dict(b_sol=tuple(b_sol.tolist()), b_err=tuple(b_err.tolist()),
                  ctrl=CTRL.filter_params(tab.error_order), want_coeffs=True)
        # Pick atol so the batch's error ratios straddle 1 (mixed
        # accept/reject): scale is atol-dominated here, so ratio ~ 1/atol.
        probe = np.asarray(ref.fused_step(
            y, K, K[-1], t, t_new, dt_cur, safe_dt, running,
            prev_inv, prev2_inv, 0.05, 1e-3, **kw)[1])
        atol = float(0.05 * np.median(probe)) if probe.any() else 0.05
        r = ref.fused_step(y, K, K[-1], t, t_new, dt_cur, safe_dt, running,
                           prev_inv, prev2_inv, atol, 1e-3, **kw)
        p = pi.fused_step(y, K, K[-1], t, t_new, dt_cur, safe_dt, running,
                          prev_inv, prev2_inv, atol, 1e-3, interpret=True, **kw)
        if tab.b_err is not None:
            accept = np.asarray(r[2])[np.asarray(running)]
            assert accept.any() and (~accept).any(), "want a mixed batch"
        for rr, pp in zip(r[:9], p[:9]):
            np.testing.assert_allclose(np.asarray(rr), np.asarray(pp),
                                       rtol=3e-5, atol=1e-5)
        for rc, pc in zip(r[9], p[9]):
            np.testing.assert_allclose(np.asarray(rc), np.asarray(pc),
                                       rtol=3e-5, atol=1e-5)

    @pytest.mark.parametrize("name", EXPLICIT_FSAL)
    def test_poly_matches_ref(self, name):
        tab = TABLEAUS[name]
        b, f = 6, 19
        (y, _, t, t_new, dt_cur, safe_dt,
         running, prev_inv, prev2_inv) = _fused_inputs(3, b, f, tab.stages)
        # Moderate dt keeps the error estimate well above float32 cancellation
        # noise (a tiny estimate is the difference of O(1) stage slopes).
        dt_cur = dt_cur * 4.0
        safe_dt = dt_cur * 0.9
        t_new = t + safe_dt
        poly = (0.0, 1.0, -1.0)  # logistic: dy/dt = y - y^2
        f0 = ref.poly_eval(y, poly)
        a, c, b_sol, b_err = _tableau_arrays(tab, np.float32)
        kw = dict(a=tuple(map(tuple, a.tolist())), c=tuple(c.tolist()),
                  b_sol=tuple(b_sol.tolist()), b_err=tuple(b_err.tolist()),
                  poly=poly, ctrl=CTRL.filter_params(tab.error_order),
                  want_coeffs=True)
        r = ref.fused_step_poly(y, f0, t, t_new, dt_cur, safe_dt, running,
                                prev_inv, prev2_inv, 1e-4, 1e-3, **kw)
        p = pi.fused_step_poly(y, f0, t, t_new, dt_cur, safe_dt, running,
                               prev_inv, prev2_inv, 1e-4, 1e-3,
                               interpret=True, **kw)
        # State outputs are tight; the error estimate b_err@K is a CANCELLING
        # combination of O(1) stage slopes, so the controller outputs derived
        # from it (err_ratio, dt_out, new_inv*) carry percent-level float32
        # summation-order noise for high-order tableaus -- gate them loosely.
        tight, loose = (0, 3, 4, 5), (1, 6, 7, 8)
        for i in tight:
            np.testing.assert_allclose(np.asarray(r[i]), np.asarray(p[i]),
                                       rtol=2e-4, atol=1e-5)
        for i in loose:
            np.testing.assert_allclose(np.asarray(r[i]), np.asarray(p[i]),
                                       rtol=3e-2, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(r[2]), np.asarray(p[2]))
        for rc, pc in zip(r[9], p[9]):
            np.testing.assert_allclose(np.asarray(rc), np.asarray(pc),
                                       rtol=2e-4, atol=1e-5)

    def test_running_mask_freezes_state(self):
        # The contract the loop relies on: a non-running instance commits
        # NOTHING -- y, f, t keep their inputs and dt keeps the standing
        # proposal, regardless of what the controller would have decided.
        b, f, s = 8, 12, 7
        (y, K, t, t_new, dt_cur, safe_dt,
         running, prev_inv, prev2_inv) = _fused_inputs(11, b, f, s)
        running = jnp.asarray([True, False] * 4)
        _, _, b_sol, b_err = _tableau_arrays(TABLEAUS["dopri5"], np.float32)
        kw = dict(b_sol=tuple(b_sol.tolist()), b_err=tuple(b_err.tolist()),
                  ctrl=CTRL.filter_params(5), want_coeffs=False)
        for impl, extra in ((ref.fused_step, {}), (pi.fused_step, {"interpret": True})):
            (y1, ratio, accept, y_out, f_out, t_out, dt_out,
             i1, i2, coeffs) = impl(
                y, K, K[-1], t, t_new, dt_cur, safe_dt, running,
                prev_inv, prev2_inv, 1e-2, 1e-3, **kw, **extra)
            frozen = ~np.asarray(running)
            assert not np.asarray(accept)[frozen].any()
            np.testing.assert_array_equal(np.asarray(y_out)[frozen], np.asarray(y)[frozen])
            np.testing.assert_array_equal(np.asarray(f_out)[frozen], np.asarray(K)[0][frozen])
            np.testing.assert_array_equal(np.asarray(t_out)[frozen], np.asarray(t)[frozen])
            np.testing.assert_array_equal(np.asarray(dt_out)[frozen], np.asarray(dt_cur)[frozen])
            assert coeffs is None


class TestFusedSolve:
    """The fused=True fast path end to end against the unfused solver."""

    def _solve(self, term, y0, fused, method="dopri5", dense=True, **kw):
        te = jnp.linspace(0.0, 2.0, 9) if dense else None
        return solve_ivp(term, y0, te, t_start=0.0, t_end=2.0, dense=dense,
                         method=method, controller=pid_controller(),
                         rtol=1e-4, atol=1e-7, fused=fused, **kw)

    @pytest.mark.parametrize("method", EXPLICIT_FSAL)
    @pytest.mark.parametrize("dense", [False, True])
    def test_bitwise_equal_on_ref_backend(self, method, dense):
        old = ops.backend()
        ops.set_backend("ref")
        try:
            y0 = jnp.asarray(np.random.default_rng(5).uniform(0.5, 1.5, (6, 8)),
                             jnp.float32)
            term = lambda t, y, args: -y + 0.1 * jnp.sin(y)
            a = self._solve(term, y0, False, method=method, dense=dense)
            c = self._solve(term, y0, True, method=method, dense=dense)
            np.testing.assert_array_equal(np.asarray(a.ys), np.asarray(c.ys))
            np.testing.assert_array_equal(np.asarray(a.ts), np.asarray(c.ts))
            np.testing.assert_array_equal(np.asarray(a.status), np.asarray(c.status))
            for key in ("n_steps", "n_accepted", "n_f_evals"):
                np.testing.assert_array_equal(
                    np.asarray(a.stats[key]), np.asarray(c.stats[key]), err_msg=key)
            # The counter proves the megakernel path actually ran every step.
            np.testing.assert_array_equal(np.asarray(c.stats["n_fused_steps"]),
                                          np.asarray(c.stats["n_steps"]))
            assert "n_fused_steps" not in a.stats
        finally:
            ops.set_backend(old)

    def test_polynomial_term_bitwise_and_fused(self):
        old = ops.backend()
        ops.set_backend("ref")
        try:
            y0 = jnp.asarray(np.random.default_rng(6).uniform(0.5, 1.5, (5, 7)),
                             jnp.float32)
            term = polynomial_term(0.0, 1.0, -1.0)  # logistic
            assert isinstance(term, PolynomialTerm)
            a = self._solve(term, y0, False)
            c = self._solve(term, y0, True)
            np.testing.assert_array_equal(np.asarray(a.ys), np.asarray(c.ys))
            np.testing.assert_array_equal(np.asarray(a.stats["n_f_evals"]),
                                          np.asarray(c.stats["n_f_evals"]))
            np.testing.assert_array_equal(np.asarray(c.stats["n_fused_steps"]),
                                          np.asarray(c.stats["n_steps"]))
        finally:
            ops.set_backend(old)

    def test_interpret_backend_fused_solve(self):
        old = ops.backend()
        ops.set_backend("interpret")
        try:
            y0 = jnp.ones((3, 4), jnp.float32)
            sol = self._solve(polynomial_term(0.0, -1.0), y0, True, method="tsit5")
            exp = np.exp(-np.asarray(sol.ts))[..., None] * np.ones((1, 1, 4))
            np.testing.assert_allclose(np.asarray(sol.ys), exp, rtol=1e-3, atol=1e-5)
            assert "n_fused_steps" in sol.stats
        finally:
            ops.set_backend(old)

    @pytest.mark.parametrize("method", ["heun", "rk4"])
    def test_fallback_for_non_fsal_methods(self, method):
        # Non-FSAL (heun) and fixed-step (rk4) tableaus must fall back to the
        # unfused path transparently: same results as fused=False, no counter.
        y0 = jnp.ones((2, 3), jnp.float32)
        term = polynomial_term(0.0, -1.0)
        kw = {} if method == "heun" else {"dt0": 0.05}
        a = solve_ivp(term, y0, jnp.linspace(0.0, 1.0, 5), method=method,
                      fused=False, **kw)
        c = solve_ivp(term, y0, jnp.linspace(0.0, 1.0, 5), method=method,
                      fused=True, **kw)
        np.testing.assert_array_equal(np.asarray(a.ys), np.asarray(c.ys))
        assert "n_fused_steps" not in c.stats
