"""Quickstart: the paper's Listing 1, in JAX.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import Status, solve_ivp


def vdp(t, y, mu):
    x, xdot = y[..., 0], y[..., 1]
    return jnp.stack((xdot, mu * (1 - x**2) * xdot - x), axis=-1)


batch_size, mu = 5, 10.0
y0 = jax.random.normal(jax.random.PRNGKey(0), (batch_size, 2))
t_eval = jnp.linspace(0.0, 10.0, 50)

sol = jax.jit(lambda y: solve_ivp(vdp, y, t_eval, method="tsit5", args=mu))(y0)

print("status:", sol.status)  # => [0 0 0 0 0]
assert all(sol.status == Status.SUCCESS.value)
print("stats:")
for k, v in sorted(sol.stats.items()):
    print(f"  {k}: {v}")
# Per-instance step counts differ (independent adaptive stepping); n_f_evals is
# shared across the batch (the dynamics run on the full batch every iteration,
# "overhanging evaluations" included) -- exactly torchode's Listing 1 output.
