"""Quickstart: the paper's Listing 1, in JAX.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import (
    AutoDiffAdjoint,
    Status,
    Stepper,
    integral_controller,
    pid_controller,
    solve_ivp,
)


def vdp(t, y, mu):
    x, xdot = y[..., 0], y[..., 1]
    return jnp.stack((xdot, mu * (1 - x**2) * xdot - x), axis=-1)


batch_size, mu = 5, 10.0
y0 = jax.random.normal(jax.random.PRNGKey(0), (batch_size, 2))
t_eval = jnp.linspace(0.0, 10.0, 50)

sol = jax.jit(lambda y: solve_ivp(vdp, y, t_eval, method="tsit5", args=mu))(y0)

print("status:", sol.status)  # => [0 0 0 0 0]
assert all(sol.status == Status.SUCCESS.value)
print("stats:")
for k, v in sorted(sol.stats.items()):
    print(f"  {k}: {v}")
# Per-instance step counts differ (independent adaptive stepping); n_f_evals is
# shared across the batch (the dynamics run on the full batch every iteration,
# "overhanging evaluations" included) -- exactly torchode's Listing 1 output.

# --- the same solve through the component API --------------------------------
# Term, stepper, controller and driver are independently swappable; this is
# the paper's AutoDiffAdjoint(stepper, controller) construction.
solver = AutoDiffAdjoint(Stepper("tsit5"), integral_controller())
sol2 = jax.jit(lambda y: solver.solve(vdp, y, t_eval, args=mu))(y0)
assert jnp.allclose(sol2.ys, sol.ys, atol=1e-5)
print("component API matches the one-liner")

# Swapping the controller is a one-word change -- PID takes a different
# (usually shorter) step sequence, so results agree only to tolerance:
pid_solver = AutoDiffAdjoint(Stepper("tsit5"), pid_controller())
sol_pid = jax.jit(lambda y: pid_solver.solve(vdp, y, t_eval, args=mu))(y0)
print("pid n_steps:", sol_pid.stats["n_steps"], "vs integral:", sol2.stats["n_steps"])

# --- PyTree states -----------------------------------------------------------
# Initial states may be arbitrary PyTrees (leaves batched on axis 0); the
# vector field then receives one instance's PyTree with a scalar t.  The hot
# loop still runs on flat (batch, features) buffers.
y0_tree = {"x": y0[:, :1], "v": {"xdot": y0[:, 1:]}}


def vdp_tree(t, y, mu):
    x, xdot = y["x"], y["v"]["xdot"]
    return {"x": xdot, "v": {"xdot": mu * (1 - x**2) * xdot - x}}


sol3 = jax.jit(lambda y: solver.solve(vdp_tree, y, t_eval, args=mu))(y0_tree)
print("pytree ys shapes:", jax.tree_util.tree_map(lambda a: a.shape, sol3.ys))
assert jnp.allclose(sol3.ys["x"], sol.ys[..., :1], atol=1e-4)
print("pytree solve matches the flat solve")
