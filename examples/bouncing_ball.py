"""Bouncing ball: per-instance terminal events + hybrid-system restarts.

    PYTHONPATH=src python examples/bouncing_ball.py

A batch of balls is dropped from different heights with different
coefficients of restitution.  Each impact is a terminal ``Event`` on the
height: every instance stops independently at ITS localized impact time
(``Status.EVENT``), the solver reports the interpolated impact state, and the
hybrid-system jump (velocity reflection) happens outside the solver before
re-arming the event by solving the next flight segment.  Event times come
from masked bisection on the dense-output interpolant -- zero extra
vector-field evaluations (compare ``n_f_evals`` with and without the event).
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Event, Status, solve_ivp

G = 9.81
N_BOUNCES = 4


def ball(t, y, args):
    """Free fall: y = (height, velocity)."""
    return jnp.stack((y[..., 1], jnp.full_like(y[..., 1], -G)), axis=-1)


ground = Event(lambda t, y, args: y[0], terminal=True, direction=-1.0)

h0 = np.array([10.0, 10.0, 4.0, 1.0])
restitution = np.array([0.9, 0.5, 0.7, 0.8])
y = jnp.asarray(np.stack([h0, np.zeros_like(h0)], 1), jnp.float32)
t = jnp.zeros((len(h0),), jnp.float32)

segment = jax.jit(
    lambda y, t: solve_ivp(
        ball, y, None, t_start=t, t_end=t + 10.0, events=ground,
        rtol=1e-6, atol=1e-9,
    )
)

print("ball     impact times (s)")
impacts = []
for bounce in range(N_BOUNCES):
    sol = segment(y, t)
    assert np.all(np.asarray(sol.status) == Status.EVENT.value)
    t = sol.ts  # per-instance impact time (== event_t[:, 0])
    impacts.append(np.asarray(t))
    # hybrid jump: reflect the velocity, damped by the restitution coefficient
    h, v = sol.ys[:, 0], sol.ys[:, 1]
    y = jnp.stack([jnp.zeros_like(h), -restitution * v], axis=1)

impacts = np.stack(impacts, 1)
for i, row in enumerate(impacts):
    print(f"  #{i}   " + "  ".join(f"{x:7.4f}" for x in row))

# Analytic check: the first impact is at t = sqrt(2 h0 / g) and every later
# flight is a scaled replay, so the k-th impact (k = 0, 1, ...) lands at
# t_k = sqrt(2 h0 / g) * (1 + 2 sum_{j=1..k} r^j).
t_hit = np.sqrt(2.0 * h0 / G)
powers = restitution[:, None] ** np.arange(1, N_BOUNCES)[None, :]
expect = t_hit[:, None] * np.concatenate(
    [np.ones((len(h0), 1)), 1.0 + 2.0 * np.cumsum(powers, axis=1)], axis=1
)
err = np.abs(impacts - expect).max()
print(f"max |impact - analytic| over {N_BOUNCES} bounces: {err:.2e}")
assert err < 1e-3
