"""End-to-end LM training driver on a reduced assigned architecture --
exercises the full production path: sharded train step, activation
constraints, checkpointing, watchdog, resumable data.

    PYTHONPATH=src python examples/train_lm.py [arch] [steps]
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "qwen2.5-14b"
    steps = sys.argv[2] if len(sys.argv) > 2 else "200"
    main([
        "--arch", arch, "--reduced", "--steps", steps, "--batch", "16",
        "--seq", "128", "--ckpt-dir", "/tmp/repro_lm_ckpt", "--remat",
    ])
