"""Continuous normalizing flow (FFJORD-style) on a 2-D density, trained with
the JOINT adjoint backward (the paper's torchode-joint fast path).

    PYTHONPATH=src python examples/cnf_density.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.cnf_bench import aug_dynamics, clip_tree, init_mlp, nll_loss, two_moons  # noqa: E402
from repro.core.adjoint import make_adjoint_solve  # noqa: E402


def main():
    key = jax.random.PRNGKey(0)
    params = init_mlp(key)
    x = two_moons(key, 512)
    solve = make_adjoint_solve(aug_dynamics, mode="joint", rtol=1e-4, atol=1e-4)
    loss_grad = jax.jit(jax.value_and_grad(lambda p: nll_loss(p, x, solve)))

    lr, m = 1e-2, jax.tree.map(jnp.zeros_like, params)
    for it in range(60):
        nll, g = loss_grad(params)
        g = clip_tree(g)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
        params = jax.tree.map(lambda p, mm: p - lr * mm, params, m)
        if it % 10 == 0:
            print(f"iter {it:3d}  nll {float(nll):.4f}")
    print(f"final nll {float(nll):.4f} (standard-normal baseline "
          f"{0.5*2*np.log(2*np.pi) + 1.0:.4f})")
    assert float(nll) < 2.5, "CNF should beat the unit-gaussian baseline"


if __name__ == "__main__":
    main()
