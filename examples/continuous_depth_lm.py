"""Continuous-depth transformer: the block stack integrated as a neural ODE by
the repro.core batch-parallel solver (weight-tied, adaptive depth per token
batch) -- the direct integration of the paper's technique into the LM substrate.

    PYTHONPATH=src python examples/continuous_depth_lm.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main  # noqa: E402

if __name__ == "__main__":
    main([
        "--arch", "stablelm-3b", "--reduced", "--ode-depth", "--steps", "30",
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
    ])
