"""Latent ODE for irregularly-sampled time series (Rubanova et al., 2019
setting, scaled down): every sequence has its OWN evaluation time grid --
the per-instance t_eval feature that torchode supports natively and joint
solvers cannot express without padding tricks.

Two training loops:

  - ``main()``: the classic in-process loop -- one jitted ``value_and_grad``
    over the whole batch (dense per-instance grids).
  - ``train_through_service()``: gradient serving -- every sequence is its
    own request, coalesced by the async ``SolveService`` into padded batches
    (final-state regime).  Forward requests produce z(t1), the client turns
    the decoder loss into per-request cotangents, and ``GradRequest``s pull
    them back through the coalesced VJP program.  The served gradients are
    asserted bitwise-equal to a solo ``ScanAdjoint`` solve of the same batch
    class before training starts.

    PYTHONPATH=src python examples/latent_ode.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (  # noqa: E402
    CompiledSolver,
    GradRequest,
    ODETerm,
    ScanAdjoint,
    SolveRequest,
    SolveService,
    Stepper,
    solve_ivp_scan,
)


def init_params(key, latent=8, hidden=32, obs=2):
    ks = jax.random.split(key, 5)
    s = lambda k, sh: jax.random.normal(k, sh) / np.sqrt(sh[0])
    return {
        "dyn_w1": s(ks[0], (latent, hidden)), "dyn_w2": s(ks[1], (hidden, latent)),
        "dec_w": s(ks[2], (latent, obs)),
        "enc_w": s(ks[3], (obs, latent)),
    }


def dynamics(t, z, p):
    return jnp.tanh(z @ p["dyn_w1"]) @ p["dyn_w2"]


def make_data(key, batch=16, n_obs=12):
    """Spirals observed at random, per-sequence times."""
    k1, k2 = jax.random.split(key)
    t = jnp.sort(jax.random.uniform(k1, (batch, n_obs)) * 4.0, axis=1)
    phase = jax.random.uniform(k2, (batch, 1)) * 2 * np.pi
    xy = jnp.stack([jnp.sin(t + phase), jnp.cos(t + phase)], -1)  # (b, n, 2)
    return t, xy


def main():
    key = jax.random.PRNGKey(0)
    params = init_params(key)
    t_obs, x_obs = make_data(key)

    def loss_fn(params):
        z0 = x_obs[:, 0, :] @ params["enc_w"]
        sol = solve_ivp_scan(dynamics, z0, t_obs, args=params, rtol=1e-3,
                             atol=1e-4, max_steps=64)  # per-instance time grids!
        pred = sol.ys @ params["dec_w"]
        return jnp.mean((pred - x_obs) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    lr = 5e-2
    m = jax.tree.map(jnp.zeros_like, params)
    for it in range(80):
        mse, g = grad_fn(params)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
        params = jax.tree.map(lambda p, mm: p - lr * mm, params, m)
        if it % 20 == 0:
            print(f"iter {it:3d}  mse {float(mse):.4f}")
    print(f"final mse {float(mse):.4f}")
    assert float(mse) < 0.3


def train_through_service(n_iters=10, lr=5e-2):
    """Final-state training where each sequence is a served request.

    The service coalesces the per-sequence requests into one padded batch
    per flush, compiles the VJP program once, and every later iteration is a
    pure cache hit.  Parameter gradients arrive as per-request rows (the
    ``batched_args`` path); the shared dynamics parameters are recovered by
    summing the rows client-side.
    """
    key = jax.random.PRNGKey(1)
    params = init_params(key)
    t_obs, x_obs = make_data(key)
    batch = x_obs.shape[0]
    x0, x1 = x_obs[:, 0, :], x_obs[:, -1, :]

    def dyn_single(t, z, p):  # one instance: z (latent,), its own params row
        return jnp.tanh(z @ p["dyn_w1"]) @ p["dyn_w2"]

    term = ODETerm(dyn_single, batched=False, batched_args=True)
    drv = ScanAdjoint(Stepper("dopri5"), max_steps=64, rtol=1e-3, atol=1e-4)
    svc = SolveService(max_batch=16, max_delay=None, max_inflight=2)

    def decode_loss(z1, dec_w):
        return jnp.mean((z1 @ dec_w - x1) ** 2)

    spans = [(float(t_obs[i, 0]), float(t_obs[i, -1])) for i in range(batch)]

    def submit(req_cls, params, z0, **kw):
        dyn = {"dyn_w1": params["dyn_w1"], "dyn_w2": params["dyn_w2"]}
        futs = []
        for i, (t0, t1) in enumerate(spans):
            ckw = {k: (v[i] if k == "cotangent" else v) for k, v in kw.items()}
            futs.append(svc.submit(req_cls(f=term, y0=z0[i], t0=t0, t1=t1,
                                           args=dyn, method=drv, **ckw)))
        svc.flush()
        return [f.result() for f in futs]

    def step(params):
        z0 = x0 @ params["enc_w"]
        sols = submit(SolveRequest, params, z0)
        z1 = jnp.stack([jnp.asarray(s.ys[0]) for s in sols])
        loss, (gz1, gdec) = jax.value_and_grad(decode_loss, argnums=(0, 1))(
            z1, params["dec_w"])
        results = submit(GradRequest, params, z0, cotangent=gz1)
        gz0 = jnp.stack([jnp.asarray(g.y0) for _, g in results])
        gdyn = jax.tree.map(lambda *rows: sum(jnp.asarray(r) for r in rows),
                            *[g.args for _, g in results])
        genc = x0.T @ gz0
        return loss, {"dyn_w1": gdyn["dyn_w1"], "dyn_w2": gdyn["dyn_w2"],
                      "dec_w": gdec, "enc_w": genc}, (z0, gz1, results)

    loss0, grads, (z0, gz1, results) = step(params)

    # --- parity: the served gradients ARE the solo ScanAdjoint gradients ---
    # (same batch class: 16 requests fill the bucket exactly)
    solver = CompiledSolver(drv, donate=False)
    stack = lambda x: jnp.stack([jnp.asarray(x, jnp.float32)] * batch)
    dyn = {"dyn_w1": params["dyn_w1"], "dyn_w2": params["dyn_w2"]}
    ref = solver.solve(
        term, z0, None,
        t_start=jnp.asarray([s[0] for s in spans], jnp.float32),
        t_end=jnp.asarray([s[1] for s in spans], jnp.float32),
        args=jax.tree.map(stack, dyn),
        rtol=stack(drv.rtol), atol=stack(drv.atol), cotangent=gz1)
    np.testing.assert_array_equal(
        np.stack([np.asarray(g.y0) for _, g in results]),
        np.asarray(ref.grads.y0))
    for k in ("dyn_w1", "dyn_w2"):
        np.testing.assert_array_equal(
            np.stack([np.asarray(g.args[k]) for _, g in results]),
            np.asarray(ref.grads.args[k]))
    print("served gradients bitwise-equal to solo ScanAdjoint: OK")

    m = jax.tree.map(jnp.zeros_like, params)
    loss = loss0
    for it in range(n_iters):
        loss, grads, _ = step(params)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, grads)
        params = jax.tree.map(lambda p, mm: p - lr * mm, params, m)
        if it % 5 == 0:
            print(f"iter {it:3d}  final-state mse {float(loss):.4f}")
    st = svc.stats()
    print(f"final-state mse {float(loss):.4f}  "
          f"(grad solves: {st['n_grad_solves']}, "
          f"grad device time: {st['grad_device_s']:.2f}s)")
    assert float(loss) < float(loss0)
    assert st["n_grad_solves"] == (n_iters + 1) * batch


if __name__ == "__main__":
    main()
    train_through_service()
