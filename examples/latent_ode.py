"""Latent ODE for irregularly-sampled time series (Rubanova et al., 2019
setting, scaled down): every sequence has its OWN evaluation time grid --
the per-instance t_eval feature that torchode supports natively and joint
solvers cannot express without padding tricks.

    PYTHONPATH=src python examples/latent_ode.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import solve_ivp_scan  # noqa: E402


def init_params(key, latent=8, hidden=32, obs=2):
    ks = jax.random.split(key, 5)
    s = lambda k, sh: jax.random.normal(k, sh) / np.sqrt(sh[0])
    return {
        "dyn_w1": s(ks[0], (latent, hidden)), "dyn_w2": s(ks[1], (hidden, latent)),
        "dec_w": s(ks[2], (latent, obs)),
        "enc_w": s(ks[3], (obs, latent)),
    }


def dynamics(t, z, p):
    return jnp.tanh(z @ p["dyn_w1"]) @ p["dyn_w2"]


def make_data(key, batch=16, n_obs=12):
    """Spirals observed at random, per-sequence times."""
    k1, k2 = jax.random.split(key)
    t = jnp.sort(jax.random.uniform(k1, (batch, n_obs)) * 4.0, axis=1)
    phase = jax.random.uniform(k2, (batch, 1)) * 2 * np.pi
    xy = jnp.stack([jnp.sin(t + phase), jnp.cos(t + phase)], -1)  # (b, n, 2)
    return t, xy


def main():
    key = jax.random.PRNGKey(0)
    params = init_params(key)
    t_obs, x_obs = make_data(key)

    def loss_fn(params):
        z0 = x_obs[:, 0, :] @ params["enc_w"]
        sol = solve_ivp_scan(dynamics, z0, t_obs, args=params, rtol=1e-3,
                             atol=1e-4, max_steps=64)  # per-instance time grids!
        pred = sol.ys @ params["dec_w"]
        return jnp.mean((pred - x_obs) ** 2)

    grad_fn = jax.jit(jax.value_and_grad(loss_fn))
    lr = 5e-2
    m = jax.tree.map(jnp.zeros_like, params)
    for it in range(80):
        mse, g = grad_fn(params)
        m = jax.tree.map(lambda mm, gg: 0.9 * mm + gg, m, g)
        params = jax.tree.map(lambda p, mm: p - lr * mm, params, m)
        if it % 20 == 0:
            print(f"iter {it:3d}  mse {float(mse):.4f}")
    print(f"final mse {float(mse):.4f}")
    assert float(mse) < 0.3


if __name__ == "__main__":
    main()
